"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--smoke]

Sections:
  fig5   — normalized dataflow performance per tensor algebra (cycle model)
  fig6   — GEMM / depthwise-conv design-space area+power sweep
  sparse — block-sparse GEMM: BSR kernel parity + compressed-format costs
  batch_fold — grid-folded vs block-diagonal batch execution (MAC ratio +
         wall time; oracle parity)
  tune   — measured autotuning smoke: tuned vs untuned wall clock per cell,
         calibrated cycle model, BENCH_tune.json emission
  serve  — continuous-batching vs static-batch serving load (open-loop,
         mixed lengths; parity + speedup gate, BENCH_serve.json emission)
  graph  — fused vs unfused attention+MLP chain (HBM-bytes proxy floor +
         bit parity vs the explicit-schedule oracle, BENCH_graph.json)
  table3 — MM throughput comparison (XLA baselines + TPU roofline projection)
  roofline — aggregated dry-run roofline table (if results/dryrun exists)

``--smoke`` is the CI bench-regress entry point: same sections, smoke
subsets everywhere, so the emitted BENCH_*.json artifacts stay cheap
enough to regenerate on every PR (``benchmarks/check_regress.py``
validates them and enforces the regression floors afterwards).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(title):
    print("\n" + "=" * 72)
    print(f"== {title}")
    print("=" * 72)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: smoke flags for every section "
                         "(the sections below already default to their "
                         "smoke variants; the flag is the bench-regress "
                         "contract and gates the graph section's size)")
    args = ap.parse_args(argv)
    t0 = time.time()
    failures = []

    _section("Fig. 5 — dataflow performance (paper cycle model)")
    try:
        from benchmarks import fig5_dataflow_perf
        fig5_dataflow_perf.main()
    except Exception:
        failures.append("fig5")
        traceback.print_exc()

    _section("Fig. 6 — design-space exploration (area / power)")
    try:
        from benchmarks import fig6_dse
        fig6_dse.main()
    except Exception:
        failures.append("fig6")
        traceback.print_exc()

    _section("Block-sparse GEMM — BSR kernel + compressed-format costs")
    try:
        from benchmarks import sparse_gemm
        sys.argv = ["sparse_gemm"]
        sparse_gemm.main()
    except Exception:
        failures.append("sparse")
        traceback.print_exc()

    _section("Batch fold — grid-folded vs block-diagonal execution")
    try:
        from benchmarks import batch_fold
        sys.argv = ["batch_fold", "--smoke"]
        batch_fold.main()
    except Exception:
        failures.append("batch_fold")
        traceback.print_exc()

    _section("Measured autotuning — tuned vs untuned + calibration")
    try:
        from benchmarks import perf_iterate
        perf_iterate.run_tune_cells(smoke=True)
    except Exception:
        failures.append("tune")
        traceback.print_exc()

    _section("Serving load — continuous vs static batching")
    try:
        import json
        import pathlib

        from benchmarks import serve_load
        from repro.serve.report import validate_serve
        serve_load.main(["--smoke"])
        doc = json.loads((pathlib.Path(__file__).parent.parent
                          / "BENCH_serve.json").read_text())
        problems = validate_serve(doc)
        assert not problems, f"BENCH_serve.json invalid: {problems}"
    except Exception:
        failures.append("serve")
        traceback.print_exc()

    _section("Graph fusion — fused vs unfused attention+MLP chain")
    try:
        from benchmarks import graph_fusion
        graph_fusion.main(["--smoke"] if args.smoke else [])
    except Exception:
        failures.append("graph")
        traceback.print_exc()

    _section("Table III — matmul throughput comparison")
    try:
        from benchmarks import table3_comparison
        table3_comparison.main()
    except Exception:
        failures.append("table3")
        traceback.print_exc()

    _section("Roofline — dry-run aggregate (single-pod)")
    try:
        from benchmarks import roofline_report
        sys.argv = ["roofline_report"]
        roofline_report.main()
    except Exception:
        failures.append("roofline")
        traceback.print_exc()

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s; "
          f"failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
