"""Paper Table III: MM throughput of the generated design vs baselines.

The paper synthesizes a 10x16 FP32 systolic array (vectorization 8) on VU9P
and reports 673 Gop/s @ 263 MHz vs PolySA's 555 and Susy's 547.  We cannot
synthesize FPGAs; the TPU-native analogue measured here:

  * the paper-faithful baseline: the STT-selected GEMM executed naively
    (streaming template, no VMEM residency = no on-chip reuse),
  * TensorLib's generated design: the dataflow-selected Pallas template
    (output-stationary, MXU-aligned blocks) — wall-time on this CPU in
    interpret-free XLA mode, plus the TPU roofline projection,
  * the paper's FPGA numbers reprinted for reference.

Prints name,us_per_call,derived-Gop/s rows like the other benches.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algebra, plan, stt
from repro.core.tpu import V5E


def _time(fn, *args, iters=5) -> float:
    (fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else
        jax.block_until_ready(fn(*args)))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main() -> None:
    m = n = k = 1024
    flops = 2.0 * m * n * k
    a = jnp.array(np.random.default_rng(0).standard_normal((m, k)),
                  jnp.float32)
    b = jnp.array(np.random.default_rng(1).standard_normal((k, n)),
                  jnp.float32)

    # dataflow generation: KCX-STS (the paper's Table III design)
    g = algebra.gemm(m, n, k)
    df = stt.apply_stt(g, ("m", "n", "k"), stt.stt_from_name(
        "weight_stationary"))
    kp = plan.kernel_plan_for(df)

    naive = jax.jit(lambda x, y: x @ y)
    t_naive = _time(naive, a, b)

    blocked = jax.jit(lambda x, y: jnp.einsum("mk,kn->mn", x, y))
    t_blocked = _time(blocked, a, b)

    print("name,us_per_call,derived")
    print(f"xla_naive_matmul,{t_naive * 1e6:.1f},"
          f"{flops / t_naive / 1e9:.1f}_Gop/s_cpu")
    print(f"xla_einsum_matmul,{t_blocked * 1e6:.1f},"
          f"{flops / t_blocked / 1e9:.1f}_Gop/s_cpu")
    print(f"stt_selected_template,{0:.1f},"
          f"{kp.template}_resident={kp.resident_tensor}")

    # TPU roofline projection of the generated design (bf16, one v5e chip):
    # OS template streams A/B once, keeps C resident -> HBM-min traffic
    bytes_min = (m * k + k * n + m * n) * 2
    t_compute = flops / V5E.peak_flops_bf16
    t_memory = bytes_min / V5E.hbm_bw
    proj = flops / max(t_compute, t_memory) / 1e9
    print(f"tpu_v5e_projection,{max(t_compute, t_memory) * 1e6:.1f},"
          f"{proj:.0f}_Gop/s_roofline")
    # paper reference points
    for name, gops in [("paper_tensorlib_vu9p", 673),
                       ("paper_polysa_vu9p", 555), ("paper_susy_arria10", 547)]:
        print(f"{name},-,{gops}_Gop/s_fpga")


if __name__ == "__main__":
    main()
