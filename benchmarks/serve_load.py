"""Open-loop serving load: continuous batching vs static batching.

    PYTHONPATH=src python benchmarks/serve_load.py [--smoke] [--arch ...]

Generates a mixed prompt/output-length workload (``configs.SERVE_MIXES``),
drives it through both serving paths and reports throughput (tok/s),
p50/p99 request latency and slot occupancy:

* **continuous** — ``ContinuousServer`` over a ``SlotEngine``: requests
  land in free slots as they arrive, finished sequences are evicted
  without draining, the decode step never recompiles (asserted);
* **static** — the baseline ``DecodeEngine``: arrival-order batches of
  ``capacity``, prompts padded to the batch max, every batch decodes
  ``max(output_lens)`` steps and drains before the next batch starts.

Emits ``BENCH_serve.json`` (schema: ``repro.serve.report``) at the repo
root.  ``--smoke`` uses the burst mix, checks per-request bit-parity
against sequential ``DecodeEngine.generate`` and asserts the >= 1.5x
continuous-over-static throughput floor (the CI gate).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Dict, List, Tuple

import numpy as np
import jax

from repro.configs import SERVE_MIXES, get_config
from repro.models import init_params, split
from repro.serve import (ContinuousServer, DecodeEngine, ServeConfig,
                         SlotEngine, serve_entry, validate_serve)

ROOT = pathlib.Path(__file__).resolve().parent.parent


def build_workload(mix, seed: int = 0) -> List[Tuple[float, np.ndarray, int]]:
    """[(arrival_time_s, prompt, max_new_tokens)] — Poisson arrivals at
    ``mix.rate_rps`` (all zero for a burst mix).  Lengths cycle through
    the buckets so every (prompt, output) combination appears."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for i in range(mix.requests):
        if mix.rate_rps > 0:
            t += rng.exponential(1.0 / mix.rate_rps)
        s0 = mix.prompt_lens[i % len(mix.prompt_lens)]
        t_new = mix.output_lens[(i // len(mix.prompt_lens))
                                % len(mix.output_lens)]
        prompt = rng.integers(0, 64, (s0,)).astype(np.int32)
        out.append((t if mix.rate_rps > 0 else 0.0, prompt, t_new))
    return out


def run_continuous(params, cfg, workload, *, capacity: int, page_size: int,
                   max_context: int) -> Tuple[Dict, List[np.ndarray]]:
    engine = SlotEngine(params, cfg, capacity=capacity,
                        max_context=max_context, page_size=page_size,
                        serve_cfg=ServeConfig())
    # warmup outside the clock: compile prefill (per prompt length) and
    # the one decode step
    for s0 in sorted({p.shape[0] for _, p, _ in workload}):
        slot, _ = engine.insert(np.ones((s0,), np.int32), max_new_tokens=1)
        engine.step()
        engine.evict(slot)
    assert engine.decode_compiles == 1, engine.decode_compiles

    futures = []
    t0 = time.perf_counter()
    with ContinuousServer(engine, prefill_per_step=2) as server:
        for arrive_at, prompt, t_new in workload:
            now = time.perf_counter() - t0
            if arrive_at > now:
                time.sleep(arrive_at - now)
            futures.append(server.submit(prompt, max_new_tokens=t_new))
        server.drain(timeout=600)
        elapsed = time.perf_counter() - t0
        outputs = [f.result() for f in futures]
        lat = np.array([f.latency_s for f in futures])
        stats = {
            "throughput_tok_s": sum(map(len, outputs)) / elapsed,
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "mean_occupancy": server.mean_occupancy(),
            "steps": server.stats["steps"],
            "decode_compiles": engine.decode_compiles,
        }
    assert engine.decode_compiles == 1, (
        f"decode recompiled: {engine.decode_compiles} entries")
    return stats, outputs


def run_static(params, cfg, workload, *, capacity: int,
               pad_to: Tuple[int, ...]) -> Tuple[Dict, List[np.ndarray]]:
    """Arrival-order batches of ``capacity``; prompts right-padded to the
    batch max (bucketed so jit reuse is fair) and every batch decodes
    ``max(t_new)`` steps — the drain the slot engine avoids."""
    engine = DecodeEngine(params, cfg, ServeConfig())
    batches = []
    for start in range(0, len(workload), capacity):
        batch = workload[start:start + capacity]
        s_max = min(p for p in pad_to
                    if p >= max(q.shape[0] for _, q, _ in batch))
        t_max = max(t for _, _, t in batch)
        prompts = np.ones((len(batch), s_max), np.int32)
        for i, (_, q, _) in enumerate(batch):
            prompts[i, :q.shape[0]] = q   # right-pad: same left-aligned rope
        if len(batch) < capacity:         # static batches are fixed-size
            prompts = np.pad(prompts, ((0, capacity - len(batch)), (0, 0)),
                             constant_values=1)
        batches.append((batch, prompts, t_max))
    # warmup outside the clock: compile each (prompt_len, cache_len) the
    # timed loop will actually hit — same treatment the continuous path got
    for shape in sorted({(p.shape[1], t) for _, p, t in batches}):
        engine.generate(np.ones((capacity, shape[0]), np.int32),
                        max_new_tokens=shape[1])

    t0 = time.perf_counter()
    outputs: List[np.ndarray] = []
    finished_at: List[float] = []
    for batch, prompts, t_max in batches:
        gen, _ = engine.generate(prompts, max_new_tokens=t_max)
        done = time.perf_counter() - t0
        for i, (_, _, t_new) in enumerate(batch):
            outputs.append(gen[i, :t_new])
            finished_at.append(done)
    elapsed = time.perf_counter() - t0
    lat = np.array(finished_at) - np.array([a for a, _, _ in workload])
    return {
        "throughput_tok_s": sum(map(len, outputs)) / elapsed,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
    }, outputs


def check_parity(params, cfg, workload, outputs, *, max_context: int) -> None:
    """Continuous outputs must be bit-identical to sequential
    ``DecodeEngine.generate`` with the cache pinned to max_context."""
    oracle = DecodeEngine(params, cfg, ServeConfig())
    for (_, prompt, t_new), got in zip(workload, outputs):
        want, _ = oracle.generate(prompt[None], max_new_tokens=t_new,
                                  cache_len=max_context)
        assert np.array_equal(got, want[0]), (
            f"parity broke: got {got.tolist()} want {want[0].tolist()}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--mix", default=None,
                    help="workload mix name (default: smoke/mixed by mode)")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="burst mix + parity check + speedup assertion (CI)")
    args = ap.parse_args(argv)

    mix = SERVE_MIXES[args.mix or ("smoke" if args.smoke else "mixed")]
    cfg = get_config(args.arch).reduced()
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    workload = build_workload(mix)
    max_context = mix.max_context()
    if max_context % args.page_size:
        max_context += args.page_size - max_context % args.page_size

    print(f"serve load: {cfg.name} ({cfg.family}) | mix={mix.name} "
          f"({mix.requests} reqs, {mix.arrival}) | capacity={args.capacity} "
          f"page={args.page_size} context={max_context}")

    cont, outputs = run_continuous(params, cfg, workload,
                                   capacity=args.capacity,
                                   page_size=args.page_size,
                                   max_context=max_context)
    static, _ = run_static(params, cfg, workload, capacity=args.capacity,
                           pad_to=tuple(sorted(mix.prompt_lens)))

    parity = False
    if args.smoke:
        check_parity(params, cfg, workload, outputs, max_context=max_context)
        parity = True
        print("parity: continuous == sequential generate (bit-identical)")

    doc = serve_entry(smoke=args.smoke, arch=cfg.name,
                      capacity=args.capacity, page_size=args.page_size,
                      max_context=max_context,
                      workload={"requests": mix.requests,
                                "arrival": mix.arrival,
                                "rate_rps": mix.rate_rps,
                                "prompt_lens": list(mix.prompt_lens),
                                "output_lens": list(mix.output_lens)},
                      continuous=cont, static=static, parity_checked=parity)
    problems = validate_serve(doc)
    assert not problems, f"BENCH_serve schema violations: {problems}"
    out_path = ROOT / "BENCH_serve.json"
    out_path.write_text(json.dumps(doc, indent=2) + "\n")

    print(f"continuous: {cont['throughput_tok_s']:8.1f} tok/s | "
          f"p50 {cont['p50_latency_s'] * 1e3:7.1f} ms | "
          f"p99 {cont['p99_latency_s'] * 1e3:7.1f} ms | "
          f"occupancy {cont['mean_occupancy']:.2f} | "
          f"steps {cont['steps']}")
    print(f"static:     {static['throughput_tok_s']:8.1f} tok/s | "
          f"p50 {static['p50_latency_s'] * 1e3:7.1f} ms | "
          f"p99 {static['p99_latency_s'] * 1e3:7.1f} ms")
    print(f"speedup: {doc['speedup']:.2f}x | wrote {out_path.name}")

    if args.smoke:
        assert doc["speedup"] >= 1.5, (
            f"continuous batching speedup {doc['speedup']:.2f}x < 1.5x floor")


if __name__ == "__main__":
    main()
