"""Perf-iteration harness (EXPERIMENTS.md §Perf).

Lowers one (arch x shape) cell under a named variant, prints the roofline
terms, and appends the record to results/perf/<cell>.jsonl — the
hypothesis -> change -> measure log lives in EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.perf_iterate \
        --arch granite-8b --shape train_4k --variant baseline

Variants are ModelConfig overrides (plus env toggles) registered below; add
new ones as the hillclimb progresses.

STT cells (ISSUE 2: benchmarks ride the front door): an ``--stt
<algebra>`` cell generates (algebra x named STT) through
``repro.generate`` instead, timing cold generation, cached re-generation
and kernel wall time, and appends the record the same way:

    PYTHONPATH=src python -m benchmarks.perf_iterate \
        --stt gemm --dataflow output_stationary

Tune cells (ISSUE 6: measured autotuning): ``--tune`` runs the
timing-driven tuner over registry cells — all six algebras, or the
two-cell ``--smoke`` subset CI runs — and writes the machine-readable
``BENCH_tune.json`` at the repo root (modeled vs measured cycles, tuned
vs untuned wall clock, the fitted calibration).  Exits nonzero when any
tuned pick is slower than its untuned baseline or the emitted document
fails the schema validator:

    PYTHONPATH=src python -m benchmarks.perf_iterate --tune [--smoke]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json


VARIANTS = {
    "baseline": {},
    "no_sp": {"sequence_parallel": False},
    "no_remat": {"remat": False},
    "no_sp_no_remat": {"sequence_parallel": False, "remat": False},
    # chunked attention at 4k (smaller transient scores)
    "chunked_attn": {"_attn_full_max": 2048},
    # bigger kv chunks for the 32k paths
    "attn_bkv_4096": {"_attn_bkv": 4096},
    # beyond-paper: STT-scheduled explicit shard_map collectives
    "explicit": {"explicit_collectives": True},
    "explicit_chunked": {"explicit_collectives": True,
                         "_attn_full_max": 2048},
    "explicit_no_remat": {"explicit_collectives": True, "remat": False},
}


def run_variant(arch: str, shape: str, variant: str, multi: bool = False):
    from repro.launch import dryrun
    from repro.models import attention

    over = dict(VARIANTS[variant])
    full_max = over.pop("_attn_full_max", None)
    bkv = over.pop("_attn_bkv", None)
    old_max = attention.FULL_SCORES_MAX_LEN
    if full_max is not None:
        attention.FULL_SCORES_MAX_LEN = full_max
    if bkv is not None:
        os.environ["REPRO_ATTN_BKV"] = str(bkv)
    try:
        import repro.launch.specs as specs_mod
        orig = specs_mod.input_specs

        def patched(a, s, m, overrides=None):
            return orig(a, s, m, overrides={**(overrides or {}), **over})

        specs_mod.input_specs = patched
        try:
            rec = dryrun.run_cell(arch, shape, multi)
        finally:
            specs_mod.input_specs = orig
    finally:
        attention.FULL_SCORES_MAX_LEN = old_max
        os.environ.pop("REPRO_ATTN_BKV", None)
    rec["variant"] = variant
    return rec


def run_stt_cell(name: str, kind: str, interpret: bool = True) -> dict:
    """One (algebra x named STT) cell through the front door."""
    import time

    import repro
    from repro import compile as rcompile
    from repro.core import algebra

    alg = algebra.get_algebra(name)

    rcompile.cache_clear()
    t0 = time.perf_counter()
    acc = repro.generate(alg, kind, interpret=interpret, validate=False)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    repro.generate(alg, kind, interpret=interpret, validate=False)
    t_cached = time.perf_counter() - t0

    # kernel wall time through the shared measurement harness (the same
    # warmup + median-of-k loop the autotuner persists numbers from)
    from repro.tune.measure import measure
    operands = alg.random_operands(0)
    meas = measure(acc, operands, warmup=1, repeats=3)
    t_first = meas.warmup_s
    t_steady = meas.median_s

    r = acc.cost_report()
    return {
        "cell": f"stt_{name}_{kind}",
        "algebra": name, "dataflow": acc.dataflow.name,
        "template": acc.template, "blocks": list(acc.kernel.blocks),
        "lower_cold_s": t_cold, "lower_cached_s": t_cached,
        "exec_first_s": t_first, "exec_steady_s": t_steady,
        "cache": rcompile.cache_info(),
        "model_cycles": r.cycles, "model_perf": r.normalized_perf,
    }


#: the two-cell CI smoke subset: the canonical dense algebra plus the
#: batch-folded one the tuner's headline speedup is measured on
SMOKE_TUNE_CELLS = ("gemm", "batched_gemv")
#: measured speedup the tuned batched_gemv pick must reach over the
#: untuned analytical pick (ISSUE 6 acceptance)
GEMV_MIN_SPEEDUP = 1.5


def run_tune_cells(smoke: bool, out_path: str = "BENCH_tune.json") -> dict:
    """Tune registry cells, emit BENCH_tune.json, return the document.

    Raises SystemExit (nonzero) when a tuned pick is slower than its
    untuned baseline, the batched_gemv speedup misses the floor (smoke),
    or the document fails its own schema validator.
    """
    import jax.numpy as jnp  # noqa: F401  (forces the backend up early)

    from repro import tune as rtune
    from repro.core.algebra import PAPER_ALGEBRAS, get_algebra
    from repro.core.tiling import ArrayConfig
    from repro.tune import report as rreport

    names = SMOKE_TUNE_CELLS if smoke else tuple(sorted(PAPER_ALGEBRAS))
    cfg = ArrayConfig()
    cells = []
    for name in names:
        alg = get_algebra(name)
        res = rtune.tune(alg, search=2, cfg=cfg, interpret=True)
        kernel = res.kernel
        rep = kernel.cost_report()
        cal = rtune.load_calibration()
        scale = cal.scale_for(kernel.template, alg.name)
        cells.append(rreport.cell_entry(
            cell=f"tune_{name}", algebra=name,
            dataflow=res.dataflow.name, template=kernel.template,
            variant={"blocks": res.variant.blocks,
                     "grid_order": res.variant.grid_order,
                     "accum": res.variant.accum},
            model_cycles=rep.cycles,
            calibrated_cycles=rep.cycles * scale,
            measured_cycles=(res.tuned_s or 0.0) * cfg.freq_mhz * 1e6,
            untuned_s=res.untuned_s or 0.0, tuned_s=res.tuned_s or 0.0,
            tune_cache_hit=res.cache_hit))
        c = cells[-1]
        print(f"tune/{name}: {c['dataflow']} {c['variant']['blocks']} "
              f"go={c['variant']['grid_order']} accum={c['variant']['accum']}"
              f" untuned={c['untuned_s'] * 1e3:.3f}ms "
              f"tuned={c['tuned_s'] * 1e3:.3f}ms "
              f"speedup={c['speedup']:.2f}x"
              + (" (cache hit)" if c["tune_cache_hit"] else ""))
        print(f"  cycles: model={c['model_cycles']:.0f} "
              f"calibrated={c['calibrated_cycles']:.0f} "
              f"measured={c['measured_cycles']:.0f}")

    cal = rtune.load_calibration()
    doc = {
        "version": rreport.BENCH_SCHEMA_VERSION,
        "smoke": bool(smoke),
        "interpret": True,
        "cells": cells,
        "calibration": {
            "per_template": dict(cal.per_template),
            "anchors": [{"template": t, "algebra": a, "scale": s}
                        for (t, a), s in sorted(cal.anchors.items())],
        },
    }

    errors = rreport.validate_bench(doc)
    if errors:
        raise SystemExit("BENCH_tune.json failed schema validation:\n  "
                         + "\n  ".join(errors))
    slow = [c["cell"] for c in cells if c["speedup"] < 1.0]
    if slow:
        raise SystemExit(f"tuned pick slower than untuned for: {slow}")
    for c in cells:
        if (c["algebra"] == "batched_gemv"
                and c["speedup"] < GEMV_MIN_SPEEDUP):
            raise SystemExit(
                f"tuned batched_gemv speedup {c['speedup']:.2f}x below "
                f"the {GEMV_MIN_SPEEDUP}x floor")
        if c["measured_cycles"] > 0 and not (
                0.5 <= c["calibrated_cycles"] / c["measured_cycles"] <= 2.0):
            raise SystemExit(
                f"{c['cell']}: calibrated prediction "
                f"{c['calibrated_cycles']:.0f} not within 2x of measured "
                f"{c['measured_cycles']:.0f}")

    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"\nwrote -> {out_path} ({len(cells)} cells, all tuned picks "
          f">= untuned)")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="results/perf")
    ap.add_argument("--stt", metavar="ALGEBRA",
                    help="run an (algebra x STT) compile-pipeline cell "
                         "instead of an (arch x shape) model cell")
    ap.add_argument("--dataflow", default="output_stationary",
                    help="named STT for --stt cells")
    ap.add_argument("--tune", action="store_true",
                    help="run measured-autotuning cells and emit "
                         "BENCH_tune.json at the repo root")
    ap.add_argument("--smoke", action="store_true",
                    help="with --tune: the two-cell CI subset")
    args = ap.parse_args()

    if args.tune:
        run_tune_cells(args.smoke)
        return

    if args.stt:
        from repro.core.algebra import PAPER_ALGEBRAS
        if args.stt not in PAPER_ALGEBRAS:
            ap.error(f"unknown algebra {args.stt!r}; "
                     f"choose from {sorted(PAPER_ALGEBRAS)}")
        rec = run_stt_cell(args.stt, args.dataflow)
        print(f"\nstt/{args.stt} [{args.dataflow}]")
        print(f"  template      {rec['template']} blocks={rec['blocks']}")
        print(f"  lower cold    {rec['lower_cold_s'] * 1e3:.1f} ms")
        print(f"  lower cached  {rec['lower_cached_s'] * 1e6:.0f} us")
        print(f"  exec first    {rec['exec_first_s'] * 1e3:.1f} ms")
        print(f"  exec steady   {rec['exec_steady_s'] * 1e3:.1f} ms")
        print(f"  model perf    {rec['model_perf']:.3f}")
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, f"{rec['cell']}.jsonl")
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        print(f"appended -> {path}")
        return

    if not (args.arch and args.shape):
        ap.error("--arch and --shape are required unless --stt is given")
    rec = run_variant(args.arch, args.shape, args.variant, args.multi)
    r = rec["roofline"]
    print(f"\n{args.arch}/{args.shape} [{args.variant}]")
    print(f"  compute_s    {r['compute_s']:.4f}")
    print(f"  memory_s     {r['memory_s']:.4f}")
    print(f"  collective_s {r['collective_s']:.4f}")
    print(f"  bottleneck   {r['bottleneck']}")
    print(f"  MFU          {r['roofline_fraction']:.4f}")
    print(f"  useful ratio {r['useful_flops_ratio']:.3f}")
    print(f"  temp GiB     {rec['memory']['temp_bytes'] / 2**30:.1f} "
          f"(fits={rec['memory']['fits_hbm']})")
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"{args.arch}_{args.shape}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"appended -> {path}")


if __name__ == "__main__":
    main()
