"""Roofline report: aggregate the dry-run JSONs into the EXPERIMENTS table.

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and prints the
per-cell three-term roofline, bottleneck, useful-flops ratio, and HBM fit —
single-pod for the table (per the assignment), multi-pod rows on request.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if os.path.basename(path).startswith("_"):
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh="single"):
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"{'arch':22s} {'shape':12s} {'kind':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'bottleneck':>11s} "
           f"{'MFU':>6s} {'useful':>7s} {'fits':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        t = r["roofline"]
        print(f"{r['arch']:22s} {r['shape']:12s} {r['kind']:8s} "
              f"{t['compute_s']:10.4f} {t['memory_s']:10.4f} "
              f"{t['collective_s']:10.4f} {t['bottleneck']:>11s} "
              f"{t['roofline_fraction']:6.3f} {t['useful_flops_ratio']:7.3f} "
              f"{str(r['memory']['fits_hbm']):>5s}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print(f"no dry-run records in {args.dir} — run "
              "`python -m repro.launch.dryrun` first")
        return
    rows = table(recs, args.mesh)
    bottlenecks = {}
    for r in rows:
        bottlenecks.setdefault(r["roofline"]["bottleneck"], []).append(r)
    print(f"\n{len(rows)} cells ({args.mesh}-pod); bottleneck distribution: "
          + ", ".join(f"{k}={len(v)}" for k, v in sorted(bottlenecks.items())))
    skips = os.path.join(args.dir, "_skips.json")
    if os.path.exists(skips):
        with open(skips) as f:
            s = json.load(f)
        print(f"{len(s)} cells skipped by assignment rule (full-attention "
              "long_500k): " + ", ".join(x["arch"] for x in s))


if __name__ == "__main__":
    main()
