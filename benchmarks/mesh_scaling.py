"""Mesh scaling: per-device executed MACs and comm bytes vs device count.

For dense / block-sparse / batched GEMM-class algebras under their own
classification, sweeps mesh shapes (1 -> 8 devices) and reports, per
point,

  * the solved partition (strategy, batch axis, compressed sides),
  * per-device executed MACs (the batch-shard / spatial speedup),
  * per-device stored operand bytes and collective bytes received — the
    compressed path vs the masked-dense baseline, the batch-sharded path
    vs the replicating baseline,

everything priced from the same ``PartitionSolution`` the interpreter
executes (``repro.core.plan.solve_partition``).

Asserts the acceptance properties: per-device MACs and operand bytes
shrink monotonically with device count (~1/P for the sharded dims), the
compressed payload is the density-scaled fraction of the dense shard,
and — in ``--smoke`` on 8 fake CPU devices — every swept configuration
executes with parity against the loop-nest oracle.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.mesh_scaling [--smoke]

(The CI multidevice job runs ``--smoke`` on every push.)
"""
from __future__ import annotations

import argparse
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

import repro
from repro.core import algebra
from repro.core.algebra import Sparsity
from repro.compile.lowering import lower_form
from repro.core.plan import comm_plan_for, solve_partition
from repro.core import stt

MESH_SHAPES = ((1, 1), (1, 2), (2, 2), (2, 4))

#: model-sweep bounds (solver accounting only — nothing executes here)
MODEL_BOUNDS = dict(m=256, n=256, k=256)
#: executed bounds for --smoke parity (loop-nest oracle stays fast)
SMOKE_BOUNDS = dict(m=16, n=16, k=16)
SPARSE_DENSITY = 0.25
SPARSE_BLOCK = 4


def cases(bounds):
    """(label, algebra, dataflow name) for dense / sparse / batched."""
    m, n, k = bounds["m"], bounds["n"], bounds["k"]
    g = algebra.gemm(m, n, k)
    sp = Sparsity.random((m, k), (SPARSE_BLOCK, SPARSE_BLOCK),
                         SPARSE_DENSITY, seed=7)
    bg = algebra.get_algebra("batched_gemv", m=m // 2, k=k, n=n)
    return (("dense-gemm", g, "output_stationary"),
            ("sparse-gemm", g.with_sparsity(A=sp), "output_stationary"),
            ("batched-gemv", bg, "output_stationary"))


def solve(alg, dfname, shape, **kw):
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(dfname))
    comm = comm_plan_for(df, densities={name: alg.density_of(name)
                                        for name, _ in alg.sparsity})
    return solve_partition(comm, lower_form(alg), shape=shape, **kw)


def rows_for(label, alg, dfname):
    form = lower_form(alg)
    rows = []
    for shape in MESH_SHAPES:
        sol = solve(alg, dfname, shape)
        devices = shape[0] * shape[1]
        stored = sol.per_device_bytes(form)
        moved = sol.comm_bytes(form)
        rows.append({
            "label": label, "shape": shape, "devices": devices,
            "strategy": sol.strategy, "batch_axis": sol.batch_axis,
            "compressed": sol.lhs.compressed or sol.rhs.compressed,
            "per_dev_macs": sol.per_device_macs(form),
            "operand_bytes": stored["lhs"] + stored["rhs"],
            "out_bytes": stored["out"],
            "comm_bytes": sum(moved.values()),
            "solution": sol,
        })
    return rows


def print_rows(rows):
    print(f"\n{rows[0]['label']}")
    print(f"{'mesh':>7s} {'devs':>4s} {'strategy':<17s} {'batch':>5s} "
          f"{'bsr':>3s} {'MACs/dev':>10s} {'opB/dev':>9s} {'commB/dev':>9s}")
    for r in rows:
        print(f"{str(r['shape']):>7s} {r['devices']:>4d} "
              f"{r['strategy']:<17s} {str(r['batch_axis'] or '-'):>5s} "
              f"{'y' if r['compressed'] else 'n':>3s} "
              f"{r['per_dev_macs']:>10d} {r['operand_bytes']:>9.0f} "
              f"{r['comm_bytes']:>9.0f}")


def assert_scaling(rows):
    """Per-device MACs and operand bytes shrink monotonically with device
    count; the 8-device point does ~1/P of the single-device work."""
    macs = [r["per_dev_macs"] for r in rows]
    opb = [r["operand_bytes"] for r in rows]
    assert all(a >= b for a, b in zip(macs, macs[1:])), macs
    assert all(a >= b for a, b in zip(opb, opb[1:])), opb
    # ~1/P on the executed work (padding on skewed dims allows slack 2x)
    p = rows[-1]["devices"]
    assert macs[-1] <= 2 * macs[0] / p, (macs, p)


def assert_baselines(label, alg, dfname, form):
    """The sharded/compressed footprints beat the replicating baselines
    the solver can still produce on request."""
    shape = MESH_SHAPES[-1]
    sol = solve(alg, dfname, shape)
    if form.batch:
        repl = solve(alg, dfname, shape, shard_batch=False)
        f_b = sol.sizes[sol.batch_axis]
        a = sol.per_device_bytes(form)
        b = repl.per_device_bytes(form)
        for side in ("lhs", "rhs", "out"):
            assert a[side] <= b[side] / f_b + 1e-9, (label, side)
        print(f"  {label}: batch shard stores 1/{f_b} of the replicating "
              f"baseline per device")
    if form.sparse is not None:
        dense = solve(alg, dfname, shape, compressed=False)
        side = form.sparse.side
        a = sol.per_device_bytes(form)[side]
        b = dense.per_device_bytes(form)[side]
        assert a < b, (label, a, b)
        print(f"  {label}: compressed payload {a:.0f}B/dev vs masked "
              f"dense {b:.0f}B/dev (density {form.sparse.density:.2f})")


def smoke_parity(label, alg, dfname):
    """Execute every swept mesh shape on fake devices: parity against
    the loop-nest oracle, compressed/batch-sharded paths included; wall
    time per shape via the shared harness (``repro.tune.measure``)."""
    import jax
    from jax.sharding import Mesh

    from repro.tune.measure import measure

    operands = alg.random_operands(seed=3)
    want = alg.reference(operands)
    acc = repro.generate(alg, dfname, interpret=True, validate=False)
    times = []
    for shape in MESH_SHAPES:
        n_dev = shape[0] * shape[1]
        if n_dev > len(jax.devices()):
            continue
        mesh = Mesh(np.asarray(jax.devices()[:n_dev]).reshape(shape),
                    ("x", "y"))
        sh = acc.sharded(mesh)
        got = np.asarray(sh(operands)).round().astype(np.int64)
        np.testing.assert_array_equal(got, want, err_msg=f"{label} {shape}")
        ms = measure(sh, operands, warmup=1, repeats=3).median_s * 1e3
        times.append(f"{shape}={ms:.1f}ms")
    print(f"  {label}: parity on {len(MESH_SHAPES)} mesh shapes "
          f"({' '.join(times)})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small bounds + executed parity (CI)")
    args = ap.parse_args()
    bounds = SMOKE_BOUNDS if args.smoke else MODEL_BOUNDS

    for label, alg, dfname in cases(bounds):
        rows = rows_for(label, alg, dfname)
        print_rows(rows)
        assert_scaling(rows)
        assert_baselines(label, alg, dfname, lower_form(alg))
    if args.smoke:
        print("\nexecuted parity (fake devices):")
        for label, alg, dfname in cases(SMOKE_BOUNDS):
            smoke_parity(label, alg, dfname)
    print("\nMESH SCALING OK: per-device MACs and operand bytes shrink "
          "with device count")


if __name__ == "__main__":
    main()
