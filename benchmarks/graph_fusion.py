"""Graph fusion benchmark: merged-megakernel vs sequential dispatch.

    PYTHONPATH=src python -m benchmarks.graph_fusion [--smoke]

Gates (CI tier-1 smoke, PR 8 + ISSUE 9 + ISSUE 10):
  * the fused plan's HBM-bytes proxy beats the unfused pricing of the
    same chain by >= 1.3x (``GraphCostReport.hbm_ratio``),
  * execution is bit-identical to the explicit-schedule oracle
    (``repro.models.chains``) AND to sequential per-node dispatch
    (``build(merge=False)``),
  * the merged megakernel's *measured* wall clock (``tune/measure.py``
    harness: warmup + median-of-repeats around ``block_until_ready``)
    beats sequential dispatch by >= 1.2x,
  * the whole dense-family layer graph (``graph/from_model.py``) merges
    into one megakernel spanning attention and the MLP (residual tap
    exported), stays bit-identical to
    ``models.transformer.dense_layer_forward`` and to sequential
    dispatch, and its measured layer-forward speedup clears >= 1.2x.

``--smoke`` runs the small shapes only; the full run adds larger ones.
Emits ``BENCH_graph.json`` (schema v3: ``measured_speedup`` per chain
plus the ``model_layer`` entry) at the repo root.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

ROOT = pathlib.Path(__file__).parent.parent

#: minimum fused-vs-unfused HBM traffic ratio the chain must clear
HBM_RATIO_FLOOR = 1.3
#: minimum measured merged-vs-sequential wall-clock speedup
MEASURED_SPEEDUP_FLOOR = 1.2
#: minimum measured whole-layer-forward speedup over sequential dispatch
MODEL_SPEEDUP_FLOOR = 1.2
#: calls per timed sample — amortizes timer granularity; the harness
#: still takes the median over ``repeats`` samples
CALLS_PER_SAMPLE = 10


def run_chain(lq, lkv, d, dv, f, *, repeats=7) -> dict:
    import repro
    from repro.graph import executor as graph_executor
    from repro.models import chains
    from repro.tune.measure import measure

    g = chains.attention_mlp_graph(lq=lq, lkv=lkv, d=d, dv=dv, f=f)
    acc = repro.generate(g)
    seq = graph_executor.build(g, interpret=True, merge=False)
    rep = acc.cost_report()
    ops = g.random_operands(1)
    got = np.asarray(acc(ops))
    got_seq = np.asarray(seq(ops))
    want = np.asarray(chains.attention_mlp_oracle(
        {k: v for k, v in ops.items()}))
    max_err = float(np.abs(got - want).max())

    def loop(fn):
        def run():
            out = None
            for _ in range(CALLS_PER_SAMPLE):
                out = fn(ops)
            return out
        return run

    t_merged = measure(loop(acc), warmup=1,
                       repeats=repeats).median_s / CALLS_PER_SAMPLE
    t_seq = measure(loop(seq), warmup=1,
                    repeats=repeats).median_s / CALLS_PER_SAMPLE
    return {
        "shape": {"lq": lq, "lkv": lkv, "d": d, "dv": dv, "f": f},
        "hbm_bytes": rep.hbm_bytes,
        "hbm_bytes_unfused": rep.hbm_bytes_unfused,
        "hbm_ratio": rep.hbm_ratio,
        "fused_edges": list(rep.fused_edges),
        "cycles": rep.cycles,
        "cycles_unfused": rep.cycles_unfused,
        "merged_groups": list(acc.group_kernels),
        "bit_parity": bool((got == want).all()),
        "bit_parity_sequential": bool((got == got_seq).all()),
        "max_err": max_err,
        "t_merged_s": t_merged,
        "t_sequential_s": t_seq,
        "measured_speedup": t_seq / t_merged,
    }


def run_model_layer(l, d, dv, f, *, repeats=7) -> dict:
    """One dense-family transformer layer as a fused graph vs sequential
    per-node dispatch, bit-compared against the model-side oracle."""
    import repro
    from repro.graph import executor as graph_executor
    from repro.graph import from_model
    from repro.tune.measure import measure

    g = from_model.transformer_layer_graph(l=l, d=d, dv=dv, f=f)
    acc = repro.generate(g)
    seq = graph_executor.build(g, interpret=True, merge=False)
    rep = acc.cost_report()
    ops = g.random_operands(1)
    got = np.asarray(acc(ops))
    got_seq = np.asarray(seq(ops))
    want = np.asarray(from_model.layer_oracle(ops))
    max_err = float(np.abs(got - want).max())

    def loop(fn):
        def run():
            out = None
            for _ in range(CALLS_PER_SAMPLE):
                out = fn(ops)
            return out
        return run

    t_merged = measure(loop(acc), warmup=1,
                       repeats=repeats).median_s / CALLS_PER_SAMPLE
    t_seq = measure(loop(seq), warmup=1,
                    repeats=repeats).median_s / CALLS_PER_SAMPLE
    return {
        "shape": {"l": l, "d": d, "dv": dv, "f": f},
        "hbm_bytes": rep.hbm_bytes,
        "hbm_bytes_unfused": rep.hbm_bytes_unfused,
        "hbm_ratio": rep.hbm_ratio,
        "fused_edges": list(rep.fused_edges),
        "tapped_edges": list(rep.tapped_edges),
        "tap_hbm_bytes": rep.tap_hbm_bytes,
        "merged_groups": list(acc.group_kernels),
        "bit_parity": bool((got == want).all()),
        "bit_parity_sequential": bool((got == got_seq).all()),
        "max_err": max_err,
        "t_merged_s": t_merged,
        "t_sequential_s": t_seq,
        "measured_speedup": t_seq / t_merged,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small chain only")
    args = ap.parse_args(argv)

    shapes = [(32, 32, 32, 32, 64)]
    if not args.smoke:
        shapes.append((64, 64, 64, 64, 128))

    rows = []
    for lq, lkv, d, dv, f in shapes:
        row = run_chain(lq, lkv, d, dv, f)
        rows.append(row)
        print(f"chain lq={lq} lkv={lkv} d={d} dv={dv} f={f}: "
              f"hbm {row['hbm_bytes']:.0f}B vs unfused "
              f"{row['hbm_bytes_unfused']:.0f}B "
              f"(ratio {row['hbm_ratio']:.2f}), "
              f"merged={row['merged_groups']}, "
              f"measured {row['t_merged_s'] * 1e3:.2f}ms vs sequential "
              f"{row['t_sequential_s'] * 1e3:.2f}ms "
              f"({row['measured_speedup']:.2f}x), "
              f"bit_parity={row['bit_parity']} "
              f"(max_err={row['max_err']:.1e})")

    layer_shape = (32, 32, 32, 64) if args.smoke else (64, 64, 64, 128)
    model = run_model_layer(*layer_shape)
    print(f"model layer l={layer_shape[0]} d={layer_shape[1]} "
          f"dv={layer_shape[2]} f={layer_shape[3]}: "
          f"merged={model['merged_groups']}, "
          f"taps={model['tapped_edges']}, "
          f"measured {model['t_merged_s'] * 1e3:.2f}ms vs sequential "
          f"{model['t_sequential_s'] * 1e3:.2f}ms "
          f"({model['measured_speedup']:.2f}x), "
          f"bit_parity={model['bit_parity']} "
          f"(max_err={model['max_err']:.1e})")

    doc = {"version": 3, "floor": HBM_RATIO_FLOOR,
           "measured_floor": MEASURED_SPEEDUP_FLOOR,
           "model_floor": MODEL_SPEEDUP_FLOOR,
           "chains": rows, "model_layer": model}
    (ROOT / "BENCH_graph.json").write_text(json.dumps(doc, indent=2))
    print(f"wrote {ROOT / 'BENCH_graph.json'}")

    problems = []
    for row in rows:
        if not row["bit_parity"]:
            problems.append(f"{row['shape']}: not bit-identical to the "
                            f"explicit-schedule oracle "
                            f"(max err {row['max_err']:.3e})")
        if not row["bit_parity_sequential"]:
            problems.append(f"{row['shape']}: merged kernel not "
                            f"bit-identical to sequential dispatch")
        if not row["merged_groups"]:
            problems.append(f"{row['shape']}: no merged group lowered")
        if row["hbm_ratio"] < HBM_RATIO_FLOOR:
            problems.append(f"{row['shape']}: hbm_ratio "
                            f"{row['hbm_ratio']:.2f} < floor "
                            f"{HBM_RATIO_FLOOR}")
        if row["measured_speedup"] < MEASURED_SPEEDUP_FLOOR:
            problems.append(f"{row['shape']}: measured_speedup "
                            f"{row['measured_speedup']:.2f} < floor "
                            f"{MEASURED_SPEEDUP_FLOOR}")
    if not model["bit_parity"]:
        problems.append(f"model_layer {model['shape']}: not bit-identical"
                        f" to models.transformer.dense_layer_forward "
                        f"(max err {model['max_err']:.3e})")
    if not model["bit_parity_sequential"]:
        problems.append(f"model_layer {model['shape']}: merged kernel "
                        f"not bit-identical to sequential dispatch")
    if not model["merged_groups"]:
        problems.append(f"model_layer {model['shape']}: no merged group "
                        f"lowered (whole-layer fusion regressed)")
    if not model["tapped_edges"]:
        problems.append(f"model_layer {model['shape']}: no residual tap "
                        f"exported")
    if model["measured_speedup"] < MODEL_SPEEDUP_FLOOR:
        problems.append(f"model_layer {model['shape']}: measured_speedup "
                        f"{model['measured_speedup']:.2f} < floor "
                        f"{MODEL_SPEEDUP_FLOOR}")
    if problems:
        raise SystemExit("graph_fusion gates failed:\n  "
                         + "\n  ".join(problems))
    print("graph_fusion gates passed "
          f"(hbm_ratio floor {HBM_RATIO_FLOOR}, measured_speedup floor "
          f"{MEASURED_SPEEDUP_FLOOR}, model_layer floor "
          f"{MODEL_SPEEDUP_FLOOR}, bit parity)")


if __name__ == "__main__":
    main()
