"""Graph fusion benchmark: fused vs unfused attention+MLP chain.

    PYTHONPATH=src python -m benchmarks.graph_fusion [--smoke]

Gates (CI tier-1 smoke, PR 8):
  * the fused plan's HBM-bytes proxy beats the unfused pricing of the
    same chain by >= 1.3x (``GraphCostReport.hbm_ratio``),
  * execution is bit-identical to the explicit-schedule oracle
    (``repro.models.chains`` — explicit-TP math at model-parallel 1).

``--smoke`` runs the small chain only; the full run adds a larger chain
and wall-clock timings of the generated executable vs the oracle.
Emits ``BENCH_graph.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

ROOT = pathlib.Path(__file__).parent.parent

#: minimum fused-vs-unfused HBM traffic ratio the chain must clear
HBM_RATIO_FLOOR = 1.3


def run_chain(lq, lkv, d, dv, f, *, time_it=False) -> dict:
    import repro
    from repro.models import chains

    g = chains.attention_mlp_graph(lq=lq, lkv=lkv, d=d, dv=dv, f=f)
    acc = repro.generate(g)
    rep = acc.cost_report()
    ops = g.random_operands(1)
    got = np.asarray(acc(ops))
    want = np.asarray(chains.attention_mlp_oracle(
        {k: v for k, v in ops.items()}))
    max_err = float(np.abs(got - want).max())
    row = {
        "shape": {"lq": lq, "lkv": lkv, "d": d, "dv": dv, "f": f},
        "hbm_bytes": rep.hbm_bytes,
        "hbm_bytes_unfused": rep.hbm_bytes_unfused,
        "hbm_ratio": rep.hbm_ratio,
        "fused_edges": list(rep.fused_edges),
        "cycles": rep.cycles,
        "cycles_unfused": rep.cycles_unfused,
        "bit_parity": bool((got == want).all()),
        "max_err": max_err,
    }
    if time_it:
        for fn, key in ((lambda: acc(ops), "t_fused_s"),
                        (lambda: chains.attention_mlp_oracle(
                            {k: v for k, v in ops.items()}), "t_oracle_s")):
            fn()                             # warm
            t0 = time.perf_counter()
            np.asarray(fn())
            row[key] = time.perf_counter() - t0
    return row


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small chain only, no wall-clock timing")
    args = ap.parse_args(argv)

    shapes = [(32, 32, 32, 32, 64)]
    if not args.smoke:
        shapes.append((64, 64, 64, 64, 128))

    rows = []
    for lq, lkv, d, dv, f in shapes:
        row = run_chain(lq, lkv, d, dv, f, time_it=not args.smoke)
        rows.append(row)
        print(f"chain lq={lq} lkv={lkv} d={d} dv={dv} f={f}: "
              f"hbm {row['hbm_bytes']:.0f}B vs unfused "
              f"{row['hbm_bytes_unfused']:.0f}B "
              f"(ratio {row['hbm_ratio']:.2f}), "
              f"fused_edges={len(row['fused_edges'])}, "
              f"bit_parity={row['bit_parity']} "
              f"(max_err={row['max_err']:.1e})")

    doc = {"version": 1, "floor": HBM_RATIO_FLOOR, "chains": rows}
    (ROOT / "BENCH_graph.json").write_text(json.dumps(doc, indent=2))
    print(f"wrote {ROOT / 'BENCH_graph.json'}")

    problems = []
    for row in rows:
        if not row["bit_parity"]:
            problems.append(f"{row['shape']}: not bit-identical to the "
                            f"explicit-schedule oracle "
                            f"(max err {row['max_err']:.3e})")
        if row["hbm_ratio"] < HBM_RATIO_FLOOR:
            problems.append(f"{row['shape']}: hbm_ratio "
                            f"{row['hbm_ratio']:.2f} < floor "
                            f"{HBM_RATIO_FLOOR}")
    if problems:
        raise SystemExit("graph_fusion gates failed:\n  "
                         + "\n  ".join(problems))
    print("graph_fusion gates passed "
          f"(hbm_ratio floor {HBM_RATIO_FLOOR}, bit parity)")


if __name__ == "__main__":
    main()
