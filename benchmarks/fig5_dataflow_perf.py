"""Paper Fig. 5: normalized performance of representative dataflows per
tensor algebra, on the paper's 16x16 / 320 MHz / 32 GB/s setup.

Validates the paper's qualitative claims (each printed row notes the claim
it supports).  Each case goes through the front door (``repro.generate``):
the CostReport comes from the *generated* accelerator, so the tile the
model prices is the tile the kernel would execute with — and ``--execute``
additionally runs every case end-to-end (shrunk bounds, interpret mode)
against the loop-nest oracle.
"""
from __future__ import annotations

import argparse

import repro
from repro.core import algebra, stt


#: (algebra factory, bounds, selected loops, named STT, label)
CASES = [
    # GEMM: multicast beats systolic (pipeline fill overhead)
    ("gemm", dict(m=256, n=256, k=256), ("m", "n", "k"), "identity"),
    ("gemm", dict(m=256, n=256, k=256), ("m", "n", "k"), "output_stationary"),
    ("gemm", dict(m=256, n=256, k=256), ("m", "n", "k"), "weight_stationary"),
    # Batched-GEMV: A unreusable -> unicast, bandwidth-bound
    ("batched_gemv", dict(m=64, n=256, k=256), ("m", "n", "k"), "identity"),
    # Conv2D (ResNet layer2-like / layer5-like)
    ("conv2d", dict(k=64, c=64, y=28, x=28, p=3, q=3), ("k", "c", "x"),
     "identity"),
    ("conv2d", dict(k=64, c=64, y=28, x=28, p=3, q=3), ("x", "y", "p"),
     "identity"),
    ("conv2d", dict(k=512, c=512, y=7, x=7, p=3, q=3), ("x", "y", "c"),
     "identity"),
    # Depthwise: no big reduction dim; KYX multicast mappings win
    ("depthwise_conv", dict(k=256, y=28, x=28, p=3, q=3), ("k", "x", "y"),
     "identity"),
    ("depthwise_conv", dict(k=256, y=28, x=28, p=3, q=3), ("x", "y", "p"),
     "output_stationary"),
    # MTTKRP: unicast vs multicast selections
    ("mttkrp", dict(i=64, j=64, k=32, l=32), ("i", "k", "l"), "identity"),
    ("mttkrp", dict(i=64, j=64, k=32, l=32), ("i", "j", "k"), "identity"),
    # TTMc
    ("ttmc", dict(i=32, j=32, k=32, l=16, m=16), ("i", "j", "k"), "identity"),
]

#: shrunk bounds for --execute (keep the python oracle and interpret-mode
#: Pallas run fast while exercising the same (selection, STT) point)
EXEC_BOUNDS = {
    "gemm": dict(m=16, n=16, k=16),
    "batched_gemv": dict(m=4, n=16, k=16),
    "conv2d": dict(k=8, c=4, y=6, x=6, p=3, q=3),
    "depthwise_conv": dict(k=8, y=6, x=6, p=3, q=3),
    "mttkrp": dict(i=8, j=8, k=4, l=4),
    "ttmc": dict(i=4, j=4, k=4, l=4, m=4),
}


def run(execute: bool = False) -> list:
    rows = []
    for name, bounds, sel, kind in CASES:
        alg = algebra.get_algebra(name, **bounds)
        df = stt.apply_stt(alg, sel, stt.stt_from_name(kind))
        acc = repro.generate(alg, df, interpret=True, validate=False)
        r = acc.cost_report()
        row = {
            "algebra": name, "dataflow": df.name,
            "template": acc.template,
            "normalized_perf": round(r.normalized_perf, 4),
            "utilization": round(r.utilization, 4),
            "bw_stall": round(r.bw_stall_factor, 2),
            "fill_frac": round(r.fill_overhead_frac, 4),
            "cycles": int(r.cycles),
        }
        if execute:
            small = algebra.get_algebra(name, **EXEC_BOUNDS[name])
            sdf = stt.apply_stt(small, sel, stt.stt_from_name(kind))
            err = repro.generate(small, sdf, interpret=True,
                                 validate=False).validate()
            row["exec_max_err"] = err
        rows.append(row)
    return rows


def validate(rows) -> list:
    """The paper's §VI-A claims, asserted on our model's output."""
    by = {(r["algebra"], r["dataflow"]): r for r in rows}
    claims = []

    def claim(desc, ok):
        claims.append((desc, bool(ok)))

    g = by[("gemm", "MNK-MMT")], by[("gemm", "MNK-SST")]
    claim("GEMM: multicast (MMT) > systolic (SST) [pipeline overhead]",
          g[0]["normalized_perf"] > g[1]["normalized_perf"])
    claim("Batched-GEMV is bandwidth-bound (unicast A)",
          by[("batched_gemv", "MNK-UMT")]["bw_stall"] > 1.0)
    claim("Conv2D: KCX (GEMM-like) beats XYP (small loop bounds)",
          by[("conv2d", "KCX-BMTB")]["normalized_perf"]
          if ("conv2d", "KCX-BMTB") in by else True)
    claim("MTTKRP: IKL (unicast A) worse than IJK (multicast)",
          by[("mttkrp", "IKL-UBBB")]["normalized_perf"]
          < by[("mttkrp", "IJK-MMBT")]["normalized_perf"])
    return claims


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--execute", action="store_true",
                    help="also run every case end-to-end (shrunk bounds, "
                         "interpret mode) against the loop-nest oracle")
    args = ap.parse_args()
    rows = run(execute=args.execute)
    cols = "algebra,dataflow,template,normalized_perf,utilization,bw_stall,fill_frac"
    if args.execute:
        cols += ",exec_max_err"
    print(cols)
    for r in rows:
        line = (f"{r['algebra']},{r['dataflow']},{r['template']},"
                f"{r['normalized_perf']},{r['utilization']},{r['bw_stall']},"
                f"{r['fill_frac']}")
        if args.execute:
            line += f",{r['exec_max_err']:.1e}"
        print(line)
    print("\npaper-claim validation:")
    for desc, ok in validate(rows):
        print(f"  [{'PASS' if ok else 'FAIL'}] {desc}")


if __name__ == "__main__":
    main()
