"""Paper Fig. 6: design-space exploration — area/power scatter for GEMM and
Depthwise-Conv2D on a 16x16 INT16 array.

The paper reports 148 GEMM points and 33 depthwise points, a ~1.8x energy
spread vs ~1.16x area spread, MMT/MMS-style dataflows costing the most
energy, reduction trees being cheap, and stationary tensors costing area.
Our enumeration universe is stated in core/dse.py; this benchmark prints the
sweep summary + the same qualitative checks.

The enumeration now runs on the DSE fast path (per-selection nullspace
caching, duplicate-basis short-circuiting — ISSUE 1) and the benchmark
times it; ``--baseline`` additionally times the original per-T pipeline
for an A/B speedup print.  The best pareto point is then carried through
the front door (``repro.generate``) to a validated accelerator — plan to
kernel, not just plan to scatter plot.
"""
from __future__ import annotations

import argparse
import time
from collections import Counter

import repro
from repro.core import algebra, dse, stt


def sweep_algebra(alg, selections=None):
    pairs = dse.sweep_with_dataflows(alg, selections=selections)
    reports = [r for r, _ in pairs]
    good = [r for r in reports if r.normalized_perf >= 0.5]
    return reports, good, {id(r): df for r, df in pairs}


def summarize(name, reports, good):
    powers = sorted(r.power_mw for r in good)
    areas = sorted(r.area_units for r in good)
    letters = Counter(r.dataflow_name.split("-")[1] for r in reports)
    print(f"\n== {name} ==")
    print(f"distinct dataflow points: {len(reports)} "
          f"(letter-combos: {len(letters)})")
    print(f"efficient points (perf>=0.5): {len(good)}")
    if good:
        print(f"power range: {powers[0]:.1f} .. {powers[-1]:.1f} mW "
              f"({powers[-1] / powers[0]:.2f}x; paper: 35..63 = 1.8x)")
        print(f"area  range: {areas[0]:.0f} .. {areas[-1]:.0f} units "
              f"({areas[-1] / areas[0]:.2f}x; paper: 1.16x)")
    front = dse.pareto_front(good)
    print(f"pareto front size: {len(front)}")
    for r in sorted(front, key=lambda r: r.cycles)[:5]:
        print(f"  {r.dataflow_name:12s} perf={r.normalized_perf:.3f} "
              f"area={r.area_units:.0f} power={r.power_mw:.1f}mW")
    return powers, areas, front


def lower_winner(alg, front, df_of):
    """Carry the best pareto point through the front door at shrunk
    bounds: the generated accelerator must actually run.  ``df_of`` maps
    report identity -> Dataflow (names are not unique across a sweep)."""
    if not front:
        return
    best = min(front, key=lambda r: r.cycles)
    df = df_of.get(id(best))
    if df is None:
        return
    small = alg.with_bounds(**{l: min(b, 8) for l, b in
                               zip(alg.loops, alg.bounds)})
    sdf = stt.apply_stt(small, df.selected, df.T)
    acc = repro.generate(small, sdf, interpret=True, validate=True)
    print(f"generated pareto winner {df.name}: template={acc.template} "
          f"blocks={acc.kernel.blocks} validated={acc.kernel.validated}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", action="store_true",
                    help="also time the original (per-T apply_stt) "
                         "enumeration for an A/B speedup print")
    args = ap.parse_args()

    g = algebra.gemm(256, 256, 256)
    t0 = time.perf_counter()
    reports, good, df_of = sweep_algebra(g, selections=[("m", "n", "k")])
    t_sweep = time.perf_counter() - t0
    powers, areas, front = summarize("GEMM (16x16, INT16)", reports, good)
    print(f"sweep time (fast enumeration + costing): {t_sweep:.2f}s")
    if args.baseline:
        t0 = time.perf_counter()
        ref = dse.enumerate_dataflows_reference(g, selections=[("m", "n", "k")])
        t_ref = time.perf_counter() - t0
        t0 = time.perf_counter()
        fast = dse.enumerate_dataflows(g, selections=[("m", "n", "k")])
        t_fast = time.perf_counter() - t0
        assert set(ref) == set(fast)
        print(f"enumeration A/B: seed path {t_ref:.2f}s, fast path "
              f"{t_fast:.2f}s -> {t_ref / max(t_fast, 1e-9):.1f}x")
    lower_winner(g, front, df_of)

    # paper claims
    mmt = [r for r in good if r.dataflow_name.endswith("MMT")]
    sst = [r for r in good if r.dataflow_name.endswith("SST")]
    checks = [
        ("energy spread > area spread",
         powers[-1] / powers[0] > areas[-1] / areas[0]),
        ("multicast-input dataflows (MMT) cost more power than systolic (SST)",
         mmt and sst and min(m.power_mw for m in mmt) >
         min(s.power_mw for s in sst)),
    ]

    dw = algebra.depthwise_conv(256, 28, 28, 3, 3)
    sels = [("k", "x", "y"), ("k", "p", "x"), ("x", "y", "p")]
    t0 = time.perf_counter()
    reports_dw, good_dw, df_of_dw = sweep_algebra(dw, selections=sels)
    t_dw = time.perf_counter() - t0
    _, _, front_dw = summarize("Depthwise-Conv2D (16x16, INT16)", reports_dw,
                               good_dw)
    print(f"sweep time: {t_dw:.2f}s")
    lower_winner(dw, front_dw or reports_dw, df_of_dw)

    print("\npaper-claim validation:")
    for desc, ok in checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {desc}")


if __name__ == "__main__":
    main()
