"""Paper Fig. 6: design-space exploration — area/power scatter for GEMM and
Depthwise-Conv2D on a 16x16 INT16 array.

The paper reports 148 GEMM points and 33 depthwise points, a ~1.8x energy
spread vs ~1.16x area spread, MMT/MMS-style dataflows costing the most
energy, reduction trees being cheap, and stationary tensors costing area.
Our enumeration universe is stated in core/dse.py; this benchmark prints the
sweep summary + the same qualitative checks.
"""
from __future__ import annotations

from collections import Counter

from repro.core import algebra, costmodel, dse


def sweep_algebra(alg, selections=None):
    reports = dse.sweep(alg, selections=selections)
    good = [r for r in reports if r.normalized_perf >= 0.5]
    return reports, good


def summarize(name, reports, good):
    powers = sorted(r.power_mw for r in good)
    areas = sorted(r.area_units for r in good)
    letters = Counter(r.dataflow_name.split("-")[1] for r in reports)
    print(f"\n== {name} ==")
    print(f"distinct dataflow points: {len(reports)} "
          f"(letter-combos: {len(letters)})")
    print(f"efficient points (perf>=0.5): {len(good)}")
    if good:
        print(f"power range: {powers[0]:.1f} .. {powers[-1]:.1f} mW "
              f"({powers[-1] / powers[0]:.2f}x; paper: 35..63 = 1.8x)")
        print(f"area  range: {areas[0]:.0f} .. {areas[-1]:.0f} units "
              f"({areas[-1] / areas[0]:.2f}x; paper: 1.16x)")
    front = dse.pareto_front(good)
    print(f"pareto front size: {len(front)}")
    for r in sorted(front, key=lambda r: r.cycles)[:5]:
        print(f"  {r.dataflow_name:12s} perf={r.normalized_perf:.3f} "
              f"area={r.area_units:.0f} power={r.power_mw:.1f}mW")
    return powers, areas


def main() -> None:
    g = algebra.gemm(256, 256, 256)
    reports, good = sweep_algebra(g, selections=[("m", "n", "k")])
    powers, areas = summarize("GEMM (16x16, INT16)", reports, good)

    # paper claims
    mmt = [r for r in good if r.dataflow_name.endswith("MMT")]
    sst = [r for r in good if r.dataflow_name.endswith("SST")]
    checks = [
        ("energy spread > area spread",
         powers[-1] / powers[0] > areas[-1] / areas[0]),
        ("multicast-input dataflows (MMT) cost more power than systolic (SST)",
         mmt and sst and min(m.power_mw for m in mmt) >
         min(s.power_mw for s in sst)),
    ]

    dw = algebra.depthwise_conv(256, 28, 28, 3, 3)
    sels = [("k", "x", "y"), ("k", "p", "x"), ("x", "y", "p")]
    reports_dw, good_dw = sweep_algebra(dw, selections=sels)
    summarize("Depthwise-Conv2D (16x16, INT16)", reports_dw, good_dw)

    print("\npaper-claim validation:")
    for desc, ok in checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {desc}")


if __name__ == "__main__":
    main()
