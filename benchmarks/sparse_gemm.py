"""Block-sparse GEMM benchmark: BSR kernel + compressed-format cost model.

Sweeps block density for a fixed GEMM and dataflow and reports, per
density,

  * cost-model cycles / runtime and operand + metadata traffic (the
    compressed-format terms the DSE ranks with),
  * the BSR grid size (nonzero blocks only) vs the dense grid,
  * end-to-end parity of the BSR Pallas kernel against the masked dense
    oracle (interpret mode, shrunk bounds — exact on integer operands),
    plus its measured wall time through the shared harness
    (``repro.tune.measure``: warmup + median-of-k).

Asserts the acceptance properties: model cycles and total traffic are
monotonically non-increasing as density decreases, and the executed
kernel matches the masked dense oracle at every density (with density
1.0 reproducing the dense path bit-exactly).

    PYTHONPATH=src python -m benchmarks.sparse_gemm [--smoke]

``--smoke`` runs one small size and two densities (< ~15 s; the CI
sparse step runs it on every push).
"""
from __future__ import annotations

import argparse

import numpy as np

import repro
from repro.core import stt
from repro.core.algebra import Sparsity, gemm
from repro.core.costmodel import PaperCycleModel
from repro.tune.measure import measure

#: validated execution bounds (loop-nest oracle + interpret-mode Pallas)
EXEC_SIZE, EXEC_BLOCK = 16, 4
#: cost-model sweep size (no execution at this size)
MODEL_SIZE, MODEL_BLOCK = 512, 32

DENSITIES = (1.0, 0.5, 0.25, 0.125)
SMOKE_DENSITIES = (1.0, 0.25)


def model_rows(densities, size=MODEL_SIZE, block=MODEL_BLOCK):
    g = gemm(size, size, size)
    df = stt.apply_stt(g, g.loops, stt.stt_from_name("output_stationary"))
    model = PaperCycleModel()
    rows = []
    for density in densities:
        sp = Sparsity.random((size, size), (block, block), density, seed=0)
        rep = model.evaluate(g.with_sparsity(A=sp), df)
        rows.append({
            "density": density,
            "nnz_blocks": sp.nnz_blocks,
            "cycles": rep.cycles,
            "runtime_ms": rep.runtime_ms,
            "traffic_mb": sum(rep.traffic_bytes.values()) / 1e6,
            "meta_kb": sum(rep.metadata_bytes.values()) / 1e3,
            "work_density": rep.work_density,
        })
    return rows


def execute_rows(densities, size=EXEC_SIZE, block=EXEC_BLOCK):
    rows = []
    dense_out = None
    for density in densities:
        sp = Sparsity.random((size, size), (block, block), density, seed=0)
        acc = repro.generate("gemm", bounds=dict(m=size, n=size, k=size),
                             sparsity={"A": sp}, interpret=True)
        err = acc.validate()
        operands = {k: np.asarray(v, np.float32) for k, v in
                    gemm(size, size, size).random_operands(seed=5).items()}
        if density == 1.0:
            dense = repro.generate("gemm",
                                   bounds=dict(m=size, n=size, k=size),
                                   interpret=True)
            dense_out = np.asarray(dense(operands))
        rows.append({
            "density": density,
            "mode": acc.kernel.sparse_mode,
            "grid_blocks": sp.nnz_blocks,
            "dense_grid": (size // block) ** 2,
            "max_err": err,
            "exec_ms": measure(acc, operands, warmup=1,
                               repeats=3).median_s * 1e3,
            "bit_exact_vs_dense": (
                bool((np.asarray(acc(operands)) == dense_out).all())
                if density == 1.0 else None),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small size, two densities (CI sparse step)")
    args = ap.parse_args()
    densities = SMOKE_DENSITIES if args.smoke else DENSITIES
    msize = 128 if args.smoke else MODEL_SIZE
    mblock = 16 if args.smoke else MODEL_BLOCK

    print(f"cost model (gemm {msize}^3, {mblock}x{mblock} blocks, "
          f"MNK-SST):")
    print("density,nnz_blocks,cycles,runtime_ms,traffic_mb,meta_kb")
    mrows = model_rows(densities, msize, mblock)
    for r in mrows:
        print(f"{r['density']},{r['nnz_blocks']},{r['cycles']:.0f},"
              f"{r['runtime_ms']:.4f},{r['traffic_mb']:.3f},"
              f"{r['meta_kb']:.2f}")
    for prev, cur in zip(mrows, mrows[1:]):
        assert cur["cycles"] <= prev["cycles"], "cycles not monotone"
        assert (cur["traffic_mb"] + cur["meta_kb"] / 1e3 <=
            prev["traffic_mb"] + prev["meta_kb"] / 1e3), "traffic not monotone"

    print(f"\nexecution (gemm {EXEC_SIZE}^3, {EXEC_BLOCK}x{EXEC_BLOCK} "
          f"blocks, interpret mode, masked dense oracle):")
    print("density,mode,grid_blocks,dense_grid,max_err,exec_ms,"
          "bit_exact_vs_dense")
    for r in execute_rows(densities):
        assert r["max_err"] <= 1e-3, r
        assert r["bit_exact_vs_dense"] in (None, True), r
        be = "-" if r["bit_exact_vs_dense"] is None else "yes"
        print(f"{r['density']},{r['mode']},{r['grid_blocks']},"
              f"{r['dense_grid']},{r['max_err']:.1e},"
              f"{r['exec_ms']:.3f},{be}")
    print("\nsparse_gemm: all parity and monotonicity checks passed")


if __name__ == "__main__":
    main()
