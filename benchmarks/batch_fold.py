"""Grid-folded vs block-diagonal batch execution (ISSUE 4 benchmark).

Sweeps batch (batched_gemv) and channel (depthwise_conv) counts and
reports, per size,

  * the executed-MAC ratio of each realization: the grid-folded path
    executes exactly the algebra's MACs (ratio 1.0, read off the
    generated accelerator's ``CostReport.executed_macs``), while the
    retired block-diagonal GEMM-ization executed batch x them,
  * wall time of both realizations on the XLA backend (jit'd, real
    compute — the asymptotic win is visible on CPU; Mosaic timings on a
    real TPU are hardware-pending, see ROADMAP),
  * interpret-mode parity of the grid-folded Pallas kernel against the
    block-diagonal oracle at the smallest size (bit-exact on integer
    operands).

Asserts the acceptance properties: the grid-folded ratio is 1.0 at every
size, the block-diagonal ratio equals the batch count, and the parity
check matches bitwise.

    PYTHONPATH=src python -m benchmarks.batch_fold [--smoke]

``--smoke`` runs two batch sizes with fewer timing repeats (< ~30 s; the
CI benchmark step runs it on every push).
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

import repro
from repro.core import algebra
from repro.kernels import ref
from repro.tune.measure import measure

BATCHES = (4, 16, 64, 128)
SMOKE_BATCHES = (4, 16)
#: per-slice problem so the block-diagonal operand (b, b*k) stays
#: buildable at the largest batch
GEMV_K, GEMV_N = 64, 64
DW = dict(y=14, x=14, p=3, q=3)


def _time(fn, *args, repeats: int = 5) -> float:
    """Best-of-``repeats`` in ms, via the shared measurement harness
    (repro.tune.measure) — the one timing loop the whole repo uses."""
    return measure(fn, *args, warmup=1, repeats=repeats).best_s * 1e3


def gemv_rows(batches, repeats: int) -> list:
    rows = []

    @jax.jit
    def folded(a, b):
        return ref.matmul_ref(b.reshape(b.shape[0], 1, -1), a)

    @jax.jit
    def blockdiag(a, b):
        return ref.batched_gemv_blockdiag_ref(a, b)

    for bsz in batches:
        alg = algebra.batched_gemv(m=bsz, k=GEMV_K, n=GEMV_N)
        acc = repro.generate(alg, interpret=True, validate=False)
        rep = acc.cost_report()
        a = jnp.asarray(np.random.default_rng(0).standard_normal(
            (bsz, GEMV_K, GEMV_N)), jnp.float32)
        b = jnp.asarray(np.random.default_rng(1).standard_normal(
            (bsz, GEMV_K)), jnp.float32)
        rows.append({
            "algebra": "batched_gemv", "batch": bsz,
            "alg_macs": alg.total_macs(),
            "folded_ratio": rep.executed_mac_ratio,
            "blockdiag_ratio": (bsz * GEMV_N * bsz * GEMV_K)
            / alg.total_macs(),
            "folded_ms": _time(folded, a, b, repeats=repeats),
            "blockdiag_ms": _time(blockdiag, a, b, repeats=repeats),
        })
    return rows


def depthwise_rows(batches, repeats: int) -> list:
    rows = []
    y, x, p, q = DW["y"], DW["x"], DW["p"], DW["q"]

    @jax.jit
    def folded(a, b):
        from repro.compile.lowering import _im2col_batched
        return ref.matmul_ref(b.reshape(b.shape[0], 1, p * q),
                              _im2col_batched(a, y, x, p, q))

    @jax.jit
    def blockdiag(a, b):
        return ref.depthwise_blockdiag_ref(a, b, y=y, x=x)

    for ch in batches:
        alg = algebra.depthwise_conv(k=ch, **DW)
        acc = repro.generate(alg, interpret=True, validate=False)
        rep = acc.cost_report()
        a = jnp.asarray(np.random.default_rng(0).standard_normal(
            (ch, y + p - 1, x + q - 1)), jnp.float32)
        b = jnp.asarray(np.random.default_rng(1).standard_normal(
            (ch, p, q)), jnp.float32)
        rows.append({
            "algebra": "depthwise_conv", "batch": ch,
            "alg_macs": alg.total_macs(),
            "folded_ratio": rep.executed_mac_ratio,
            "blockdiag_ratio": (ch * y * x * ch * p * q) / alg.total_macs(),
            "folded_ms": _time(folded, a, b, repeats=repeats),
            "blockdiag_ms": _time(blockdiag, a, b, repeats=repeats),
        })
    return rows


def parity_check() -> None:
    """Grid-folded Pallas kernel (interpret mode) vs block-diagonal
    oracle: bit-exact on integer operands."""
    bg = algebra.batched_gemv(m=4, k=8, n=8)
    acc = repro.generate(bg, interpret=True)
    operands = bg.random_operands(seed=7)
    got = np.asarray(acc(operands))
    want = np.asarray(ref.batched_gemv_blockdiag_ref(
        jnp.asarray(operands["A"], jnp.float32),
        jnp.asarray(operands["B"], jnp.float32)))
    assert (got == want).all(), "batched_gemv parity failed"

    dw = algebra.depthwise_conv(k=8, y=6, x=6, p=3, q=3)
    acc = repro.generate(dw, interpret=True)
    operands = dw.random_operands(seed=7)
    got = np.asarray(acc(operands))
    want = np.asarray(ref.depthwise_blockdiag_ref(
        jnp.asarray(operands["A"], jnp.float32),
        jnp.asarray(operands["B"], jnp.float32), y=6, x=6))
    assert (got == want).all(), "depthwise parity failed"
    print("parity: grid-folded Pallas == block-diagonal oracle "
          "(bit-exact, interpret mode)\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two batch sizes, fewer repeats (CI step)")
    args = ap.parse_args()
    batches = SMOKE_BATCHES if args.smoke else BATCHES
    repeats = 3 if args.smoke else 7

    parity_check()
    print("algebra,batch,alg_macs,folded_ratio,blockdiag_ratio,"
          "folded_ms,blockdiag_ms,speedup")
    worst_win_at_16 = None
    for row in gemv_rows(batches, repeats) + depthwise_rows(batches,
                                                            repeats):
        assert row["folded_ratio"] == 1.0, row
        assert row["blockdiag_ratio"] == row["batch"], row
        speedup = row["blockdiag_ms"] / row["folded_ms"]
        if row["batch"] >= 16:
            worst_win_at_16 = (speedup if worst_win_at_16 is None
                else min(worst_win_at_16, speedup))
        print(f"{row['algebra']},{row['batch']},{row['alg_macs']},"
              f"{row['folded_ratio']:.2f},{row['blockdiag_ratio']:.0f},"
              f"{row['folded_ms']:.3f},{row['blockdiag_ms']:.3f},"
              f"{speedup:.1f}x")
    print("\nbatch_fold: executed-MAC ratio drops from batch x to 1.0 at "
          "every size; all parity checks passed")
    if worst_win_at_16 is not None and worst_win_at_16 <= 1.0:
        # the win must hold for every row, so report the minimum; XLA
        # timing on shared CI machines can be noisy, so report rather
        # than fail (Mosaic wall time is hardware-pending anyway)
        print(f"note: wall-time win at batch >= 16 not observed on this "
              f"host for every case (worst {worst_win_at_16:.2f}x, "
              f"hardware-pending)")


if __name__ == "__main__":
    main()
