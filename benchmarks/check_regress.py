"""bench-regress gate: validate emitted BENCH_*.json + enforce floors.

    PYTHONPATH=src python -m benchmarks.check_regress

Run after ``benchmarks/run.py --smoke`` (the CI bench-regress job does).
Re-validates every benchmark artifact against its schema and fails the
job when a performance ratio regresses below its floor:

  * BENCH_tune.json  — schema ``repro.tune.report.validate_bench``;
    tuned-vs-untuned speedup >= TUNE_SPEEDUP_FLOOR per cell (a tuned
    pick must never lose to its own untuned baseline),
  * BENCH_serve.json — schema ``repro.serve.report.validate_serve``;
    continuous-vs-static throughput >= SERVE_SPEEDUP_FLOOR,
  * BENCH_graph.json — schema v3: fused-vs-unfused HBM ratio >= the
    modeled floor recorded in the document
    (``benchmarks.graph_fusion.HBM_RATIO_FLOOR``), *measured*
    merged-vs-sequential wall-clock speedup >= the document's
    ``measured_floor`` (``MEASURED_SPEEDUP_FLOOR``, >= 1.2), bit
    parity with both the explicit-schedule oracle and sequential
    dispatch, AND a ``model_layer`` entry: the whole dense-family
    layer graph must keep >= 1 merged group with its residual tap
    exported, bit parity vs ``models.transformer.dense_layer_forward``,
    and measured layer-forward speedup >= ``model_floor`` (>= 1.2).

The emitting benchmarks enforce their own gates too; this checker is
the belt to their suspenders — it catches a stale or hand-edited
artifact and gives CI one uniform failure surface to report.
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).parent.parent

TUNE_SPEEDUP_FLOOR = 1.0
SERVE_SPEEDUP_FLOOR = 1.5


def _load(name: str, problems: list) -> dict | None:
    path = ROOT / name
    if not path.exists():
        problems.append(f"{name}: missing (did benchmarks/run.py run?)")
        return None
    try:
        return json.loads(path.read_text())
    except ValueError as e:
        problems.append(f"{name}: unparseable ({e})")
        return None


def check(problems: list) -> None:
    from repro.serve.report import validate_serve
    from repro.tune.report import validate_bench

    tune = _load("BENCH_tune.json", problems)
    if tune is not None:
        problems += [f"BENCH_tune.json: {p}" for p in validate_bench(tune)]
        for cell in tune.get("cells", []):
            sp = cell.get("speedup")
            if isinstance(sp, (int, float)) and sp < TUNE_SPEEDUP_FLOOR:
                problems.append(
                    f"BENCH_tune.json: {cell.get('cell')} tuned/untuned "
                    f"speedup {sp:.2f} < floor {TUNE_SPEEDUP_FLOOR}")

    serve = _load("BENCH_serve.json", problems)
    if serve is not None:
        problems += [f"BENCH_serve.json: {p}" for p in
                     validate_serve(serve)]
        sp = serve.get("speedup")
        if sp is not None and sp < SERVE_SPEEDUP_FLOOR:
            problems.append(
                f"BENCH_serve.json: continuous/static speedup {sp:.2f} "
                f"< floor {SERVE_SPEEDUP_FLOOR}")

    graph = _load("BENCH_graph.json", problems)
    if graph is not None:
        floor = graph.get("floor")
        mfloor = graph.get("measured_floor")
        lfloor = graph.get("model_floor")
        chains = graph.get("chains")
        model = graph.get("model_layer")
        if graph.get("version") != 3:
            problems.append(f"BENCH_graph.json: schema version "
                            f"{graph.get('version')!r} != 3 (stale "
                            f"artifact? re-run benchmarks.graph_fusion)")
        elif (not isinstance(floor, (int, float))
                or not isinstance(mfloor, (int, float))
                or not isinstance(lfloor, (int, float))
                or not isinstance(chains, list) or not chains
                or not isinstance(model, dict)):
            problems.append("BENCH_graph.json: needs numeric 'floor', "
                            "'measured_floor' and 'model_floor', "
                            "non-empty 'chains' and a 'model_layer' "
                            "object")
        elif mfloor < 1.2 or lfloor < 1.2:
            problems.append(f"BENCH_graph.json: measured_floor {mfloor} "
                            f"/ model_floor {lfloor} < 1.2 (the gates "
                            f"must not be weakened)")
        else:
            for row in chains:
                ratio = row.get("hbm_ratio")
                if not isinstance(ratio, (int, float)) or ratio < floor:
                    problems.append(
                        f"BENCH_graph.json: {row.get('shape')} hbm_ratio "
                        f"{ratio} < floor {floor}")
                speedup = row.get("measured_speedup")
                if (not isinstance(speedup, (int, float))
                        or speedup < mfloor):
                    problems.append(
                        f"BENCH_graph.json: {row.get('shape')} "
                        f"measured_speedup {speedup} < floor {mfloor}")
                if not row.get("merged_groups"):
                    problems.append(
                        f"BENCH_graph.json: {row.get('shape')} has no "
                        f"merged group (megakernel path not exercised)")
                if row.get("bit_parity") is not True:
                    problems.append(
                        f"BENCH_graph.json: {row.get('shape')} lost bit "
                        f"parity vs the explicit-schedule oracle")
                if row.get("bit_parity_sequential") is not True:
                    problems.append(
                        f"BENCH_graph.json: {row.get('shape')} merged "
                        f"kernel lost bit parity vs sequential dispatch")
            speedup = model.get("measured_speedup")
            if not isinstance(speedup, (int, float)) or speedup < lfloor:
                problems.append(
                    f"BENCH_graph.json: model_layer measured_speedup "
                    f"{speedup} < floor {lfloor}")
            if not model.get("merged_groups"):
                problems.append(
                    "BENCH_graph.json: model_layer has no merged group "
                    "(whole-layer fusion regressed)")
            if not model.get("tapped_edges"):
                problems.append(
                    "BENCH_graph.json: model_layer exports no residual "
                    "tap")
            if model.get("bit_parity") is not True:
                problems.append(
                    "BENCH_graph.json: model_layer lost bit parity vs "
                    "models.transformer.dense_layer_forward")
            if model.get("bit_parity_sequential") is not True:
                problems.append(
                    "BENCH_graph.json: model_layer merged kernel lost "
                    "bit parity vs sequential dispatch")


def main() -> None:
    problems: list = []
    check(problems)
    if problems:
        print("bench-regress gates FAILED:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        raise SystemExit(1)
    print("bench-regress gates passed (tune schema+floor, serve "
          "schema+floor, graph ratio+parity)")


if __name__ == "__main__":
    main()
