"""Deterministic synthetic data pipeline."""
from .pipeline import DataConfig, SyntheticPipeline, frontend_stub
