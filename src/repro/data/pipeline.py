"""Deterministic synthetic token pipeline, sharded per host.

Real clusters stream from a distributed store; this container has no
dataset, so the pipeline synthesizes a *deterministic* token stream from
(seed, step, shard) — the properties that matter for the framework are kept:

  * restart-safety: batch(step) is a pure function, so resuming from a
    checkpoint replays the exact stream (tested),
  * per-host sharding: each data-parallel shard draws a disjoint slice,
  * learnable structure: tokens follow a noisy affine-recurrence language
    (next = (a * cur + b) % vocab with ~10% noise) so train-loss decreases
    measurably within a few hundred steps on the smoke models.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    n_shards: int = 1
    shard: int = 0


def _batch_numpy(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function of (cfg, step) -> host-local batch."""
    assert cfg.global_batch % cfg.n_shards == 0
    local = cfg.global_batch // cfg.n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard]))
    a = 31, 17
    start = rng.integers(0, cfg.vocab, size=(local, 1))
    seq = [start]
    cur = start
    for _ in range(cfg.seq_len):
        nxt = (a[0] * cur + a[1]) % cfg.vocab
        flip = rng.random((local, 1)) < cfg.noise
        rand = rng.integers(0, cfg.vocab, size=(local, 1))
        cur = np.where(flip, rand, nxt)
        seq.append(cur)
    toks = np.concatenate(seq, axis=1).astype(np.int32)   # (local, S+1)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class SyntheticPipeline:
    """Iterator with explicit step state (checkpointable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def next(self) -> Dict[str, np.ndarray]:
        batch = _batch_numpy(self.cfg, self.step)
        self.step += 1
        return batch

    def state(self) -> Dict:
        return {"step": self.step}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()


def frontend_stub(batch: int, tokens: int, d_model: int,
                  step: int = 0, seed: int = 0) -> np.ndarray:
    """Deterministic stand-in for modality frontends (image patches /
    audio frames): input_specs() feeds these pre-computed embeddings."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    return (0.02 * rng.standard_normal((batch, tokens, d_model))
            ).astype(np.float32)
