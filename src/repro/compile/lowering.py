"""Algebra lowering: GEMM-ize every Table II tensor algebra.

TensorLib's reuse argument (paper §V) is that a small set of hardware
templates covers every tensor algebra.  On the TPU retarget the templates
are the three Pallas GEMM kernels in ``kernels/stt_gemm.py`` — so to make
*every* ``get_algebra`` name executable the non-GEMM algebras must be
expressed as one 2-D matmul plus cheap data-layout prep:

    gemm            C = A @ B^T                        (transpose)
    batched_gemv    block-diagonal lhs over the batch  (batch folding)
    conv2d          im2col patches x reshaped weights  (paper's conv = GEMM)
    depthwise_conv  im2col + per-channel block-diagonal weights
    mttkrp          mode-1 unfolding x Khatri-Rao product
    ttmc            mode-1 unfolding x Kronecker product

Each lowering yields a :class:`GemmForm`: the 2-D problem dims, which loop
iterators each GEMM dim folds (so the STT tile choice maps onto Pallas
block sizes), which algebra tensors feed the lhs/rhs (so VMEM residency
from the KernelPlan maps onto the ``stationary`` operand), and
prepare/finish callables that move operands into and out of matrix form.

The prep work is pure jnp layout code (reshape/slice/broadcast) — the MACs
all run inside the selected Pallas template, which is the point.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Mapping, Tuple

import jax
import jax.numpy as jnp

from ..core.algebra import TensorAlgebra


Operands = Mapping[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class GemmForm:
    """A 2-D matmul view of a tensor algebra: out2d = lhs2d @ rhs2d."""

    m: int
    n: int
    k: int
    #: which loop iterators each GEMM dim folds, e.g. conv2d k = (c, p, q)
    dim_loops: Mapping[str, Tuple[str, ...]]
    #: algebra tensors feeding each matmul operand (residency mapping)
    lhs_tensors: FrozenSet[str]
    rhs_tensors: FrozenSet[str]
    prepare: Callable[[Operands], Tuple[jax.Array, jax.Array]]
    finish: Callable[[jax.Array], jax.Array]


def _b(alg: TensorAlgebra, *names: str) -> Tuple[int, ...]:
    return tuple(alg.bounds[alg.loop_index(nm)] for nm in names)


def _im2col(a: jax.Array, y: int, x: int, p: int, q: int) -> jax.Array:
    """(C, y+p-1, x+q-1) -> (C * p * q, y * x) patch matrix, C-major then
    (p, q) — matching a (C, p, q)-ordered weight reshape."""
    c = a.shape[0]
    patches = jnp.stack([a[:, pp:pp + y, qq:qq + x]
                         for pp in range(p) for qq in range(q)], axis=1)
    return patches.reshape(c * p * q, y * x)


def _block_diag_rows(rows: jax.Array) -> jax.Array:
    """(B, K) -> (B, B*K) with row i equal to rows[i] placed in block i.

    Folds a batch loop that indexes an operand *and* the output into the
    contraction dimension: the zero blocks make cross-batch products
    vanish, so one plain GEMM computes every batch at once.

    Honesty note: the zero padding means the executed GEMM performs B x
    the algebra's MACs (batched_gemv, depthwise_conv).  The cost model
    prices the *algebra's* dataflow, not this dense realization — fine
    for correctness-oriented execution, wasteful at production batch
    sizes; ROADMAP has an open item to move the batch loop into the
    Pallas grid instead.
    """
    b = rows.shape[0]
    return (jnp.eye(b, dtype=rows.dtype)[:, :, None]
            * rows[None, :, :]).reshape(b, -1)


# ---------------------------------------------------------------------------
# Per-algebra lowerings (Table II)
# ---------------------------------------------------------------------------

def _gemmize_gemm(alg: TensorAlgebra) -> GemmForm:
    m, n, k = _b(alg, "m", "n", "k")
    return GemmForm(
        m, n, k,
        {"m": ("m",), "n": ("n",), "k": ("k",)},
        frozenset({"A"}), frozenset({"B"}),
        prepare=lambda ops: (ops["A"], ops["B"].T),   # B is (n, k)
        finish=lambda c: c)


def _gemmize_batched_gemv(alg: TensorAlgebra) -> GemmForm:
    m, n, k = _b(alg, "m", "n", "k")
    return GemmForm(
        m, n, m * k,
        {"m": ("m",), "n": ("n",), "k": ("m", "k")},
        frozenset({"B"}), frozenset({"A"}),
        # C[m, n] = sum_k A[m, k, n] * B[m, k]: the batch loop m indexes
        # both inputs and the output -> fold it into the contraction with a
        # block-diagonal lhs.
        prepare=lambda ops: (_block_diag_rows(ops["B"]),
                             ops["A"].reshape(m * k, n)),
        finish=lambda c: c)


def _gemmize_conv2d(alg: TensorAlgebra) -> GemmForm:
    k, c, y, x, p, q = _b(alg, "k", "c", "y", "x", "p", "q")
    return GemmForm(
        k, y * x, c * p * q,
        {"m": ("k",), "n": ("y", "x"), "k": ("c", "p", "q")},
        frozenset({"B"}), frozenset({"A"}),
        prepare=lambda ops: (ops["B"].reshape(k, c * p * q),
                             _im2col(ops["A"], y, x, p, q)),
        finish=lambda o: o.reshape(k, y, x))


def _gemmize_depthwise(alg: TensorAlgebra) -> GemmForm:
    k, y, x, p, q = _b(alg, "k", "y", "x", "p", "q")
    return GemmForm(
        k, y * x, k * p * q,
        {"m": ("k",), "n": ("y", "x"), "k": ("k", "p", "q")},
        frozenset({"B"}), frozenset({"A"}),
        # channel loop k indexes weights, activations and output -> fold it
        # into the contraction (block-diagonal weights x im2col patches)
        prepare=lambda ops: (_block_diag_rows(ops["B"].reshape(k, p * q)),
                             _im2col(ops["A"], y, x, p, q)),
        finish=lambda o: o.reshape(k, y, x))


def _gemmize_mttkrp(alg: TensorAlgebra) -> GemmForm:
    i, j, k, l = _b(alg, "i", "j", "k", "l")
    return GemmForm(
        i, j, k * l,
        {"m": ("i",), "n": ("j",), "k": ("k", "l")},
        frozenset({"A"}), frozenset({"B", "C"}),
        # D = A_(1) @ (B Khatri-Rao C): mode-1 unfolding of A against the
        # column-wise Khatri-Rao product of the factor matrices
        prepare=lambda ops: (ops["A"].reshape(i, k * l),
                             (ops["B"][:, None, :]
                              * ops["C"][None, :, :]).reshape(k * l, j)),
        finish=lambda d: d)


def _gemmize_ttmc(alg: TensorAlgebra) -> GemmForm:
    i, j, k, l, m = _b(alg, "i", "j", "k", "l", "m")
    return GemmForm(
        i, j * k, l * m,
        {"m": ("i",), "n": ("j", "k"), "k": ("l", "m")},
        frozenset({"A"}), frozenset({"B", "C"}),
        # D_(1) = A_(1) @ (B Kronecker C): Tucker-style chain contraction
        prepare=lambda ops: (ops["A"].reshape(i, l * m),
                             (ops["B"][:, None, :, None]
                              * ops["C"][None, :, None, :]
                              ).reshape(l * m, j * k)),
        finish=lambda d: d.reshape(i, j, k))


_LOWERINGS: Dict[str, Callable[[TensorAlgebra], GemmForm]] = {
    "gemm": _gemmize_gemm,
    "batched_gemv": _gemmize_batched_gemv,
    "conv2d": _gemmize_conv2d,
    "depthwise_conv": _gemmize_depthwise,
    "mttkrp": _gemmize_mttkrp,
    "ttmc": _gemmize_ttmc,
}


def gemmize(alg: TensorAlgebra) -> GemmForm:
    """Lower any registry algebra to a single-GEMM form (bounds-aware)."""
    try:
        builder = _LOWERINGS[alg.name]
    except KeyError:
        raise NotImplementedError(
            f"no GEMM lowering registered for algebra {alg.name!r}; "
            f"known: {sorted(_LOWERINGS)}") from None
    return builder(alg)
