"""Algebra lowering: map every Table II tensor algebra onto the templates.

TensorLib's reuse argument (paper §V) is that a small set of hardware
templates covers every tensor algebra.  On the TPU retarget the templates
are the three Pallas GEMM kernels in ``kernels/stt_gemm.py`` — so to make
*every* ``get_algebra`` name executable the non-GEMM algebras must be
expressed as one (optionally batched) matmul plus cheap data-layout prep:

    gemm            C = A @ B^T                         (transpose)
    batched_gemv    per-batch (1,k)x(k,n) on the grid   (grid-folded batch)
    conv2d          im2col patches x reshaped weights   (paper's conv = GEMM)
    depthwise_conv  per-channel im2col x (1,pq) weights (grid-folded channel)
    mttkrp          mode-1 unfolding x Khatri-Rao product
    ttmc            mode-1 unfolding x Kronecker product

Each lowering yields a :class:`LoweredForm`: the batched-matmul problem
dims ``out[b, m, n] = lhs[b|·, m, k] @ rhs[b|·, k, n]`` (``batch=()``
degenerates to the plain 2-D GEMM), which loop iterators each dim folds
(so the STT tile choice maps onto Pallas block sizes), which algebra
tensors feed the lhs/rhs (so VMEM residency from the KernelPlan maps onto
the ``stationary`` operand), and prepare/finish callables that move
operands into and out of matrix form.

Batch loops that index an operand *and* the output (batched_gemv's batch,
depthwise_conv's channel) become leading **grid** dimensions of the Pallas
templates — never contraction padding — so the executed kernel performs
exactly the algebra's MACs and ``CostReport.executed_macs`` matches what
``PaperCycleModel`` prices.  (The retired block-diagonal GEMM-ization,
which zero-padded the contraction and executed batch× the useful work,
survives only as a test oracle in ``kernels/ref.py``.)

The prep work is pure jnp layout code (reshape/slice/broadcast) — the MACs
all run inside the selected Pallas template, which is the point.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, FrozenSet, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.algebra import Sparsity, TensorAlgebra


Operands = Mapping[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class OperandSparsity:
    """A tensor's block-sparse pattern mapped onto one 2-D GEMM operand.

    ``coords`` live on the block grid of the *prepared* 2-D operand
    (lhs2d or rhs2d, post-``prepare``), sorted row-major — the form the
    BSR kernel's scalar-prefetch index map consumes directly.
    """

    side: str                            # "lhs" | "rhs"
    tensor: str                          # the algebra tensor it came from
    block: Tuple[int, int]               # 2-D block shape on that operand
    coords: Tuple[Tuple[int, int], ...]  # row-major block-COO
    grid: Tuple[int, int]                # block-grid shape of the operand

    @property
    def nnz_blocks(self) -> int:
        return len(self.coords)

    @property
    def density(self) -> float:
        total = self.grid[0] * self.grid[1]
        return self.nnz_blocks / total if total else 0.0


@dataclasses.dataclass(frozen=True)
class LoweredForm:
    """A rank-aware batched-matmul view of a tensor algebra:

        out[b, m, n] = lhs[b|·, m, k] @ rhs[b|·, k, n]

    ``batch`` holds the sizes of the leading (grid-parallel) batch dims;
    ``()`` degenerates to the plain 2-D GEMM every dense non-batched
    algebra uses.  ``lhs_batched`` / ``rhs_batched`` record whether
    ``prepare`` emits that operand with the leading batch dim (un-batched
    operands broadcast across the batch grid axis via their index maps).
    """

    m: int
    n: int
    k: int
    #: which loop iterators each dim folds, e.g. conv2d k = (c, p, q);
    #: the "b" key lists the batch loops folded onto the grid axis
    dim_loops: Mapping[str, Tuple[str, ...]]
    #: algebra tensors feeding each matmul operand (residency mapping)
    lhs_tensors: FrozenSet[str]
    rhs_tensors: FrozenSet[str]
    prepare: Callable[[Operands], Tuple[jax.Array, jax.Array]]
    finish: Callable[[jax.Array], jax.Array]
    #: leading batch-dim sizes; () = no batch grid axis
    batch: Tuple[int, ...] = ()
    lhs_batched: bool = False
    rhs_batched: bool = False
    #: structured block-sparse operand (at most one: the BSR kernel takes
    #: one coordinate list); None for dense algebras
    sparse: Optional[OperandSparsity] = None
    #: sparse tensors executed via the masked-dense fallback — their
    #: pattern has no structured 2-D image under this lowering (operands
    #: are zero-masked, so the dense templates stay exact; only the
    #: block-skipping speedup is lost)
    masked_sparse: Tuple[str, ...] = ()
    #: for batched forms with sparse operands: the original batch-slice
    #: indices the kernel executes (slices whose sparse operands are
    #: entirely zero blocks produce exactly-zero output slices and are
    #: skipped; ``prepare``/``finish`` compact and re-expand the batch
    #: axis).  None = every slice executes.
    batch_keep: Optional[Tuple[int, ...]] = None
    #: original batch extent before slice skipping (``batch`` holds the
    #: compacted extent so every consumer scales with executed work)
    batch_full: Optional[Tuple[int, ...]] = None

    @property
    def batch_size(self) -> int:
        """Total batch grid extent (1 when the form is a plain GEMM)."""
        return math.prod(self.batch) if self.batch else 1

    @property
    def executed_macs(self) -> int:
        """MACs the lowered kernel actually performs: one per grid point
        of the batched matmul.  The BSR grid visits only nonzero blocks,
        so a structured sparse operand scales this by its block density.
        Equal to ``alg.total_macs()`` for every registry algebra — the
        grid-folded refactor's invariant."""
        executed = self.batch_size * self.m * self.n * self.k
        if self.sparse is not None:
            executed = round(executed * self.sparse.density)
        return max(1, executed)


#: back-compat alias: the 2-D special case (batch=()) of LoweredForm is
#: exactly the historic GemmForm
GemmForm = LoweredForm


def _b(alg: TensorAlgebra, *names: str) -> Tuple[int, ...]:
    return tuple(alg.bounds[alg.loop_index(nm)] for nm in names)


def _im2col_batched(a: jax.Array, y: int, x: int, p: int, q: int
                    ) -> jax.Array:
    """(C, y+p-1, x+q-1) -> (C, p * q, y * x) per-channel patch matrices,
    (p, q)-ordered rows — matching a (p, q)-ordered weight reshape."""
    c = a.shape[0]
    patches = jnp.stack([a[:, pp:pp + y, qq:qq + x]
                         for pp in range(p) for qq in range(q)], axis=1)
    return patches.reshape(c, p * q, y * x)


def _im2col(a: jax.Array, y: int, x: int, p: int, q: int) -> jax.Array:
    """(C, y+p-1, x+q-1) -> (C * p * q, y * x) patch matrix, C-major then
    (p, q) — matching a (C, p, q)-ordered weight reshape."""
    c = a.shape[0]
    return _im2col_batched(a, y, x, p, q).reshape(c * p * q, y * x)


# ---------------------------------------------------------------------------
# Per-algebra lowerings (Table II)
# ---------------------------------------------------------------------------

def _lower_gemm(alg: TensorAlgebra) -> LoweredForm:
    m, n, k = _b(alg, "m", "n", "k")
    return LoweredForm(
        m, n, k,
        {"b": (), "m": ("m",), "n": ("n",), "k": ("k",)},
        frozenset({"A"}), frozenset({"B"}),
        prepare=lambda ops: (ops["A"], ops["B"].T),   # B is (n, k)
        finish=lambda c: c)


def _lower_batched_gemv(alg: TensorAlgebra) -> LoweredForm:
    m, n, k = _b(alg, "m", "n", "k")
    return LoweredForm(
        1, n, k,
        {"b": ("m",), "m": (), "n": ("n",), "k": ("k",)},
        frozenset({"B"}), frozenset({"A"}),
        # C[m, n] = sum_k A[m, k, n] * B[m, k]: the batch loop m indexes
        # both inputs and the output -> it becomes the leading grid dim,
        # a (1, k) x (k, n) matvec per batch slice.
        prepare=lambda ops: (ops["B"].reshape(m, 1, k), ops["A"]),
        finish=lambda c: c.reshape(m, n),
        batch=(m,), lhs_batched=True, rhs_batched=True)


def _lower_conv2d(alg: TensorAlgebra) -> LoweredForm:
    k, c, y, x, p, q = _b(alg, "k", "c", "y", "x", "p", "q")
    return LoweredForm(
        k, y * x, c * p * q,
        {"b": (), "m": ("k",), "n": ("y", "x"), "k": ("c", "p", "q")},
        frozenset({"B"}), frozenset({"A"}),
        prepare=lambda ops: (ops["B"].reshape(k, c * p * q),
                             _im2col(ops["A"], y, x, p, q)),
        finish=lambda o: o.reshape(k, y, x))


def _lower_depthwise(alg: TensorAlgebra) -> LoweredForm:
    k, y, x, p, q = _b(alg, "k", "y", "x", "p", "q")
    return LoweredForm(
        1, y * x, p * q,
        {"b": ("k",), "m": (), "n": ("y", "x"), "k": ("p", "q")},
        frozenset({"B"}), frozenset({"A"}),
        # channel loop k indexes weights, activations and output -> it
        # becomes the leading grid dim: per-channel im2col patches against
        # that channel's (1, p*q) filter row.
        prepare=lambda ops: (ops["B"].reshape(k, 1, p * q),
                             _im2col_batched(ops["A"], y, x, p, q)),
        finish=lambda o: o.reshape(k, y, x),
        batch=(k,), lhs_batched=True, rhs_batched=True)


def _lower_mttkrp(alg: TensorAlgebra) -> LoweredForm:
    i, j, k, l = _b(alg, "i", "j", "k", "l")
    return LoweredForm(
        i, j, k * l,
        {"b": (), "m": ("i",), "n": ("j",), "k": ("k", "l")},
        frozenset({"A"}), frozenset({"B", "C"}),
        # D = A_(1) @ (B Khatri-Rao C): mode-1 unfolding of A against the
        # column-wise Khatri-Rao product of the factor matrices
        prepare=lambda ops: (ops["A"].reshape(i, k * l),
                             (ops["B"][:, None, :]
                              * ops["C"][None, :, :]).reshape(k * l, j)),
        finish=lambda d: d)


def _lower_ttmc(alg: TensorAlgebra) -> LoweredForm:
    i, j, k, l, m = _b(alg, "i", "j", "k", "l", "m")
    return LoweredForm(
        i, j * k, l * m,
        {"b": (), "m": ("i",), "n": ("j", "k"), "k": ("l", "m")},
        frozenset({"A"}), frozenset({"B", "C"}),
        # D_(1) = A_(1) @ (B Kronecker C): Tucker-style chain contraction
        prepare=lambda ops: (ops["A"].reshape(i, l * m),
                             (ops["B"][:, None, :, None]
                              * ops["C"][None, :, None, :]
                              ).reshape(l * m, j * k)),
        finish=lambda d: d.reshape(i, j, k))


_LOWERINGS: Dict[str, Callable[[TensorAlgebra], LoweredForm]] = {
    "gemm": _lower_gemm,
    "batched_gemv": _lower_batched_gemv,
    "conv2d": _lower_conv2d,
    "depthwise_conv": _lower_depthwise,
    "mttkrp": _lower_mttkrp,
    "ttmc": _lower_ttmc,
}


# ---------------------------------------------------------------------------
# Block-sparse pattern -> 2-D GEMM operand mapping
# ---------------------------------------------------------------------------
# Each mapper takes (alg, tensor shape, Sparsity) and returns an
# OperandSparsity on the *prepared* 2-D operand, or None when the pattern
# has no structured image under the lowering (the caller then falls back
# to masked-dense execution, which stays exact).  Batched forms have no
# mappers: the BSR kernel is 2-D, so their patterns run masked-dense.

def _sparse_gemm_A(alg: TensorAlgebra, shape, sp: Sparsity
                   ) -> Optional[OperandSparsity]:
    # A (m, k) feeds lhs2d unchanged
    grid = sp.grid(shape)
    return OperandSparsity("lhs", "A", (sp.block[0], sp.block[1]),
                           tuple(sorted(sp.coords)), grid)


def _sparse_gemm_B(alg: TensorAlgebra, shape, sp: Sparsity
                   ) -> Optional[OperandSparsity]:
    # B (n, k) becomes rhs2d = B.T (k, n): block coords transpose
    grid = sp.grid(shape)
    coords = tuple(sorted((c, r) for r, c in sp.coords))
    return OperandSparsity("rhs", "B", (sp.block[1], sp.block[0]), coords,
                           (grid[1], grid[0]))


def _sparse_conv2d_B(alg: TensorAlgebra, shape, sp: Sparsity
                     ) -> Optional[OperandSparsity]:
    # weights (k, c, p, q) reshape to lhs2d (k, c*p*q): a block covering
    # the full (p, q) window maps to a contiguous 2-D block — the
    # block-sparse im2col form (im2col'd activations stay dense)
    k, c, p, q = shape
    if sp.block[2:] != (p, q):
        return None
    grid = sp.grid(shape)
    coords = tuple(sorted((ci[0], ci[1]) for ci in sp.coords))
    return OperandSparsity("lhs", "B", (sp.block[0], sp.block[1] * p * q),
                           coords, (grid[0], grid[1]))


def _sparse_mttkrp_A(alg: TensorAlgebra, shape, sp: Sparsity
                     ) -> Optional[OperandSparsity]:
    # A (i, k, l) reshapes to lhs2d (i, k*l): blocks covering full l stay
    # contiguous through the mode-1 unfolding
    i, k, l = shape
    if sp.block[2] != l:
        return None
    grid = sp.grid(shape)
    coords = tuple(sorted((ci[0], ci[1]) for ci in sp.coords))
    return OperandSparsity("lhs", "A", (sp.block[0], sp.block[1] * l),
                           coords, (grid[0], grid[1]))


_SPARSE_MAPPERS: Dict[Tuple[str, str], Callable] = {
    ("gemm", "A"): _sparse_gemm_A,
    ("gemm", "B"): _sparse_gemm_B,
    ("conv2d", "B"): _sparse_conv2d_B,
    ("mttkrp", "A"): _sparse_mttkrp_A,
}


def _attach_sparsity(alg: TensorAlgebra, form: LoweredForm) -> LoweredForm:
    """Map every attached pattern onto the lowered form: at most one
    becomes the structured (BSR-executed) operand and the rest run
    masked-dense.

    Tie-break intent, explicitly: the structured slot goes to the pattern
    with the **lowest block density** — fewest nonzero blocks, i.e. the
    most grid stages the BSR kernel gets to skip.  Equal densities break
    deterministically by tensor name (alphabetical).
    """
    mapped = []
    masked = []
    for name, sp in alg.sparsity:
        t = next(t for t in alg.tensors if t.name == name)
        mapper = _SPARSE_MAPPERS.get((alg.name, name))
        osp = mapper(alg, alg.tensor_shape(t), sp) if mapper else None
        if osp is None:
            masked.append(name)
        else:
            mapped.append(osp)
    mapped.sort(key=lambda o: (o.density, o.tensor))
    chosen = mapped[0] if mapped else None
    masked.extend(o.tensor for o in mapped[1:])
    return dataclasses.replace(form, sparse=chosen,
                               masked_sparse=tuple(sorted(masked)))


def _batch_keep(alg: TensorAlgebra, form: LoweredForm
                ) -> Optional[Tuple[int, ...]]:
    """Batch slices the kernel must execute for a sparse batched form.

    The batched lowerings run masked-dense (the BSR kernel is 2-D), but a
    block pattern still maps **per batch slice**: a slice whose sparse
    operands hold only zero blocks produces an exactly-zero output slice
    and can be skipped outright.  Any sparse input whose leading tensor
    dim *is* the batch iterator (batched_gemv's A/B over m,
    depthwise_conv's A/B over the channel) constrains the kept set; when
    several do, a slice survives only if nonzero in all of them (the
    output is their product).  Returns None when every slice executes.
    """
    if len(form.batch) != 1 or not alg.sparsity:
        return None
    bloops = form.dim_loops.get("b", ())
    if len(bloops) != 1:
        return None
    bcol = alg.loop_index(bloops[0])
    b = form.batch[0]
    keep = None
    for name, sp in alg.sparsity:
        t = next(t for t in alg.tensors if t.name == name)
        row0 = t.access[0]
        if not (row0[bcol] == 1 and sum(abs(v) for v in row0) == 1):
            continue              # leading dim is not the batch iterator
        nz = set()
        for c in sp.coords:
            lo = c[0] * sp.block[0]
            nz.update(range(lo, min(b, lo + sp.block[0])))
        keep = nz if keep is None else (keep & nz)
    if keep is None or len(keep) == b:
        return None
    return tuple(sorted(keep)) or (0,)


def _compact_batch(form: LoweredForm, keep: Tuple[int, ...]) -> LoweredForm:
    """Wrap prepare/finish to execute only the kept batch slices (the
    skipped ones are exactly zero under the enforced patterns)."""
    idx = jnp.asarray(keep, jnp.int32)
    b_full = form.batch[0]
    orig_prepare, orig_finish = form.prepare, form.finish

    def prepare(ops: Operands) -> Tuple[jax.Array, jax.Array]:
        lhs, rhs = orig_prepare(ops)
        if form.lhs_batched:
            lhs = jnp.take(lhs, idx, axis=0)
        if form.rhs_batched:
            rhs = jnp.take(rhs, idx, axis=0)
        return lhs, rhs

    def finish(o: jax.Array) -> jax.Array:
        full = jnp.zeros((b_full, *o.shape[1:]), o.dtype).at[idx].set(o)
        return orig_finish(full)

    return dataclasses.replace(form, batch=(len(keep),), prepare=prepare,
                               finish=finish, batch_keep=keep,
                               batch_full=form.batch)


def lower_form(alg: TensorAlgebra) -> LoweredForm:
    """Lower any registry algebra to its batched-matmul form (bounds-aware).

    Algebras carrying block-sparse patterns get them mapped onto the 2-D
    operands here (``LoweredForm.sparse`` / ``masked_sparse``); the
    pipeline then routes the structured operand through the BSR kernel
    grid.  Sparse *batched* forms map their patterns per batch slice:
    all-zero slices are skipped (``batch_keep``), so ``executed_macs``
    scales with the nonzero slice count instead of the full batch.
    """
    try:
        builder = _LOWERINGS[alg.name]
    except KeyError:
        raise NotImplementedError(
            f"no template lowering registered for algebra {alg.name!r}; "
            f"known: {sorted(_LOWERINGS)}") from None
    form = builder(alg)
    if alg.sparsity:
        form = _attach_sparsity(alg, form)
        keep = _batch_keep(alg, form)
        if keep is not None:
            form = _compact_batch(form, keep)
    return form


#: back-compat alias for the historic entry-point name
gemmize = lower_form
