"""TensorLib compile pipeline: (TensorAlgebra, Dataflow) -> executable.

Public API:
    lower                  — plan + lower + tile + cache -> CompiledKernel
    lower_form/LoweredForm — algebra lowering onto the batched-matmul
                             templates (grid-folded batch dims; ``gemmize``
                             / ``GemmForm`` kept as back-compat aliases)
    default_dataflow       — output-stationary STT over the first three loops
    cache_info / cache_clear / cache_resize — bounded-LRU compile cache

The paper's pipeline is ``algebra + STT -> dataflow -> hardware``; this
package is the last arrow on TPU: the dataflow classification selects a
Pallas template (core/plan.py), the algebra is lowered onto that
template's batched-matmul interface (lowering.py) so the executed MACs
equal the algebra's, and the shared batch-aware tile chooser
(core/tiling.py) fixes the block sizes the cost model already priced.
"""
from .lowering import (GemmForm, LoweredForm, OperandSparsity, gemmize,
                       lower_form)
from .pipeline import (CompiledKernel, DEFAULT_CACHE_CAPACITY,
                       VALIDATE_MACS_LIMIT, cache_clear, cache_info,
                       cache_resize, default_dataflow, lower)

__all__ = [
    "CompiledKernel", "DEFAULT_CACHE_CAPACITY", "GemmForm", "LoweredForm",
    "OperandSparsity", "VALIDATE_MACS_LIMIT", "cache_clear", "cache_info",
    "cache_resize", "default_dataflow", "gemmize", "lower", "lower_form",
]
