"""TensorLib compile pipeline: (TensorAlgebra, Dataflow) -> executable.

Public API:
    lower               — plan + GEMM-ize + tile + cache -> CompiledKernel
    gemmize / GemmForm  — algebra lowering onto the GEMM templates
    default_dataflow    — output-stationary STT over the first three loops
    cache_info / cache_clear — compile-cache introspection

The paper's pipeline is ``algebra + STT -> dataflow -> hardware``; this
package is the last arrow on TPU: the dataflow classification selects a
Pallas template (core/plan.py), the algebra is lowered onto that
template's GEMM interface (lowering.py), and the shared tile chooser
(core/tiling.py) fixes the block sizes the cost model already priced.
"""
from .lowering import GemmForm, gemmize
from .pipeline import (CompiledKernel, VALIDATE_MACS_LIMIT, cache_clear,
                       cache_info, default_dataflow, lower)

__all__ = [
    "CompiledKernel", "GemmForm", "VALIDATE_MACS_LIMIT",
    "cache_clear", "cache_info", "default_dataflow", "gemmize", "lower",
]
