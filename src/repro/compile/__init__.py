"""TensorLib compile pipeline: (TensorAlgebra, Dataflow) -> executable.

Public API:
    lower               — plan + GEMM-ize + tile + cache -> CompiledKernel
    gemmize / GemmForm  — algebra lowering onto the GEMM templates
    default_dataflow    — output-stationary STT over the first three loops
    cache_info / cache_clear / cache_resize — bounded-LRU compile cache

The paper's pipeline is ``algebra + STT -> dataflow -> hardware``; this
package is the last arrow on TPU: the dataflow classification selects a
Pallas template (core/plan.py), the algebra is lowered onto that
template's GEMM interface (lowering.py), and the shared tile chooser
(core/tiling.py) fixes the block sizes the cost model already priced.
"""
from .lowering import GemmForm, OperandSparsity, gemmize
from .pipeline import (CompiledKernel, DEFAULT_CACHE_CAPACITY,
                       VALIDATE_MACS_LIMIT, cache_clear, cache_info,
                       cache_resize, default_dataflow, lower)

__all__ = [
    "CompiledKernel", "DEFAULT_CACHE_CAPACITY", "GemmForm",
    "OperandSparsity", "VALIDATE_MACS_LIMIT", "cache_clear", "cache_info",
    "cache_resize", "default_dataflow", "gemmize", "lower",
]
