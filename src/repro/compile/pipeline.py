"""The plan -> executable pipeline:  (TensorAlgebra, Dataflow) -> callable.

This is the missing right half of the paper's Fig. 2 on the TPU retarget
(module selection *and connection*, §V): where the repo previously stopped
at ``KernelPlan.template`` — a string — ``lower`` turns the classification
into a runnable, validated kernel:

    1. ``plan.kernel_plan_for`` picks the Pallas template (paper's module
       selection, a total function of the classification),
    2. the algebra lowering (``lowering.lower_form``) maps the loop nest
       onto the template's batched-matmul interface (im2col /
       mode-unfolding / grid-folded batch dims — the paper's
       template-reuse claim, in code, executing exactly the algebra's
       MACs),
    3. the *shared*, batch-aware tile chooser (``core.tiling`` — the same
       one the cost model prices with) maps the STT tile onto Pallas block
       sizes via ``tiling.form_blocks``, replacing the historic
       hard-coded 128s,
    4. the result is cached on (algebra, dataflow, shapes, dtype,
       interpret, backend, array config) so serving / benchmark paths
       never re-trace, and
    5. small problems are validated against ``alg.reference`` at lower
       time (larger ones on demand via ``CompiledKernel.validate``).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import plan as plan_mod
from ..core import stt as stt_mod
from ..core import tiling
from ..core.algebra import TensorAlgebra
from ..core.costmodel import CostReport, PaperCycleModel
from ..core.stt import Dataflow
from ..core.tiling import ArrayConfig
from ..kernels import epilogue as epilogue_mod
from ..kernels import fused_chain as fused_chain_mod
from ..kernels import ops
from .lowering import LoweredForm, lower_form

#: auto-validate at lower time below this many MACs (a pure-python oracle
#: loop; ~1s at the limit, so big sweep/serving shapes skip it)
VALIDATE_MACS_LIMIT = 300_000


@dataclasses.dataclass
class CompiledKernel:
    """A lowered, executable tensor-algebra kernel.

    Call it with a dict of operand arrays (the algebra's input tensor
    names) and it returns the output tensor, computed by the Pallas
    template the dataflow classification selected.
    """

    algebra: TensorAlgebra
    dataflow: Dataflow
    plan: plan_mod.ExecutionPlan
    form: LoweredForm
    blocks: Tuple[int, int, int]        # (bm, bn, bk) from the STT tile
    stationary: str                     # GEMM operand pinned in VMEM
    cfg: ArrayConfig
    dtype: jnp.dtype
    interpret: bool
    backend: str
    #: measured-autotuning knobs (kernels/stt_gemm.py): contraction grid
    #: order and accumulation strategy; "default"/"auto" = the analytical
    #: pipeline's historic behavior
    grid_order: str = "default"
    accum: str = "auto"
    #: epilogue ops fused into the kernel's output-block flush
    #: (``kernels/epilogue.py``); () = plain algebra
    epilogue: Tuple[str, ...] = ()
    #: operand-dict key carrying the rank-1 bias vector a "bias" epilogue
    #: op streams (not an algebra tensor; None when the epilogue has none)
    bias_tensor: Optional[str] = None
    #: identity of the fused graph group this kernel was lowered for
    #: (``repro.graph``); part of the compile/tune cache key so a
    #: block-constrained fused lowering never aliases the standalone one
    fused_group: Optional[str] = None
    #: where the blocks/knobs came from: "analytical" (shared tile
    #: chooser) or "tuned" (measured-autotuning cache, repro.tune)
    source: str = "analytical"
    #: median measured wall-clock seconds for this kernel, when the tuner
    #: has timed it (drives CostReport.measured_cycles)
    measured_s: Optional[float] = None
    validated: bool = False
    _report: Optional[CostReport] = dataclasses.field(
        default=None, repr=False)

    @property
    def template(self) -> str:
        return self.plan.kernel.template

    @property
    def gemm(self) -> LoweredForm:
        """Back-compat accessor: the lowered form (historic field name)."""
        return self.form

    @property
    def sparse(self):
        """The structured block-sparse operand (OperandSparsity) or None."""
        return self.form.sparse

    @property
    def sparse_mode(self) -> str:
        """``bsr`` (grid skips zero blocks), ``masked`` (sparse algebra,
        dense execution on zero-masked operands; batched forms skip
        all-zero batch slices — see ``LoweredForm.batch_keep``), or
        ``dense``."""
        if self.form.sparse is not None:
            return "bsr"
        return "masked" if self.algebra.is_sparse else "dense"

    def partition_for(self, shape: Tuple[int, int],
                      axes: Tuple[str, str] = ("x", "y"), *,
                      shard_batch: bool = True,
                      compressed: Optional[bool] = None):
        """Solve this kernel's mesh partition for a mesh shape without
        binding devices (:func:`repro.core.plan.solve_partition` over the
        generated CommPlan + this LoweredForm) — what the cost model, the
        DSE and ``Accelerator.describe()`` consume."""
        return plan_mod.solve_partition(
            self.plan.comm, self.form, axes=axes, shape=shape,
            shard_batch=shard_batch, compressed=compressed)

    def cast_operands(self, operands: Dict[str, jax.Array]
                      ) -> Dict[str, jax.Array]:
        """Cast to the kernel dtype and *enforce* every attached sparsity
        pattern (zero outside the nonzero blocks).  Masking here makes the
        pattern part of the kernel's semantics on every path: the BSR grid
        (which never reads out-of-pattern blocks), the masked-dense
        fallback, and the mesh program all compute the same function of
        the same operands — even when a caller passes unmasked data."""
        cast = {name: jnp.asarray(v).astype(self.dtype)
                for name, v in operands.items()}
        for name, sp in self.algebra.sparsity:
            t = next(t for t in self.algebra.tensors if t.name == name)
            mask = jnp.asarray(sp.element_mask(self.algebra.tensor_shape(t)))
            # select, don't multiply: out-of-pattern inf/nan must drop out
            cast[name] = jnp.where(mask, cast[name],
                                   jnp.zeros((), self.dtype))
        return cast

    def __call__(self, operands: Dict[str, jax.Array]) -> jax.Array:
        bias = None
        if self.bias_tensor is not None:
            if self.bias_tensor not in operands:
                raise ValueError(
                    f"kernel has a fused bias epilogue: operands must "
                    f"include {self.bias_tensor!r}")
            operands = dict(operands)
            bias = jnp.asarray(operands.pop(self.bias_tensor),
                               jnp.float32)
        cast = self.cast_operands(operands)
        lhs, rhs = self.form.prepare(cast)
        bm, bn, bk = self.blocks
        sp = self.form.sparse
        if sp is not None:
            sp_arr, dense_arr = (lhs, rhs) if sp.side == "lhs" else (rhs, lhs)
            out2d = ops.bsr_matmul(
                sp_arr, dense_arr, coords=sp.coords, block=sp.block,
                bstream=bn if sp.side == "lhs" else bm, side=sp.side,
                backend=self.backend, interpret=self.interpret)
            if self.epilogue:
                # the BSR grid has no epilogue flush point yet; apply on
                # the full 2-D output (same math, one extra VMEM pass)
                out2d = epilogue_mod.apply_epilogue(
                    out2d.astype(jnp.float32), self.epilogue,
                    bias=bias).astype(self.dtype)
        else:
            out2d = ops.stt_matmul(
                lhs, rhs, template=self.template, stationary=self.stationary,
                bm=bm, bn=bn, bk=bk, backend=self.backend,
                interpret=self.interpret,
                vmem_budget=self.cfg.vmem_budget_bytes,
                grid_order=self.grid_order, accum=self.accum,
                epilogue=self.epilogue, bias=bias)
        return self.form.finish(out2d)

    def validate(self, seed: int = 0, atol: float = 1e-3) -> float:
        """Execute on random operands and compare against the loop-nest
        oracle ``alg.reference`` (composed with the numpy epilogue mirror
        when ops are fused).  Returns the max abs error; raises on
        mismatch.  Integer-valued operands make the fp32 path exact for
        every registry shape that fits the oracle."""
        operands = dict(self.algebra.random_operands(seed))
        bias = None
        if self.bias_tensor is not None:
            n_last = self.algebra.tensor_shape(self.algebra.output)[-1]
            bias = np.random.default_rng(seed + 1).integers(
                -4, 5, size=(n_last,)).astype(np.float64)
            operands[self.bias_tensor] = bias
        got = np.asarray(self(operands), dtype=np.float64)
        want = self.algebra.reference(
            {k: v for k, v in operands.items()
             if k != self.bias_tensor}).astype(np.float64)
        if self.epilogue:
            want = epilogue_mod.apply_epilogue_np(want, self.epilogue,
                                                  bias=bias)
        err = float(np.abs(got - want).max()) if got.size else 0.0
        if got.shape != want.shape or err > atol:
            raise AssertionError(
                f"lowered {self.algebra.name} x {self.dataflow.name} "
                f"diverged from reference: shape {got.shape} vs "
                f"{want.shape}, max err {err:.3e}")
        self.validated = True
        return err

    def cost_report(self) -> CostReport:
        """The cost model's view of this exact (algebra, dataflow, config)
        — same tile chooser, so priced and executed tiles agree.  When the
        measured autotuner has timed this kernel (``measured_s``), the
        report carries the measurement as ``measured_cycles`` at the
        model's clock, so modeled and measured sit side by side."""
        if self._report is None:
            self._report = PaperCycleModel(self.cfg).evaluate(
                self.algebra, self.dataflow)
        if self.measured_s is not None:
            mc = self.measured_s * self.cfg.freq_mhz * 1e6
            if self._report.measured_cycles != mc:
                # re-attach on every change: the compile cache shares this
                # object, and a re-tune may update measured_s in place
                self._report = dataclasses.replace(
                    self._report, measured_cycles=mc)
        return self._report


# ---------------------------------------------------------------------------
# Compile cache — bounded LRU, safe under concurrent lowers (serving
# processes lower from request threads; an unbounded dict would grow with
# every distinct shape and race on simultaneous inserts).
# ---------------------------------------------------------------------------

#: default cap; generous for benchmarks (the full registry x named-STT
#: matrix is 24 entries) while bounding long-running serving processes.
DEFAULT_CACHE_CAPACITY = 256

_CACHE: "collections.OrderedDict[Tuple, CompiledKernel]" = (
    collections.OrderedDict())
_CACHE_LOCK = threading.Lock()
_CAPACITY = DEFAULT_CACHE_CAPACITY
_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _cache_key(alg: TensorAlgebra, df: Dataflow, cfg: ArrayConfig,
               dtype, interpret: bool, backend: str,
               epilogue: Tuple[str, ...] = (),
               bias_tensor: Optional[str] = None,
               fused_group: Optional[str] = None) -> Tuple:
    # alg is a frozen dataclass of tuples: it *is* the algebra signature
    # (name + loops + bounds/shapes + access matrices + sparsity), and the
    # LoweredForm — batch grid dims included — is a pure function of it,
    # so the key needs no separate form component.  The dataflow key adds
    # the selection, the exact T and the per-tensor classification.
    #
    # This tuple is also the identity the on-disk *tuning* cache hashes
    # (repro.tune.cache.key_for): a tuned variant applies exactly where
    # the compiled kernel it was measured on would be reused.  The
    # epilogue spec and the fused-group id are part of that identity: an
    # epilogue'd kernel computes a different function, and a fused-graph
    # lowering constrains the block schedule — a variant tuned for the
    # standalone algebra must not be replayed for either.
    return (alg, df.selected, df.T, df.signature, cfg,
            jnp.dtype(dtype).name, interpret, backend,
            tuple(epilogue), bias_tensor, fused_group)


def _variant_key(key: Tuple, blocks, grid_order: str, accum: str) -> Tuple:
    """Extend the base key with the knob values a kernel was built with
    (``blocks=None`` = the analytical tile chooser's blocks, which are a
    pure function of the base key)."""
    return key + (blocks, grid_order, accum)


def cache_info() -> Dict[str, int]:
    with _CACHE_LOCK:
        return {"size": len(_CACHE), "capacity": _CAPACITY, **_STATS}


def cache_clear() -> None:
    with _CACHE_LOCK:
        _CACHE.clear()
        _STATS["hits"] = _STATS["misses"] = _STATS["evictions"] = 0


def cache_resize(capacity: int) -> None:
    """Set the LRU capacity, evicting least-recently-used entries now if
    the cache is over the new cap."""
    if capacity < 1:
        raise ValueError("cache capacity must be >= 1")
    global _CAPACITY
    with _CACHE_LOCK:
        _CAPACITY = capacity
        while len(_CACHE) > _CAPACITY:
            _CACHE.popitem(last=False)
            _STATS["evictions"] += 1


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def default_dataflow(alg: TensorAlgebra) -> Dataflow:
    """A sane default schedule: output-stationary STT over the first three
    loop iterators (every Table II algebra admits it)."""
    return stt_mod.apply_stt(alg, alg.loops[:3],
                             stt_mod.stt_from_name("output_stationary"))


def _blocks_from_tile(alg: TensorAlgebra, df: Dataflow, form: LoweredForm,
                      cfg: ArrayConfig) -> Tuple[int, int, int]:
    """Map the STT tile (per selected loop) onto GEMM block sizes via the
    shared, batch-aware chooser (``core.tiling.form_blocks``): loops
    folded onto the batch grid dims never inflate a block."""
    return tiling.form_blocks(alg, df, form, cfg.pe_dims)


def _epilogue_legal_for_form(alg: TensorAlgebra, form: LoweredForm,
                             epilogue: Tuple[str, ...]) -> Optional[str]:
    """Why this epilogue cannot ride this lowered form (None = legal).

    Elementwise ops commute with the finish reshape, so they are legal on
    every form.  ``bias`` / ``softmax`` act along the last axis: they are
    only legal when the finished tensor's last axis *is* the matmul n
    axis (gemm's identity finish is the canonical case) — otherwise the
    2-D in-kernel application and the finished-tensor semantics diverge.
    """
    rowwise = (epilogue_mod.needs_bias(epilogue)
        or epilogue_mod.has_softmax(epilogue))
    if not rowwise:
        return None
    out_shape = alg.tensor_shape(alg.output)
    if form.batch or out_shape[-1] != form.n:
        return (f"bias/softmax epilogue acts on the matmul n axis "
                f"(n={form.n}) but the finished output {out_shape} of "
                f"{alg.name} does not end with it")
    return None


def lower(alg: TensorAlgebra, df: Optional[Dataflow] = None, *,
          cfg: ArrayConfig = ArrayConfig(),
          dtype=jnp.float32, interpret: bool = False,
          backend: str = "pallas",
          validate: Optional[bool] = None,
          blocks: Optional[Tuple[int, int, int]] = None,
          grid_order: Optional[str] = None,
          accum: Optional[str] = None,
          tuned: Optional[bool] = None,
          epilogue: Sequence[str] = (),
          bias_tensor: Optional[str] = None,
          fused_group: Optional[str] = None) -> CompiledKernel:
    """Lower ``(algebra, dataflow)`` to an executable, cached kernel.

    ``validate=None`` (default) auto-validates against ``alg.reference``
    when the problem is small enough for the python oracle; pass True to
    force (may be slow) or False to skip.

    ``blocks`` / ``grid_order`` / ``accum`` override the analytical tile
    chooser and the kernel-knob defaults (the measured autotuner's search
    axes).  When none are given and ``tuned`` is not False, the on-disk
    tuning cache (``repro.tune``) is consulted first — a persisted winner
    for this exact compile key replaces the analytical choice, which is
    how a ``repro.tune.tune()`` run keeps paying off in later processes.

    ``epilogue`` fuses post-processing ops (``kernels/epilogue.py``) into
    the kernel's output-block flush; a ``"bias"`` op names its extra
    rank-1 operand via ``bias_tensor`` (the ``__call__`` dict key).
    ``fused_group`` tags a lowering constrained by a fused graph
    (``repro.graph``); all three enter the compile *and* tuning cache
    keys, so standalone and fused variants never alias.
    """
    if df is None:
        df = default_dataflow(alg)
    if df.algebra_name != alg.name:
        raise ValueError(f"dataflow {df.name} was generated for algebra "
                         f"{df.algebra_name!r}, not {alg.name!r}")
    epilogue = epilogue_mod.validate_spec(epilogue)
    if epilogue_mod.needs_bias(epilogue) and bias_tensor is None:
        raise ValueError("epilogue with a 'bias' op needs bias_tensor= "
                         "(the operand-dict key of the bias vector)")
    if bias_tensor is not None and not epilogue_mod.needs_bias(epilogue):
        raise ValueError("bias_tensor= given but the epilogue has no "
                         "'bias' op")
    if bias_tensor is not None and any(t.name == bias_tensor
                                       for t in alg.tensors):
        raise ValueError(f"bias_tensor {bias_tensor!r} collides with an "
                         f"algebra tensor name")
    key = _cache_key(alg, df, cfg, dtype, interpret, backend,
                     epilogue, bias_tensor, fused_group)
    source, measured_s = "analytical", None
    if (blocks is None and grid_order is None and accum is None
            and tuned is not False):
        # consult the measured-tuning cache before the analytical chooser
        from ..tune import cache as tune_cache
        entry = tune_cache.lookup_variant(tune_cache.key_of(key))
        if entry is not None:
            blocks = tuple(entry["blocks"])
            grid_order = entry["grid_order"]
            accum = entry["accum"]
            source = "tuned"
            measured_s = entry.get("measured_s")
    grid_order = "default" if grid_order is None else grid_order
    accum = "auto" if accum is None else accum
    key = _variant_key(key, blocks, grid_order, accum)
    with _CACHE_LOCK:
        hit = _CACHE.get(key)
        if hit is not None:
            _STATS["hits"] += 1
            _CACHE.move_to_end(key)
        else:
            _STATS["misses"] += 1
    if hit is not None:
        if not hit.validated and (
                validate or (validate is None
                             and alg.total_macs() <= VALIDATE_MACS_LIMIT)):
            # an earlier lower(validate=False) cached it unvalidated;
            # honour the explicit or auto-validate request now (outside
            # the lock — the python oracle can be slow)
            hit.validate()
        return hit

    ep = plan_mod.plan_for(
        df, densities={name: alg.density_of(name) for name, _ in alg.sparsity})
    form = lower_form(alg)
    if epilogue:
        reason = _epilogue_legal_for_form(alg, form, epilogue)
        if reason is not None:
            raise ValueError(reason)
    if blocks is None:
        blocks = _blocks_from_tile(alg, df, form, cfg)
    if epilogue_mod.has_softmax(epilogue) and blocks[1] != form.n:
        # a row softmax needs the whole unpadded row in one block
        blocks = (blocks[0], form.n, blocks[2])
    stationary = ("A" if ep.kernel.resident_tensor in form.lhs_tensors
        else "B")
    kernel = CompiledKernel(
        algebra=alg, dataflow=df, plan=ep, form=form, blocks=blocks,
        stationary=stationary, cfg=cfg, dtype=jnp.dtype(dtype),
        interpret=interpret, backend=backend,
        epilogue=epilogue, bias_tensor=bias_tensor,
        fused_group=fused_group,
        grid_order=grid_order, accum=accum, source=source,
        measured_s=measured_s)
    if validate or (validate is None
                    and alg.total_macs() <= VALIDATE_MACS_LIMIT):
        kernel.validate()
    with _CACHE_LOCK:
        prior = _CACHE.get(key)
        if prior is not None:
            # a concurrent lower built the same kernel first; keep the
            # cached one so callers always share a single object per key
            _CACHE.move_to_end(key)
            return prior
        _CACHE[key] = kernel
        while len(_CACHE) > _CAPACITY:
            _CACHE.popitem(last=False)
            _STATS["evictions"] += 1
    return kernel


# ---------------------------------------------------------------------------
# Merged fused-group lowering — one CompiledGroupKernel per chain
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledGroupKernel:
    """An entire fused graph group lowered to ONE Pallas kernel.

    Two templates share this wrapper.  ``kind == "chain"`` (the streamed
    lhs ladder): ``__call__(lhs, rhss, biases)`` takes the group's
    external operands in *storage* layout (gemm weights are ``(n, k)``;
    the transpose the per-node ``prepare`` would apply happens here) and
    returns the group's result edge.  ``kind == "dag"`` (stage-major:
    rhs-landing edges, batched stages, residuals, taps):
    ``__call__(exts)`` takes ONE sequence of external operands matching
    ``ext_roles`` order — again in storage layout, role casts applied
    here — and returns ``(result, *taps)``.  Either way every
    non-tapped intermediate stays in VMEM scratch inside the single
    ``pallas_call`` (``kernels/fused_chain.py``).
    """

    group: str                          # FusedGroupPlan.name
    stages: Tuple[str, ...]             # member node names (labels)
    chain: Tuple[fused_chain_mod.ChainStage, ...]
    m: int
    k0: int
    bm: int                             # m-block (grid phases)
    interleave: str                     # "chain" | "stage" | "dag"
    cfg: ArrayConfig
    dtype: jnp.dtype
    interpret: bool
    backend: str
    kind: str = "chain"                 # "chain" | "dag"
    dag: Tuple[fused_chain_mod.DagStage, ...] = ()
    ext_roles: Tuple[Tuple[str, str], ...] = ()     # (edge, role)
    ext_shapes: Tuple[Tuple[int, ...], ...] = ()    # storage shapes
    n_tap: int = 0
    #: where bm/interleave came from: "analytical" (the plan's agreed
    #: blocks) or "tuned" (the on-disk group tuning cache)
    source: str = "analytical"
    #: merged / sequential medians when the group tuner measured them
    measured_s: Optional[float] = None
    sequential_s: Optional[float] = None
    validated: bool = False
    #: the jitted end-to-end entry (casts + transposes + megakernel in
    #: ONE dispatch — per-call eager ops would cost more than the merge
    #: saves); built lazily on first call
    _fn: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    def total_macs(self) -> int:
        if self.kind == "dag":
            return sum(st.m * st.k * st.n for st in self.dag)
        return sum(self.m * st.k * st.n for st in self.chain)

    @staticmethod
    def _dag_prep(ext, role, dtype):
        """Storage layout -> kernel-facing layout, per operand role."""
        if role == "rhs":
            return ext.astype(dtype).T          # (n, k) storage -> (k, n)
        if role == "res":
            return ext.astype(jnp.float32)
        if role == "bias":
            return ext.astype(jnp.float32).reshape(1, -1)
        return ext.astype(dtype)                # lhs / a3d / vec

    def _build_fn(self):
        dtype, interpret = self.dtype, self.interpret
        xla = self.backend == "xla"
        if self.kind == "dag":
            dag, roles = self.dag, tuple(r for _, r in self.ext_roles)

            @jax.jit
            def fn(exts):
                prepped = tuple(self._dag_prep(e, role, dtype)
                                for e, role in zip(exts, roles))
                if xla:
                    return fused_chain_mod.dag_reference(
                        prepped, stages=dag, out_dtype=dtype)
                return fused_chain_mod.fused_dag(
                    prepped, stages=dag, out_dtype=dtype,
                    interpret=interpret)

            return fn
        stages, out_name = self.chain, dtype.name
        bm, interleave = self.bm, self.interleave

        @jax.jit
        def fn(lhs, rhss, biases):
            lhs = lhs.astype(dtype)
            # gemm stores B as (n, k); the merged template wants (k, n)
            rhs_kn = tuple(r.astype(dtype).T for r in rhss)
            rows = tuple(b.astype(jnp.float32).reshape(-1) for b in biases)
            if xla:
                return fused_chain_mod.chain_reference(
                    lhs, *rhs_kn, *(r.reshape(1, -1) for r in rows),
                    stages=stages, out_dtype=out_name)
            return fused_chain_mod.fused_chain_matmul(
                lhs, rhs_kn, rows, stages=stages, bm=bm,
                interleave=interleave, out_dtype=dtype,
                interpret=interpret)

        return fn

    def __call__(self, lhs, rhss: Sequence[jax.Array] = (),
                 biases: Sequence[jax.Array] = ()):
        if self._fn is None:
            self._fn = self._build_fn()
        if self.kind == "dag":
            # single argument: the ext_roles-ordered operand sequence
            return self._fn(tuple(jnp.asarray(e) for e in lhs))
        return self._fn(jnp.asarray(lhs),
                        tuple(jnp.asarray(r) for r in rhss),
                        tuple(jnp.asarray(b) for b in biases))

    def validate(self, seed: int = 0, atol: float = 1e-3,
                 rtol: Optional[float] = None) -> float:
        """Run on random integer operands and compare against the fp64
        numpy chain oracle (dot + ``apply_epilogue_np`` per stage).
        ``rtol`` scales with the output magnitude (a chain compounds
        rounding); defaults per dtype."""
        if rtol is None:
            rtol = 1e-5 if self.dtype == jnp.float32 else 2e-2
        rng = np.random.default_rng(seed)
        if self.kind == "dag":
            return self._validate_dag(rng, atol, rtol)
        lhs = rng.integers(-4, 5, size=(self.m, self.k0))
        rhss = [rng.integers(-4, 5, size=(st.n, st.k))
                for st in self.chain]
        biases = [rng.integers(-4, 5, size=(st.n,))
                  for st in self.chain if st.has_bias]
        got = np.asarray(self(lhs, rhss, biases), dtype=np.float64)
        x = lhs.astype(np.float64)
        bi = 0
        for st, r in zip(self.chain, rhss):
            x = x @ r.T.astype(np.float64)
            if st.epilogue:
                b = None
                if st.has_bias:
                    b = biases[bi].astype(np.float64)
                    bi += 1
                x = epilogue_mod.apply_epilogue_np(x, st.epilogue, bias=b)
        want = x
        err = float(np.abs(got - want).max()) if got.size else 0.0
        bound = atol + rtol * (float(np.abs(want).max()) if want.size
                               else 0.0)
        if got.shape != want.shape or err > bound:
            raise AssertionError(
                f"merged group {self.group} diverged from the chain "
                f"oracle: shape {got.shape} vs {want.shape}, max err "
                f"{err:.3e} (bound {bound:.3e})")
        self.validated = True
        return err

    def _validate_dag(self, rng, atol: float, rtol: float) -> float:
        """DAG branch of :meth:`validate`: random integer operands in
        storage layout, compared (result + every tap) against a fp64
        numpy mirror of the stage list."""
        exts = [rng.integers(-4, 5, size=shape)
                for shape in self.ext_shapes]
        got = tuple(np.asarray(o, dtype=np.float64) for o in self(exts))
        prepped = []
        for e, (_, role) in zip(exts, self.ext_roles):
            a = e.astype(np.float64)
            prepped.append(a.T if role == "rhs" else a)
        vals: list = []
        taps: dict = {}
        for st in self.dag:
            def fetch(src, transpose=False):
                where, idx = src
                buf = prepped[idx] if where == "ext" else vals[idx]
                return buf.T if transpose else buf
            if st.kind == "batched":
                acc = np.einsum("bkn,bk->bn", fetch(st.lhs),
                                fetch(st.rhs))
            else:
                acc = fetch(st.lhs) @ fetch(
                    st.rhs, transpose=st.rhs[0] == "scr")
            if st.epilogue:
                b = (prepped[st.bias].reshape(-1) if st.has_bias
                     else None)
                acc = epilogue_mod.apply_epilogue_np(acc, st.epilogue,
                                                     bias=b)
            y = acc
            if st.res is not None:
                y = y + fetch(st.res)
            vals.append(y)
            if st.tap >= 0:
                taps[st.tap] = y
        wants = (vals[-1],) + tuple(taps[i] for i in sorted(taps))
        err_max = 0.0
        for which, (g, want) in enumerate(zip(got, wants)):
            err = float(np.abs(g - want).max()) if g.size else 0.0
            bound = atol + rtol * (float(np.abs(want).max())
                                   if want.size else 0.0)
            if g.shape != want.shape or err > bound:
                what = "result" if which == 0 else f"tap {which - 1}"
                raise AssertionError(
                    f"merged group {self.group} {what} diverged from "
                    f"the DAG oracle: shape {g.shape} vs {want.shape}, "
                    f"max err {err:.3e} (bound {bound:.3e})")
            err_max = max(err_max, err)
        self.validated = True
        return err_max


def _group_cache_key(plan, group, interpret: bool, backend: str) -> Tuple:
    """The merged-kernel compile/tune-cache identity: ``_cache_key``'s
    per-node components *extended with the stage list* — each stage
    contributes its algebra, dataflow identity, epilogue spec and bias
    presence, in chain order — plus the shared config/dtype/backend.
    Two graphs whose fused chains are structurally identical share the
    entry regardless of node or edge naming.  A ``kind="dag"`` group
    keys on its bound stage list + operand-role order instead — the
    dag template ignores per-node dataflows (everything is whole-tensor
    stage-major), and the hashable :class:`DagStage` tuple already
    encodes shapes, wiring, epilogues and taps."""
    if getattr(group, "kind", "chain") == "dag":
        return ("fused_dag", group.dag,
                tuple(role for _, role in group.ext_inputs),
                plan.cfg, str(plan.dtype), bool(interpret), str(backend))
    stage_ids = []
    for name in group.stages:
        p = plan.nodes[name]
        stage_ids.append((p.node.algebra, p.dataflow.selected,
                          p.dataflow.T, p.dataflow.signature,
                          p.epilogue, p.bias_edge is not None))
    return ("fused_chain", tuple(stage_ids), plan.cfg, str(plan.dtype),
            bool(interpret), str(backend))


def _group_variant_key(key: Tuple, bm: int, interleave: str) -> Tuple:
    return key + (int(bm), str(interleave))


def lower_group(plan, group, *, interpret: bool = False,
                backend: str = "pallas",
                validate: Optional[bool] = None,
                bm: Optional[int] = None,
                interleave: Optional[str] = None,
                tuned: Optional[bool] = None
                ) -> Optional[CompiledGroupKernel]:
    """Lower a :class:`~repro.graph.planner.FusedGroupPlan` to a single
    cached :class:`CompiledGroupKernel` (one ``pallas_call`` for the
    whole chain).

    ``bm`` / ``interleave`` override the plan's agreed m-block and the
    default stage order (the merged-kernel tuner's knobs).  When neither
    is given and ``tuned`` is not False, the on-disk group tuning cache
    is consulted first: a persisted winner supplies the knobs — and a
    persisted *sequential* verdict makes this return ``None``, telling
    the executor to keep per-node dispatch (the tuner measured merged
    slower on this machine).
    """
    if not group.eligible:
        raise ValueError(f"group {group.name} is not merged-eligible: "
                         f"{group.reason}")
    key = _group_cache_key(plan, group, interpret, backend)
    source, measured_s, sequential_s = "analytical", None, None
    if bm is None and interleave is None and tuned is not False:
        from ..tune import cache as tune_cache
        entry = tune_cache.lookup_group(tune_cache.key_of(key))
        if entry is not None:
            if not entry["merged"]:
                return None             # measured verdict: keep sequential
            bm = int(entry["bm"])
            interleave = entry["interleave"]
            source = "tuned"
            measured_s = entry.get("merged_s")
            sequential_s = entry.get("sequential_s")
    is_dag = getattr(group, "kind", "chain") == "dag"
    bm = group.bm if bm is None else bm
    if interleave is None:
        interleave = (fused_chain_mod.DAG_INTERLEAVE if is_dag
                      else "chain")
    allowed = ((fused_chain_mod.DAG_INTERLEAVE,) if is_dag
               else fused_chain_mod.FUSED_INTERLEAVES)
    if interleave not in allowed:
        raise ValueError(f"interleave must be one of {allowed}, "
                         f"got {interleave!r}")
    key = _group_variant_key(key, bm, interleave)
    with _CACHE_LOCK:
        hit = _CACHE.get(key)
        if hit is not None:
            _STATS["hits"] += 1
            _CACHE.move_to_end(key)
        else:
            _STATS["misses"] += 1
    if hit is not None:
        if not hit.validated and (
                validate or (validate is None
                             and hit.total_macs() <= VALIDATE_MACS_LIMIT)):
            hit.validate()
        return hit
    ext_shapes = (tuple(plan.graph.edge_shape(e)
                        for e, _ in group.ext_inputs) if is_dag else ())
    kernel = CompiledGroupKernel(
        group=group.name, stages=tuple(group.stages), chain=group.chain,
        m=group.m, k0=group.k0, bm=bm, interleave=interleave,
        cfg=plan.cfg, dtype=jnp.dtype(plan.dtype), interpret=interpret,
        backend=backend, source=source, measured_s=measured_s,
        sequential_s=sequential_s,
        kind="dag" if is_dag else "chain",
        dag=group.dag if is_dag else (),
        ext_roles=tuple(group.ext_inputs) if is_dag else (),
        ext_shapes=ext_shapes,
        n_tap=len(group.taps) if is_dag else 0)
    if validate or (validate is None
                    and kernel.total_macs() <= VALIDATE_MACS_LIMIT):
        kernel.validate()
    with _CACHE_LOCK:
        prior = _CACHE.get(key)
        if prior is not None:
            _CACHE.move_to_end(key)
            return prior
        _CACHE[key] = kernel
        while len(_CACHE) > _CAPACITY:
            _CACHE.popitem(last=False)
            _STATS["evictions"] += 1
    return kernel
