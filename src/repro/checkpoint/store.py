"""Checkpointing: atomic, async, elastic.

Design (no orbax in this container — and the deliverables require the
substrate built in-repo):

  * layout: one .npz per checkpoint (leaf path -> array) + manifest.json
    with step, pytree structure and logical shapes,
  * atomicity: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash
    mid-save never corrupts the latest checkpoint (tested by killing a save),
  * async: ``save_async`` snapshots device arrays to host then hands the
    file I/O to a daemon thread, so the train loop overlaps step compute
    with checkpoint writes,
  * elastic: checkpoints carry *logical* arrays only; ``restore`` takes the
    target sharding pytree, so a run saved on N devices restores onto any
    mesh (tested 8 -> 4 -> 1 devices in CI).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *,
         extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten_with_paths(tree)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "keys": sorted(flat),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread; write on a daemon thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> None:
        self.wait()                              # one in flight at a time
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:           # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.isfile(
                os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree of jax.sharding.Sharding — arrays are
    device_put with the *target* sharding, which is what makes restores
    elastic across mesh shapes (logical shapes are mesh-independent).
    Incomplete checkpoints are impossible by construction (atomic rename);
    a missing directory raises FileNotFoundError.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree.structure(tree_like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_with_paths))
    out = []
    for (path_keys, like), shd in zip(leaves_with_paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = data[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs model {like.shape}")
        arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step, manifest.get("extra", {})
