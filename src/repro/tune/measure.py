"""The shared wall-clock measurement harness (ISSUE 6 satellite 2).

One deterministic timing loop for the whole repo: the measured autotuner
(``repro.tune.tuner``), the perf benchmarks (``benchmarks/*.py``) and the
calibration fit all time through :func:`measure`, so every number the
tuning cache persists and every number a benchmark prints was produced
the same way —

  * a fixed number of **warmup** calls runs first (compilation/tracing
    lands outside the clock),
  * each timed call blocks on the result (``jax.block_until_ready`` — a
    dispatch-only time would flatter every asynchronous backend),
  * the reported statistic is the **median** of ``repeats`` timed calls
    (robust to one-off scheduler noise; the min and mean are kept for
    benchmarks that historically printed best-of).

The harness is backend-agnostic: it times whatever callable it is given,
so interpret-mode Pallas (the CPU fallback every environment can run),
compiled Mosaic on a real TPU, and plain XLA baselines all measure
identically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Tuple

#: defaults shared by the tuner, the benchmarks and the CI smoke step
DEFAULT_WARMUP = 1
DEFAULT_REPEATS = 5


def _block(x) -> None:
    """Block until ``x`` (array or pytree of arrays) is ready."""
    try:
        import jax
        jax.block_until_ready(x)
    except (ImportError, AttributeError):
        if hasattr(x, "block_until_ready"):
            x.block_until_ready()


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One harness run: every timed sample plus the warmup cost."""

    times_s: Tuple[float, ...]
    warmup_s: float

    @property
    def median_s(self) -> float:
        s = sorted(self.times_s)
        n = len(s)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    @property
    def best_s(self) -> float:
        return min(self.times_s)

    @property
    def mean_s(self) -> float:
        return sum(self.times_s) / len(self.times_s)

    def cycles(self, freq_mhz: float) -> float:
        """The median expressed in cycles of a ``freq_mhz`` clock — the
        unit the calibration fit compares against ``CostReport.cycles``."""
        return self.median_s * freq_mhz * 1e6


def measure(fn: Callable, *args, warmup: int = DEFAULT_WARMUP,
            repeats: int = DEFAULT_REPEATS, **kwargs) -> Measurement:
    """Time ``fn(*args, **kwargs)``: warmup outside the clock, then
    median-of-``repeats`` with ``block_until_ready`` on every result."""
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    t0 = time.perf_counter()
    for _ in range(max(0, warmup)):
        _block(fn(*args, **kwargs))
    warmup_s = time.perf_counter() - t0
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return Measurement(times_s=tuple(times), warmup_s=warmup_s)
