"""The on-disk tuning cache: measured winners that outlive the process.

The compile cache (``compile/pipeline.py``) is in-memory and per-process;
measurement is expensive, so the autotuner persists its winners here and
``lower()`` consults this cache *before* the analytical tile chooser.
Two maps live in one JSON document (``tune_cache.json``):

``variants``
    compile-key -> the winning kernel variant (blocks, grid_order, accum,
    measured seconds).  The key is the **same tuple** the compile cache
    uses (``pipeline._cache_key``) hashed with sha256 over its ``repr``
    — Python's builtin ``hash`` is randomized per process, so it cannot
    key an on-disk store.  A tuned variant therefore applies exactly
    where the compiled kernel it was measured on would be reused.

``choices``
    algebra-level key (no dataflow) -> the winning *dataflow* choice
    (selected loops + T matrix) plus its variant, so a second
    ``tune()`` call on the same shape is a pure cache hit — no search,
    no measurement, no candidate lowering.

``groups``
    fused-group key (``pipeline._group_cache_key``) -> the measured
    merged-vs-sequential verdict for one graph chain: ``merged`` plus
    (when merged won) the winning ``bm``/``interleave`` knobs.  A
    ``merged: False`` entry is a real hit — it tells ``lower_group``
    to return None so the executor keeps per-node dispatch.

Robustness contract (ISSUE 6 satellite 3): a corrupt or truncated cache
file degrades to a warning plus the analytical fallback (never an
exception on the lower path); entries are version-stamped and silently
dropped on schema mismatch; writes are atomic (temp file + ``os.replace``)
so a crashed writer cannot corrupt readers; ``cache_info()`` exposes
hit/miss/store/invalid/corrupt counters for tests and benchmarks.

Location: ``$REPRO_TUNE_CACHE`` if set, else ``~/.cache/repro-tune``.
The env var is re-read on every call so tests can point each case at a
fresh tmpdir.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
import warnings
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

#: bump when the entry layout changes; mismatched entries are dropped
SCHEMA_VERSION = 1

_FILENAME = "tune_cache.json"
_ENV = "REPRO_TUNE_CACHE"

_LOCK = threading.RLock()
#: (path, stat) -> parsed doc, so the hot lower() path stats instead of
#: re-parsing; invalidated whenever the file changes or the env moves
_MEMO: Dict[str, Any] = {"path": None, "stat": None, "doc": None}
_STATS = {"hits": 0, "misses": 0, "stores": 0, "invalid": 0, "corrupt": 0}


def cache_dir() -> Path:
    """Resolve the cache directory (env var first, re-read every call)."""
    env = os.environ.get(_ENV)
    if env:
        return Path(env).expanduser()
    return Path.home() / ".cache" / "repro-tune"


def cache_path() -> Path:
    return cache_dir() / _FILENAME


def key_of(key_tuple: Tuple) -> str:
    """Stable cross-process digest of a compile-cache key tuple.

    The tuple is made of frozen dataclasses, strings, ints and numpy
    array reprs — all with deterministic ``repr`` — so sha256 over the
    repr is stable where builtin ``hash`` (randomized per process) is
    not.
    """
    return hashlib.sha256(repr(key_tuple).encode()).hexdigest()


def _empty_doc() -> Dict[str, Any]:
    return {"version": SCHEMA_VERSION, "variants": {}, "choices": {},
            "groups": {}}


def _load() -> Dict[str, Any]:
    """Parse (or reuse the memoized parse of) the cache document.

    Never raises: missing file -> empty doc; unparseable file -> one
    warning + empty doc (counted in ``corrupt``); wrong document version
    -> entries dropped (counted in ``invalid``).
    """
    path = cache_path()
    try:
        st = path.stat()
        stat = (st.st_mtime_ns, st.st_size)
    except OSError:
        stat = None
    with _LOCK:
        if (_MEMO["path"] == str(path) and _MEMO["stat"] == stat
                and _MEMO["doc"] is not None):
            return _MEMO["doc"]
    if stat is None:
        doc = _empty_doc()
    else:
        try:
            raw = json.loads(path.read_text())
            if not isinstance(raw, dict):
                raise ValueError("tuning cache root is not an object")
            if raw.get("version") != SCHEMA_VERSION:
                with _LOCK:
                    _STATS["invalid"] += 1
                doc = _empty_doc()
            else:
                doc = {
                    "version": SCHEMA_VERSION,
                    "variants": dict(raw.get("variants") or {}),
                    "choices": dict(raw.get("choices") or {}),
                    "groups": dict(raw.get("groups") or {}),
                }
        except (ValueError, OSError) as e:
            with _LOCK:
                _STATS["corrupt"] += 1
            warnings.warn(
                f"tuning cache at {path} is unreadable ({e}); falling "
                f"back to analytical choices", RuntimeWarning,
                stacklevel=3)
            doc = _empty_doc()
    with _LOCK:
        _MEMO.update(path=str(path), stat=stat, doc=doc)
    return doc


def _save(doc: Dict[str, Any]) -> None:
    """Atomic write (temp + rename) so readers never see a torn file."""
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                               prefix=_FILENAME, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    try:
        st = path.stat()
        stat = (st.st_mtime_ns, st.st_size)
    except OSError:
        stat = None
    with _LOCK:
        _MEMO.update(path=str(path), stat=stat, doc=doc)


def _valid_variant(entry: Any) -> bool:
    return (isinstance(entry, dict)
            and entry.get("version") == SCHEMA_VERSION
            and isinstance(entry.get("blocks"), (list, tuple))
            and len(entry["blocks"]) == 3
            and all(isinstance(b, int) and b > 0 for b in entry["blocks"])
            and isinstance(entry.get("grid_order"), str)
            and isinstance(entry.get("accum"), str))


def _valid_group(entry: Any) -> bool:
    if not (isinstance(entry, dict)
            and entry.get("version") == SCHEMA_VERSION
            and isinstance(entry.get("merged"), bool)):
        return False
    if not entry["merged"]:
        return True                     # sequential verdict carries no knobs
    return (isinstance(entry.get("bm"), int) and entry["bm"] > 0
            and isinstance(entry.get("interleave"), str))


def _valid_choice(entry: Any) -> bool:
    return (isinstance(entry, dict)
            and entry.get("version") == SCHEMA_VERSION
            and isinstance(entry.get("selected"), (list, tuple))
            and isinstance(entry.get("T"), (list, tuple))
            and _valid_variant(entry.get("variant")))


# ---------------------------------------------------------------------------
# Variant map — keyed exactly like the compile cache
# ---------------------------------------------------------------------------

def lookup_variant(key: str) -> Optional[Dict[str, Any]]:
    """The persisted winning variant for a compile key digest, or None."""
    entry = _load()["variants"].get(key)
    with _LOCK:
        if entry is None:
            _STATS["misses"] += 1
            return None
        if not _valid_variant(entry):
            _STATS["invalid"] += 1
            _STATS["misses"] += 1
            return None
        _STATS["hits"] += 1
    return entry


def store_variant(key: str, *, blocks: Tuple[int, int, int],
                  grid_order: str, accum: str,
                  measured_s: Optional[float] = None,
                  untuned_s: Optional[float] = None,
                  meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    entry: Dict[str, Any] = {
        "version": SCHEMA_VERSION,
        "blocks": [int(b) for b in blocks],
        "grid_order": str(grid_order),
        "accum": str(accum),
    }
    if measured_s is not None:
        entry["measured_s"] = float(measured_s)
    if untuned_s is not None:
        entry["untuned_s"] = float(untuned_s)
    if meta:
        entry["meta"] = meta
    with _LOCK:
        doc = dict(_load())
        doc["variants"] = {**doc["variants"], key: entry}
        _save(doc)
        _STATS["stores"] += 1
    return entry


# ---------------------------------------------------------------------------
# Group map — merged-kernel verdicts per fused chain
# ---------------------------------------------------------------------------

def lookup_group(key: str) -> Optional[Dict[str, Any]]:
    """The persisted merged-vs-sequential verdict for a fused-group key
    digest (``pipeline._group_cache_key``), or None.  ``merged: False``
    entries are themselves cache hits — they record that sequential
    dispatch measured faster, so the executor should skip merging."""
    entry = _load()["groups"].get(key)
    with _LOCK:
        if entry is None:
            _STATS["misses"] += 1
            return None
        if not _valid_group(entry):
            _STATS["invalid"] += 1
            _STATS["misses"] += 1
            warnings.warn(
                f"tuning cache group entry {key[:12]} is corrupt or "
                f"version-skewed; falling back to the analytical verdict",
                RuntimeWarning, stacklevel=2)
            return None
        _STATS["hits"] += 1
    return entry


def store_group(key: str, *, merged: bool, bm: Optional[int] = None,
                interleave: Optional[str] = None,
                merged_s: Optional[float] = None,
                sequential_s: Optional[float] = None,
                meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    entry: Dict[str, Any] = {
        "version": SCHEMA_VERSION,
        "merged": bool(merged),
    }
    if bm is not None:
        entry["bm"] = int(bm)
    if interleave is not None:
        entry["interleave"] = str(interleave)
    if merged_s is not None:
        entry["merged_s"] = float(merged_s)
    if sequential_s is not None:
        entry["sequential_s"] = float(sequential_s)
    if meta:
        entry["meta"] = meta
    with _LOCK:
        doc = dict(_load())
        doc["groups"] = {**doc["groups"], key: entry}
        _save(doc)
        _STATS["stores"] += 1
    return entry


# ---------------------------------------------------------------------------
# Choice map — algebra-level winners (dataflow + variant)
# ---------------------------------------------------------------------------

def shape_key_for(alg, cfg, dtype, interpret: bool, backend: str) -> str:
    """Digest of the *algebra-level* tuning identity: everything the
    compile key carries except the dataflow (which is what the choice
    records)."""
    import jax.numpy as jnp
    return key_of((alg, cfg, jnp.dtype(dtype).name, bool(interpret),
                   str(backend)))


def lookup_choice(key: str) -> Optional[Dict[str, Any]]:
    entry = _load()["choices"].get(key)
    with _LOCK:
        if entry is None:
            _STATS["misses"] += 1
            return None
        if not _valid_choice(entry):
            _STATS["invalid"] += 1
            _STATS["misses"] += 1
            return None
        _STATS["hits"] += 1
    return entry


def store_choice(key: str, *, selected, T, variant: Dict[str, Any],
                 dataflow_name: str = "",
                 meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    entry: Dict[str, Any] = {
        "version": SCHEMA_VERSION,
        "selected": [str(s) for s in selected],
        "T": [[int(v) for v in row] for row in T],
        "dataflow_name": str(dataflow_name),
        "variant": variant,
    }
    if meta:
        entry["meta"] = meta
    with _LOCK:
        doc = dict(_load())
        doc["choices"] = {**doc["choices"], key: entry}
        _save(doc)
        _STATS["stores"] += 1
    return entry


# ---------------------------------------------------------------------------
# Introspection / maintenance
# ---------------------------------------------------------------------------

def cache_info() -> Dict[str, int]:
    doc = _load()
    with _LOCK:
        return {"variants": len(doc["variants"]),
                "choices": len(doc["choices"]),
                "groups": len(doc.get("groups") or {}), **_STATS}


def cache_clear(*, counters_only: bool = False) -> None:
    """Delete the on-disk cache file (unless ``counters_only``) and reset
    the in-memory memo + counters."""
    with _LOCK:
        if not counters_only:
            try:
                cache_path().unlink()
            except OSError:
                pass
        _MEMO.update(path=None, stat=None, doc=None)
        for k in _STATS:
            _STATS[k] = 0
