"""The measurement-driven autotuner (ISSUE 6 tentpole).

The analytical pipeline ranks designs with ``PaperCycleModel`` and picks
block sizes with the shared tile chooser — both first-principles models
that a real machine (even interpret-mode Pallas on CPU) disagrees with.
``tune()`` closes the gap:

    1. take the top-``search`` candidates from the analytical ranking
       (``core.dse.search`` — blocks x template x dataflow x partition),
    2. expand each into kernel *variants* over the measured-tuning knobs
       (block sizes, contraction grid order, accumulation strategy),
    3. time every variant with the shared harness
       (``measure.measure``: warmup + median-of-k, ``block_until_ready``),
       validating each against the untuned kernel's output,
    4. persist the winner in the on-disk tuning cache keyed exactly like
       the compile cache — so later ``lower()``/``generate()`` calls in
       *any* process pick it up without re-measuring, and
    5. feed the top-1 analytical measurement into the calibration fit
       (``calibrate.record``) so the cost model's predictions track the
       machine.

The untuned analytical variant is always trial #0, so the tuned pick is
never slower than untuned *by construction* (CI's tune smoke step relies
on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..compile import pipeline
from ..core import dse, linalg, stt as stt_mod
from ..core.algebra import TensorAlgebra
from ..core.stt import Dataflow
from ..core.tiling import ArrayConfig
from ..kernels import stt_gemm as _gemm
from . import cache as _cache
from . import calibrate as _calibrate
from .measure import DEFAULT_REPEATS, DEFAULT_WARMUP, Measurement, measure

#: trial-count ceiling (variants per tune() call, across all candidate
#: dataflows); the knob grid is pruned to fit
DEFAULT_MAX_TRIALS = 32

#: relative-error gates for validating a variant against the untuned
#: kernel's output (integer random operands make fp32 scratch exact; the
#: bf16-direct accumulation strategy is allowed its rounding, and is
#: rejected when it exceeds the gate)
_REL_TOL = {"float32": 1e-4, "bfloat16": 2e-2, "float16": 2e-2}


@dataclasses.dataclass(frozen=True)
class Variant:
    """One point in the kernel-knob space the tuner searches."""

    blocks: Tuple[int, int, int]
    grid_order: str = "default"
    accum: str = "auto"


@dataclasses.dataclass(frozen=True)
class Trial:
    """One measured (or rejected) variant of one candidate dataflow."""

    dataflow_name: str
    variant: Variant
    measurement: Optional[Measurement]   # None when the variant failed
    ok: bool
    error: str = ""

    @property
    def median_s(self) -> float:
        return self.measurement.median_s if self.measurement else float("inf")


@dataclasses.dataclass
class TuneResult:
    """What a ``tune()`` call produced.

    ``kernel`` is lowered with the winning variant (``source == "tuned"``);
    ``untuned_s`` is the analytical pick's measured median, ``tuned_s``
    the winner's, so ``speedup`` is a same-session apples-to-apples
    ratio.  ``cache_hit`` means the on-disk choice cache answered and no
    measurement ran (``trials`` is empty).
    """

    kernel: pipeline.CompiledKernel
    dataflow: Dataflow
    variant: Variant
    tuned_s: Optional[float]
    untuned_s: Optional[float]
    cache_hit: bool
    trials: Tuple[Trial, ...] = ()

    @property
    def speedup(self) -> Optional[float]:
        if self.tuned_s and self.untuned_s:
            return self.untuned_s / self.tuned_s
        return None


def _t_rows(T: linalg.Mat) -> List[List[int]]:
    return [[int(v) for v in row] for row in T]


def _clamp_blocks(blocks: Tuple[int, int, int], dims: Tuple[int, int, int]
                  ) -> Tuple[int, int, int]:
    return tuple(max(1, min(b, d)) for b, d in zip(blocks, dims))


def block_candidates(analytical: Tuple[int, int, int],
                     dims: Tuple[int, int, int]
                     ) -> List[Tuple[int, int, int]]:
    """Block-size candidates around the analytical pick: the pick itself
    (trial #0's variant), hardware-friendly clamps (128/256), the full
    problem capped at 512 (fewest grid steps — the big interpret-mode
    win), and the pick doubled.  Deduped, analytical first."""
    cands = [
        analytical,
        _clamp_blocks((128, 128, 128), dims),
        _clamp_blocks((256, 256, 256), dims),
        _clamp_blocks((512, 512, 512), dims),
        _clamp_blocks(tuple(b * 2 for b in analytical), dims),
    ]
    out: List[Tuple[int, int, int]] = []
    for c in cands:
        c = _clamp_blocks(c, dims)
        if c not in out:
            out.append(c)
    return out


def _knob_grid(template: str) -> List[Tuple[str, str]]:
    """(grid_order, accum) combos valid for a template — the analytical
    default first, so trial #0 is exactly the untuned kernel."""
    if template == "output_stationary":
        combos = [("default", "auto")]
        combos += [(o, "scratch") for o in _gemm.OS_GRID_ORDERS
                   if o != "mnk"]          # "default" == mnk + scratch
        combos += [(o, "inplace") for o in _gemm.OS_GRID_ORDERS]
        return combos
    if template in ("reduction_tree", "streaming"):
        return [("default", "auto"), ("nm", "auto")]
    # operand_stationary has a fixed streaming order; only blocks vary
    return [("default", "auto")]


def _rel_err(got: np.ndarray, want: np.ndarray) -> float:
    scale = float(np.abs(want).max()) if want.size else 0.0
    if got.shape != want.shape:
        return float("inf")
    err = float(np.abs(got - want).max()) if want.size else 0.0
    return err / (scale + 1e-30)


def _lower_kwargs(cfg, dtype, interpret, backend) -> Dict:
    return dict(cfg=cfg, dtype=dtype, interpret=interpret, backend=backend)


def tune(alg: TensorAlgebra, dataflow: Optional[Dataflow] = None, *,
         search: int = 4,
         cfg: ArrayConfig = ArrayConfig(),
         dtype=jnp.float32,
         interpret: bool = False,
         backend: str = "pallas",
         repeats: int = DEFAULT_REPEATS,
         warmup: int = DEFAULT_WARMUP,
         force: bool = False,
         validate: Optional[bool] = None,
         max_trials: int = DEFAULT_MAX_TRIALS,
         seed: int = 0) -> TuneResult:
    """Measure-and-pick: the best (dataflow, variant) for ``alg`` on this
    machine, persisted for later processes.

    ``dataflow`` pins the schedule (only kernel variants are searched);
    otherwise the top-``search`` analytical candidates from
    ``dse.search`` each contribute variants.  ``force=True`` bypasses the
    on-disk choice cache and re-measures.  ``validate`` controls the
    *oracle* validation of the final kernel (default: auto, small
    problems only); every trial is always gated on matching the untuned
    kernel's output.
    """
    lkw = _lower_kwargs(cfg, dtype, interpret, backend)
    shape_key = _cache.shape_key_for(alg, cfg, dtype, interpret, backend)

    if not force:
        choice = _cache.lookup_choice(shape_key)
        if choice is not None:
            df = stt_mod.apply_stt(alg, tuple(choice["selected"]),
                                   linalg.mat(choice["T"]))
            if dataflow is None or df.signature == dataflow.signature:
                v = choice["variant"]
                # no explicit knobs: lower() consults the variant cache
                # itself, so the kernel comes back source == "tuned"
                kernel = pipeline.lower(alg, df, validate=validate, **lkw)
                variant = Variant(tuple(v["blocks"]), v["grid_order"],
                                  v["accum"])
                return TuneResult(
                    kernel=kernel, dataflow=df, variant=variant,
                    tuned_s=v.get("measured_s"),
                    untuned_s=v.get("untuned_s"),
                    cache_hit=True, trials=())

    if dataflow is not None:
        pairs = [(None, dataflow)]
    else:
        pairs = dse.search(alg, top_k=max(1, search), cfg=cfg)

    operands = alg.random_operands(seed)
    tol = _REL_TOL.get(jnp.dtype(dtype).name, 2e-2)

    # --- trial #0: the untuned analytical pick (top-1 candidate) --------
    untuned_df = pairs[0][1]
    untuned_kernel = pipeline.lower(alg, untuned_df, validate=validate,
                                    tuned=False, **lkw)
    ref_out = np.asarray(untuned_kernel(operands), dtype=np.float64)
    untuned_meas = measure(untuned_kernel, operands,
                           warmup=warmup, repeats=repeats)
    trials: List[Trial] = [Trial(
        dataflow_name=untuned_df.name,
        variant=Variant(untuned_kernel.blocks, "default", "auto"),
        measurement=untuned_meas, ok=True)]
    best = (untuned_meas.median_s, untuned_df, trials[0].variant,
            untuned_kernel)

    # --- the variant sweep ---------------------------------------------
    for _, df in pairs:
        if len(trials) > max_trials:
            break
        base = pipeline.lower(alg, df, validate=False, tuned=False, **lkw)
        dims = (base.form.m, base.form.n, base.form.k)
        for blocks in block_candidates(base.blocks, dims):
            for grid_order, accum in _knob_grid(base.template):
                variant = Variant(blocks, grid_order, accum)
                if df is untuned_df and variant == trials[0].variant:
                    continue            # already measured as trial #0
                if len(trials) > max_trials:
                    break
                try:
                    k = pipeline.lower(alg, df, validate=False,
                                       blocks=blocks, grid_order=grid_order,
                                       accum=accum, **lkw)
                    got = np.asarray(k(operands), dtype=np.float64)
                    err = _rel_err(got, ref_out)
                    if err > tol:
                        trials.append(Trial(df.name, variant, None, False,
                                            f"rel err {err:.3e} > {tol}"))
                        continue
                    meas = measure(k, operands, warmup=warmup,
                                   repeats=repeats)
                except Exception as e:  # invalid knob combo, OOM, ...
                    trials.append(Trial(df.name, variant, None, False,
                                        f"{type(e).__name__}: {e}"))
                    continue
                trials.append(Trial(df.name, variant, meas, True))
                if meas.median_s < best[0]:
                    best = (meas.median_s, df, variant, k)

    tuned_s, win_df, win_variant, win_kernel = best

    # --- calibration: anchor the cost model on the winner's measurement
    # (newest record per (template, algebra) supersedes older ones, so
    # the fitted scale maps the analytical prediction onto what this
    # machine actually runs after tuning)
    _calibrate.record(
        win_kernel.template, alg.name, win_kernel.cost_report().cycles,
        tuned_s * cfg.freq_mhz * 1e6,
        meta={"interpret": bool(interpret), "backend": backend,
              "dtype": jnp.dtype(dtype).name, "dataflow": win_df.name})

    # --- persist: variant under the compile key, choice per algebra ----
    base_key = pipeline._cache_key(alg, win_df, cfg, jnp.dtype(dtype),
                                   interpret, backend)
    entry = _cache.store_variant(
        _cache.key_of(base_key), blocks=win_variant.blocks,
        grid_order=win_variant.grid_order, accum=win_variant.accum,
        measured_s=tuned_s, untuned_s=untuned_meas.median_s,
        meta={"algebra": alg.name, "dataflow": win_df.name,
              "template": win_kernel.template})
    _cache.store_choice(
        shape_key, selected=win_df.selected, T=_t_rows(win_df.T),
        variant=entry, dataflow_name=win_df.name)

    # label the winner with its measurement (the compile cache shares the
    # object, so later lower() hits in this process see it too)
    win_kernel.source = "tuned"
    win_kernel.measured_s = tuned_s
    if validate and not win_kernel.validated:
        # trials only gate on matching the untuned output; an explicit
        # validate=True also runs the winner against the python oracle
        win_kernel.validate()

    return TuneResult(
        kernel=win_kernel, dataflow=win_df, variant=win_variant,
        tuned_s=tuned_s, untuned_s=untuned_meas.median_s,
        cache_hit=False, trials=tuple(trials))


# ---------------------------------------------------------------------------
# Merged-group tuning — megakernel vs sequential dispatch (ISSUE 9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupVariant:
    """One point in the merged-kernel knob space: the m-block ladder
    step and the stage interleave order (``kernels/fused_chain.py``)."""

    bm: int
    interleave: str = "chain"


@dataclasses.dataclass(frozen=True)
class GroupTrial:
    """One measured (or rejected) merged variant of one fused group."""

    variant: GroupVariant
    measurement: Optional[Measurement]   # None when the variant failed
    ok: bool
    error: str = ""

    @property
    def median_s(self) -> float:
        return self.measurement.median_s if self.measurement else float("inf")


@dataclasses.dataclass
class GroupTuneResult:
    """What a ``tune_group()`` call decided for one fused chain.

    ``merged`` is the verdict: the best megakernel variant measured
    faster than sequential per-node dispatch.  ``kernel`` carries the
    winning :class:`~repro.compile.pipeline.CompiledGroupKernel` when
    merged won, None when sequential did (the executor then keeps
    per-node dispatch).  The verdict persists in the on-disk tuning
    cache, so a later ``build()``/``generate()`` in any process honors
    it without re-measuring (``cache_hit``).
    """

    group: str
    kernel: Optional[pipeline.CompiledGroupKernel]
    merged: bool
    variant: Optional[GroupVariant]
    merged_s: Optional[float]
    sequential_s: Optional[float]
    cache_hit: bool
    trials: Tuple[GroupTrial, ...] = ()

    @property
    def speedup(self) -> Optional[float]:
        """Sequential over merged median — >1 means the megakernel won."""
        if self.merged_s and self.sequential_s:
            return self.sequential_s / self.merged_s
        return None


def group_bm_candidates(group) -> List[int]:
    """m-block ladder for a merged chain: the plan's agreed bm (trial
    #0), hardware-friendly 128/256 clamps, and the whole-m degenerate
    single-phase case.  Deduped, agreed-first."""
    m = group.m
    cands = [group.bm, min(128, m), min(256, m), m]
    out: List[int] = []
    for bm in cands:
        bm = max(1, min(int(bm), m))
        if bm not in out:
            out.append(bm)
    return out


def _group_operands(group, seed: int):
    """Random integer operands in the group's external layout (lhs
    ``(m, k0)``, per-stage weights in gemm storage ``(n, k)``, rank-1
    biases) — integers keep fp32 stage dots exact, same rationale as
    ``TensorAlgebra.random_operands``."""
    rng = np.random.default_rng(seed)
    lhs = rng.integers(-4, 5, size=(group.m, group.k0))
    rhss = [rng.integers(-4, 5, size=(st.n, st.k)) for st in group.chain]
    biases = [rng.integers(-4, 5, size=(st.n,))
              for st in group.chain if st.has_bias]
    return lhs, rhss, biases


def _sequential_runner(plan, group, *, interpret: bool, backend: str):
    """The measured baseline: the group's member nodes lowered exactly
    as ``graph.executor.build(..., merge=False)`` lowers them — one
    ``pallas_call`` per stage, intermediates round-tripping as JAX
    arrays — chained into one callable over the group's operands."""
    from ..graph.executor import bias_operand_key
    from ..kernels import epilogue as epilogue_mod
    stages = []
    for name in group.stages:
        p = plan.nodes[name]
        fused_ep = p.epilogue if p.epilogue_fused else ()
        bias_key = (bias_operand_key(p.bias_edge)
            if (fused_ep and p.bias_edge is not None
                and epilogue_mod.needs_bias(fused_ep)) else None)
        k = pipeline.lower(
            p.node.algebra, p.dataflow, cfg=plan.cfg, dtype=p.dtype,
            interpret=interpret, backend=backend, validate=False,
            blocks=p.blocks if p.blocks_constrained else None,
            epilogue=fused_ep, bias_tensor=bias_key,
            fused_group=plan.fused_group_for(name))
        stages.append((k, p))

    def run(lhs, rhss, biases):
        x, bi = lhs, 0
        for i, (k, p) in enumerate(stages):
            a_name = p.node.algebra.inputs[0].name
            b_name = p.node.algebra.inputs[1].name
            ops = {a_name: x, b_name: rhss[i]}
            if k.bias_tensor is not None:
                ops[k.bias_tensor] = biases[bi]
                bi += 1
            x = k(ops)
        return x

    return run


def _sequential_dag_runner(plan, group, *, interpret: bool,
                           backend: str):
    """Sequential baseline for a ``kind="dag"`` group: the members run
    one ``pallas_call`` each (as ``build(merge=False)`` would), values
    memoized by edge name, folded residuals applied post-kernel in fp32;
    returns ``(result, *taps)`` to mirror the merged kernel's outputs."""
    from ..graph.executor import bias_operand_key
    from ..kernels import epilogue as epilogue_mod
    stages = []
    for name in group.stages:
        p = plan.nodes[name]
        fused_ep = p.epilogue if p.epilogue_fused else ()
        bias_key = (bias_operand_key(p.bias_edge)
            if (fused_ep and p.bias_edge is not None
                and epilogue_mod.needs_bias(fused_ep)) else None)
        k = pipeline.lower(
            p.node.algebra, p.dataflow, cfg=plan.cfg, dtype=p.dtype,
            interpret=interpret, backend=backend, validate=False,
            blocks=p.blocks if p.blocks_constrained else None,
            epilogue=fused_ep, bias_tensor=bias_key,
            fused_group=plan.fused_group_for(name))
        stages.append((k, p))

    def run(exts):
        values = {e: jnp.asarray(v)
                  for (e, _), v in zip(group.ext_inputs, exts)}
        for k, p in stages:
            node = p.node
            ops = {t.name: values[e]
                   for t, e in zip(node.algebra.inputs, node.inputs)}
            if k.bias_tensor is not None:
                ops[k.bias_tensor] = values[p.bias_edge]
            out = k(ops)
            if p.residual_edge is not None:
                out = (out.astype(jnp.float32)
                       + values[p.residual_edge].astype(jnp.float32)
                       ).astype(k.dtype)
            values[p.result_edge] = out
        return (values[group.result_edge],
                *(values[e] for _, e in group.taps))

    return run


def tune_group(plan, group, *,
               interpret: bool = False,
               backend: str = "pallas",
               repeats: int = DEFAULT_REPEATS,
               warmup: int = DEFAULT_WARMUP,
               force: bool = False,
               max_trials: int = DEFAULT_MAX_TRIALS,
               seed: int = 0) -> GroupTuneResult:
    """Measure merged-megakernel variants against sequential per-node
    dispatch for one fused group, and persist whichever wins.

    Knobs: the m-block ladder (``group_bm_candidates``) crossed with the
    stage interleave orders (``fused_chain.FUSED_INTERLEAVES``), capped
    at ``max_trials``.  Every variant is gated on matching the
    sequential baseline's output before it may be timed.  ``force=True``
    bypasses the on-disk group cache and re-measures.
    """
    if not group.eligible:
        raise ValueError(f"group {group.name} is not merged-eligible: "
                         f"{group.reason}")
    from ..kernels.fused_chain import FUSED_INTERLEAVES
    digest = _cache.key_of(
        pipeline._group_cache_key(plan, group, interpret, backend))

    if not force:
        entry = _cache.lookup_group(digest)
        if entry is not None:
            # no explicit knobs: lower_group re-consults the cache, so a
            # merged winner comes back source == "tuned" and a
            # sequential verdict comes back None
            kernel = pipeline.lower_group(plan, group,
                                          interpret=interpret,
                                          backend=backend)
            variant = (GroupVariant(entry["bm"], entry["interleave"])
                       if entry["merged"] else None)
            return GroupTuneResult(
                group=group.name, kernel=kernel, merged=entry["merged"],
                variant=variant, merged_s=entry.get("merged_s"),
                sequential_s=entry.get("sequential_s"),
                cache_hit=True, trials=())

    tol = _REL_TOL.get(jnp.dtype(group.dtype).name, 2e-2)
    is_dag = getattr(group, "kind", "chain") == "dag"

    # --- the baseline merging must beat: sequential dispatch -----------
    if is_dag:
        rng = np.random.default_rng(seed)
        exts = [rng.integers(-4, 5, size=plan.graph.edge_shape(e))
                for e, _ in group.ext_inputs]
        seq = _sequential_dag_runner(plan, group, interpret=interpret,
                                     backend=backend)
        ref_outs = [np.asarray(o, dtype=np.float64) for o in seq(exts)]
        seq_meas = measure(seq, exts, warmup=warmup, repeats=repeats)
    else:
        lhs, rhss, biases = _group_operands(group, seed)
        seq = _sequential_runner(plan, group, interpret=interpret,
                                 backend=backend)
        ref_out = np.asarray(seq(lhs, rhss, biases), dtype=np.float64)
        seq_meas = measure(seq, lhs, rhss, biases,
                           warmup=warmup, repeats=repeats)

    # --- the merged-variant sweep --------------------------------------
    trials: List[GroupTrial] = []
    best: Optional[Tuple[float, GroupVariant,
                         pipeline.CompiledGroupKernel]] = None
    if is_dag:
        # the stage-major dag template has no block/interleave ladder:
        # one whole-tensor variant, measured against the same gate
        from ..kernels.fused_chain import DAG_INTERLEAVE
        variant = GroupVariant(group.m, DAG_INTERLEAVE)
        try:
            k = pipeline.lower_group(
                plan, group, interpret=interpret, backend=backend,
                validate=False, bm=group.m, interleave=DAG_INTERLEAVE)
            got = [np.asarray(o, dtype=np.float64) for o in k(exts)]
            err = max(_rel_err(g_, r_)
                      for g_, r_ in zip(got, ref_outs))
            if err > tol:
                trials.append(GroupTrial(variant, None, False,
                                         f"rel err {err:.3e} > {tol}"))
            else:
                meas = measure(k, exts, warmup=warmup, repeats=repeats)
                trials.append(GroupTrial(variant, meas, True))
                best = (meas.median_s, variant, k)
        except Exception as e:          # VMEM overflow, lowering bug, ...
            trials.append(GroupTrial(variant, None, False,
                                     f"{type(e).__name__}: {e}"))
    else:
        for bm in group_bm_candidates(group):
            for interleave in FUSED_INTERLEAVES:
                if len(trials) >= max_trials:
                    break
                variant = GroupVariant(bm, interleave)
                try:
                    k = pipeline.lower_group(
                        plan, group, interpret=interpret,
                        backend=backend, validate=False, bm=bm,
                        interleave=interleave)
                    got = np.asarray(k(lhs, rhss, biases),
                                     dtype=np.float64)
                    err = _rel_err(got, ref_out)
                    if err > tol:
                        trials.append(GroupTrial(
                            variant, None, False,
                            f"rel err {err:.3e} > {tol}"))
                        continue
                    meas = measure(k, lhs, rhss, biases,
                                   warmup=warmup, repeats=repeats)
                except Exception as e:  # VMEM overflow, bad knob, ...
                    trials.append(GroupTrial(variant, None, False,
                                             f"{type(e).__name__}: {e}"))
                    continue
                trials.append(GroupTrial(variant, meas, True))
                if best is None or meas.median_s < best[0]:
                    best = (meas.median_s, variant, k)

    merged = best is not None and best[0] < seq_meas.median_s
    if merged:
        merged_s, win_variant, win_kernel = best
        win_kernel.source = "tuned"
        win_kernel.measured_s = merged_s
        win_kernel.sequential_s = seq_meas.median_s
        _cache.store_group(
            digest, merged=True, bm=win_variant.bm,
            interleave=win_variant.interleave, merged_s=merged_s,
            sequential_s=seq_meas.median_s,
            meta={"group": group.name, "stages": list(group.stages)})
        return GroupTuneResult(
            group=group.name, kernel=win_kernel, merged=True,
            variant=win_variant, merged_s=merged_s,
            sequential_s=seq_meas.median_s, cache_hit=False,
            trials=tuple(trials))

    _cache.store_group(
        digest, merged=False,
        merged_s=best[0] if best else None,
        sequential_s=seq_meas.median_s,
        meta={"group": group.name, "stages": list(group.stages)})
    return GroupTuneResult(
        group=group.name, kernel=None, merged=False, variant=None,
        merged_s=best[0] if best else None,
        sequential_s=seq_meas.median_s, cache_hit=False,
        trials=tuple(trials))


def rank_measured(alg: TensorAlgebra,
                  pairs: Sequence[Tuple[object, Dataflow]], *,
                  cfg: ArrayConfig = ArrayConfig(),
                  dtype=jnp.float32,
                  interpret: bool = False,
                  backend: str = "pallas",
                  repeats: int = DEFAULT_REPEATS,
                  warmup: int = DEFAULT_WARMUP,
                  seed: int = 0
                  ) -> List[Tuple[object, Dataflow, float]]:
    """Re-rank ``(report, dataflow)`` candidates by *measured* wall clock.

    Each candidate is lowered with its analytical variant and timed with
    the shared harness; the result is a permutation of the input pairs
    (nothing added, nothing dropped) extended with the measured median
    seconds — measurement reorders the analytical ranking, it never
    invents candidates."""
    operands = alg.random_operands(seed)
    lkw = _lower_kwargs(cfg, dtype, interpret, backend)
    timed = []
    for rep, df in pairs:
        kernel = pipeline.lower(alg, df, validate=False, tuned=False, **lkw)
        meas = measure(kernel, operands, warmup=warmup, repeats=repeats)
        timed.append((rep, df, meas.median_s))
    return sorted(timed, key=lambda t: t[2])
