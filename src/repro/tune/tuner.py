"""The measurement-driven autotuner (ISSUE 6 tentpole).

The analytical pipeline ranks designs with ``PaperCycleModel`` and picks
block sizes with the shared tile chooser — both first-principles models
that a real machine (even interpret-mode Pallas on CPU) disagrees with.
``tune()`` closes the gap:

    1. take the top-``search`` candidates from the analytical ranking
       (``core.dse.search`` — blocks x template x dataflow x partition),
    2. expand each into kernel *variants* over the measured-tuning knobs
       (block sizes, contraction grid order, accumulation strategy),
    3. time every variant with the shared harness
       (``measure.measure``: warmup + median-of-k, ``block_until_ready``),
       validating each against the untuned kernel's output,
    4. persist the winner in the on-disk tuning cache keyed exactly like
       the compile cache — so later ``lower()``/``generate()`` calls in
       *any* process pick it up without re-measuring, and
    5. feed the top-1 analytical measurement into the calibration fit
       (``calibrate.record``) so the cost model's predictions track the
       machine.

The untuned analytical variant is always trial #0, so the tuned pick is
never slower than untuned *by construction* (CI's tune smoke step relies
on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..compile import pipeline
from ..core import dse, linalg, stt as stt_mod
from ..core.algebra import TensorAlgebra
from ..core.stt import Dataflow
from ..core.tiling import ArrayConfig
from ..kernels import stt_gemm as _gemm
from . import cache as _cache
from . import calibrate as _calibrate
from .measure import DEFAULT_REPEATS, DEFAULT_WARMUP, Measurement, measure

#: trial-count ceiling (variants per tune() call, across all candidate
#: dataflows); the knob grid is pruned to fit
DEFAULT_MAX_TRIALS = 32

#: relative-error gates for validating a variant against the untuned
#: kernel's output (integer random operands make fp32 scratch exact; the
#: bf16-direct accumulation strategy is allowed its rounding, and is
#: rejected when it exceeds the gate)
_REL_TOL = {"float32": 1e-4, "bfloat16": 2e-2, "float16": 2e-2}


@dataclasses.dataclass(frozen=True)
class Variant:
    """One point in the kernel-knob space the tuner searches."""

    blocks: Tuple[int, int, int]
    grid_order: str = "default"
    accum: str = "auto"


@dataclasses.dataclass(frozen=True)
class Trial:
    """One measured (or rejected) variant of one candidate dataflow."""

    dataflow_name: str
    variant: Variant
    measurement: Optional[Measurement]   # None when the variant failed
    ok: bool
    error: str = ""

    @property
    def median_s(self) -> float:
        return self.measurement.median_s if self.measurement else float("inf")


@dataclasses.dataclass
class TuneResult:
    """What a ``tune()`` call produced.

    ``kernel`` is lowered with the winning variant (``source == "tuned"``);
    ``untuned_s`` is the analytical pick's measured median, ``tuned_s``
    the winner's, so ``speedup`` is a same-session apples-to-apples
    ratio.  ``cache_hit`` means the on-disk choice cache answered and no
    measurement ran (``trials`` is empty).
    """

    kernel: pipeline.CompiledKernel
    dataflow: Dataflow
    variant: Variant
    tuned_s: Optional[float]
    untuned_s: Optional[float]
    cache_hit: bool
    trials: Tuple[Trial, ...] = ()

    @property
    def speedup(self) -> Optional[float]:
        if self.tuned_s and self.untuned_s:
            return self.untuned_s / self.tuned_s
        return None


def _t_rows(T: linalg.Mat) -> List[List[int]]:
    return [[int(v) for v in row] for row in T]


def _clamp_blocks(blocks: Tuple[int, int, int], dims: Tuple[int, int, int]
                  ) -> Tuple[int, int, int]:
    return tuple(max(1, min(b, d)) for b, d in zip(blocks, dims))


def block_candidates(analytical: Tuple[int, int, int],
                     dims: Tuple[int, int, int]
                     ) -> List[Tuple[int, int, int]]:
    """Block-size candidates around the analytical pick: the pick itself
    (trial #0's variant), hardware-friendly clamps (128/256), the full
    problem capped at 512 (fewest grid steps — the big interpret-mode
    win), and the pick doubled.  Deduped, analytical first."""
    cands = [
        analytical,
        _clamp_blocks((128, 128, 128), dims),
        _clamp_blocks((256, 256, 256), dims),
        _clamp_blocks((512, 512, 512), dims),
        _clamp_blocks(tuple(b * 2 for b in analytical), dims),
    ]
    out: List[Tuple[int, int, int]] = []
    for c in cands:
        c = _clamp_blocks(c, dims)
        if c not in out:
            out.append(c)
    return out


def _knob_grid(template: str) -> List[Tuple[str, str]]:
    """(grid_order, accum) combos valid for a template — the analytical
    default first, so trial #0 is exactly the untuned kernel."""
    if template == "output_stationary":
        combos = [("default", "auto")]
        combos += [(o, "scratch") for o in _gemm.OS_GRID_ORDERS
                   if o != "mnk"]          # "default" == mnk + scratch
        combos += [(o, "inplace") for o in _gemm.OS_GRID_ORDERS]
        return combos
    if template in ("reduction_tree", "streaming"):
        return [("default", "auto"), ("nm", "auto")]
    # operand_stationary has a fixed streaming order; only blocks vary
    return [("default", "auto")]


def _rel_err(got: np.ndarray, want: np.ndarray) -> float:
    scale = float(np.abs(want).max()) if want.size else 0.0
    if got.shape != want.shape:
        return float("inf")
    err = float(np.abs(got - want).max()) if want.size else 0.0
    return err / (scale + 1e-30)


def _lower_kwargs(cfg, dtype, interpret, backend) -> Dict:
    return dict(cfg=cfg, dtype=dtype, interpret=interpret, backend=backend)


def tune(alg: TensorAlgebra, dataflow: Optional[Dataflow] = None, *,
         search: int = 4,
         cfg: ArrayConfig = ArrayConfig(),
         dtype=jnp.float32,
         interpret: bool = False,
         backend: str = "pallas",
         repeats: int = DEFAULT_REPEATS,
         warmup: int = DEFAULT_WARMUP,
         force: bool = False,
         validate: Optional[bool] = None,
         max_trials: int = DEFAULT_MAX_TRIALS,
         seed: int = 0) -> TuneResult:
    """Measure-and-pick: the best (dataflow, variant) for ``alg`` on this
    machine, persisted for later processes.

    ``dataflow`` pins the schedule (only kernel variants are searched);
    otherwise the top-``search`` analytical candidates from
    ``dse.search`` each contribute variants.  ``force=True`` bypasses the
    on-disk choice cache and re-measures.  ``validate`` controls the
    *oracle* validation of the final kernel (default: auto, small
    problems only); every trial is always gated on matching the untuned
    kernel's output.
    """
    lkw = _lower_kwargs(cfg, dtype, interpret, backend)
    shape_key = _cache.shape_key_for(alg, cfg, dtype, interpret, backend)

    if not force:
        choice = _cache.lookup_choice(shape_key)
        if choice is not None:
            df = stt_mod.apply_stt(alg, tuple(choice["selected"]),
                                   linalg.mat(choice["T"]))
            if dataflow is None or df.signature == dataflow.signature:
                v = choice["variant"]
                # no explicit knobs: lower() consults the variant cache
                # itself, so the kernel comes back source == "tuned"
                kernel = pipeline.lower(alg, df, validate=validate, **lkw)
                variant = Variant(tuple(v["blocks"]), v["grid_order"],
                                  v["accum"])
                return TuneResult(
                    kernel=kernel, dataflow=df, variant=variant,
                    tuned_s=v.get("measured_s"),
                    untuned_s=v.get("untuned_s"),
                    cache_hit=True, trials=())

    if dataflow is not None:
        pairs = [(None, dataflow)]
    else:
        pairs = dse.search(alg, top_k=max(1, search), cfg=cfg)

    operands = alg.random_operands(seed)
    tol = _REL_TOL.get(jnp.dtype(dtype).name, 2e-2)

    # --- trial #0: the untuned analytical pick (top-1 candidate) --------
    untuned_df = pairs[0][1]
    untuned_kernel = pipeline.lower(alg, untuned_df, validate=validate,
                                    tuned=False, **lkw)
    ref_out = np.asarray(untuned_kernel(operands), dtype=np.float64)
    untuned_meas = measure(untuned_kernel, operands,
                           warmup=warmup, repeats=repeats)
    trials: List[Trial] = [Trial(
        dataflow_name=untuned_df.name,
        variant=Variant(untuned_kernel.blocks, "default", "auto"),
        measurement=untuned_meas, ok=True)]
    best = (untuned_meas.median_s, untuned_df, trials[0].variant,
            untuned_kernel)

    # --- the variant sweep ---------------------------------------------
    for _, df in pairs:
        if len(trials) > max_trials:
            break
        base = pipeline.lower(alg, df, validate=False, tuned=False, **lkw)
        dims = (base.form.m, base.form.n, base.form.k)
        for blocks in block_candidates(base.blocks, dims):
            for grid_order, accum in _knob_grid(base.template):
                variant = Variant(blocks, grid_order, accum)
                if df is untuned_df and variant == trials[0].variant:
                    continue            # already measured as trial #0
                if len(trials) > max_trials:
                    break
                try:
                    k = pipeline.lower(alg, df, validate=False,
                                       blocks=blocks, grid_order=grid_order,
                                       accum=accum, **lkw)
                    got = np.asarray(k(operands), dtype=np.float64)
                    err = _rel_err(got, ref_out)
                    if err > tol:
                        trials.append(Trial(df.name, variant, None, False,
                                            f"rel err {err:.3e} > {tol}"))
                        continue
                    meas = measure(k, operands, warmup=warmup,
                                   repeats=repeats)
                except Exception as e:  # invalid knob combo, OOM, ...
                    trials.append(Trial(df.name, variant, None, False,
                                        f"{type(e).__name__}: {e}"))
                    continue
                trials.append(Trial(df.name, variant, meas, True))
                if meas.median_s < best[0]:
                    best = (meas.median_s, df, variant, k)

    tuned_s, win_df, win_variant, win_kernel = best

    # --- calibration: anchor the cost model on the winner's measurement
    # (newest record per (template, algebra) supersedes older ones, so
    # the fitted scale maps the analytical prediction onto what this
    # machine actually runs after tuning)
    _calibrate.record(
        win_kernel.template, alg.name, win_kernel.cost_report().cycles,
        tuned_s * cfg.freq_mhz * 1e6,
        meta={"interpret": bool(interpret), "backend": backend,
              "dtype": jnp.dtype(dtype).name, "dataflow": win_df.name})

    # --- persist: variant under the compile key, choice per algebra ----
    base_key = pipeline._cache_key(alg, win_df, cfg, jnp.dtype(dtype),
                                   interpret, backend)
    entry = _cache.store_variant(
        _cache.key_of(base_key), blocks=win_variant.blocks,
        grid_order=win_variant.grid_order, accum=win_variant.accum,
        measured_s=tuned_s, untuned_s=untuned_meas.median_s,
        meta={"algebra": alg.name, "dataflow": win_df.name,
              "template": win_kernel.template})
    _cache.store_choice(
        shape_key, selected=win_df.selected, T=_t_rows(win_df.T),
        variant=entry, dataflow_name=win_df.name)

    # label the winner with its measurement (the compile cache shares the
    # object, so later lower() hits in this process see it too)
    win_kernel.source = "tuned"
    win_kernel.measured_s = tuned_s
    if validate and not win_kernel.validated:
        # trials only gate on matching the untuned output; an explicit
        # validate=True also runs the winner against the python oracle
        win_kernel.validate()

    return TuneResult(
        kernel=win_kernel, dataflow=win_df, variant=win_variant,
        tuned_s=tuned_s, untuned_s=untuned_meas.median_s,
        cache_hit=False, trials=tuple(trials))


def rank_measured(alg: TensorAlgebra,
                  pairs: Sequence[Tuple[object, Dataflow]], *,
                  cfg: ArrayConfig = ArrayConfig(),
                  dtype=jnp.float32,
                  interpret: bool = False,
                  backend: str = "pallas",
                  repeats: int = DEFAULT_REPEATS,
                  warmup: int = DEFAULT_WARMUP,
                  seed: int = 0
                  ) -> List[Tuple[object, Dataflow, float]]:
    """Re-rank ``(report, dataflow)`` candidates by *measured* wall clock.

    Each candidate is lowered with its analytical variant and timed with
    the shared harness; the result is a permutation of the input pairs
    (nothing added, nothing dropped) extended with the measured median
    seconds — measurement reorders the analytical ranking, it never
    invents candidates."""
    operands = alg.random_operands(seed)
    lkw = _lower_kwargs(cfg, dtype, interpret, backend)
    timed = []
    for rep, df in pairs:
        kernel = pipeline.lower(alg, df, validate=False, tuned=False, **lkw)
        meas = measure(kernel, operands, warmup=warmup, repeats=repeats)
        timed.append((rep, df, meas.median_s))
    return sorted(timed, key=lambda t: t[2])
