"""Calibration: map measured cycles onto PaperCycleModel overrides.

The analytical model (``core/costmodel.py``) predicts cycles from first
principles — MACs, bandwidth, the STT tile.  Real machines disagree by a
template-dependent constant factor (interpret-mode python dispatch,
Mosaic pipelining, XLA fusion...).  Rather than refit every coefficient,
we calibrate **multiplicatively**: each record pairs one measured kernel
with its model prediction, and the fit stores

* a per-``(template, algebra)`` **anchor** — the geometric mean of the
  measured/model cycle ratios observed for that exact pair, and
* a per-``template`` fallback — the geometric mean of that template's
  anchors — for algebras never measured.

Scale-only calibration is monotone-safe by construction: every scale is
clamped positive, so calibrated cycles are positive whenever model
cycles are, and the relative order of two designs under the *same*
(template, algebra) scale is exactly the analytical order.  The fitted
scales plus the raw records persist in ``calibration.json`` next to the
tuning cache, so ``PaperCycleModel(calibration=load())`` works in any
later process.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Tuple

from . import cache as _cache

SCHEMA_VERSION = 1
_FILENAME = "calibration.json"

#: clamp fitted scales into a sane band; a ratio outside it means the
#: measurement or the model is broken, and an unbounded scale would let
#: one bad sample dominate every later prediction
_MIN_SCALE = 1e-6
_MAX_SCALE = 1e9


def _geomean(values: List[float]) -> float:
    vals = [v for v in values if v > 0.0 and math.isfinite(v)]
    if not vals:
        return 1.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def _clamp(s: float) -> float:
    if not math.isfinite(s) or s <= 0.0:
        return 1.0
    return min(max(s, _MIN_SCALE), _MAX_SCALE)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted measured/model cycle scales.

    ``scale_for`` resolves most-specific-first: exact (template, algebra)
    anchor, then the per-template geomean, then 1.0 (uncalibrated).
    Every stored scale is positive, so ``model_cycles * scale`` can never
    go negative or zero out a positive prediction.
    """

    per_template: Mapping[str, float] = dataclasses.field(
        default_factory=dict)
    anchors: Mapping[Tuple[str, str], float] = dataclasses.field(
        default_factory=dict)

    def scale_for(self, template: str, algebra: Optional[str] = None
                  ) -> float:
        if algebra is not None:
            s = self.anchors.get((template, algebra))
            if s is not None:
                return _clamp(s)
        return _clamp(self.per_template.get(template, 1.0))

    @property
    def templates(self) -> Tuple[str, ...]:
        return tuple(sorted(self.per_template))

    def __bool__(self) -> bool:
        return bool(self.per_template) or bool(self.anchors)


def fit(records: List[Dict[str, Any]]) -> Calibration:
    """Fit scales from measurement records.

    Each record needs ``template``, ``algebra``, ``model_cycles`` and
    ``measured_cycles``; records with non-positive or non-finite cycles
    are skipped (a zero model prediction has no defined ratio).
    """
    ratios: Dict[Tuple[str, str], List[float]] = {}
    for r in records:
        try:
            template = str(r["template"])
            algebra = str(r["algebra"])
            model = float(r["model_cycles"])
            measured = float(r["measured_cycles"])
        except (KeyError, TypeError, ValueError):
            continue
        if (model <= 0 or measured <= 0 or not math.isfinite(model)
                or not math.isfinite(measured)):
            continue
        ratios.setdefault((template, algebra), []).append(measured / model)
    anchors = {pair: _clamp(_geomean(v)) for pair, v in ratios.items()}
    by_template: Dict[str, List[float]] = {}
    for (template, _), s in anchors.items():
        by_template.setdefault(template, []).append(s)
    per_template = {t: _clamp(_geomean(v)) for t, v in by_template.items()}
    return Calibration(per_template=per_template, anchors=anchors)


# ---------------------------------------------------------------------------
# Persistence — calibration.json next to the tuning cache
# ---------------------------------------------------------------------------

def calibration_path() -> Path:
    return _cache.cache_dir() / _FILENAME


def _doc(records: List[Dict[str, Any]], cal: Calibration) -> Dict[str, Any]:
    return {
        "version": SCHEMA_VERSION,
        "records": records,
        "fitted": {
            "per_template": dict(cal.per_template),
            "anchors": [
                {"template": t, "algebra": a, "scale": s}
                for (t, a), s in sorted(cal.anchors.items())],
        },
    }


def load_records() -> List[Dict[str, Any]]:
    """The raw measurement records on disk (empty on any problem)."""
    path = calibration_path()
    try:
        raw = json.loads(path.read_text())
    except (OSError, ValueError):
        return []
    if not isinstance(raw, dict) or raw.get("version") != SCHEMA_VERSION:
        return []
    recs = raw.get("records")
    return ([r for r in recs if isinstance(r, dict)]
        if isinstance(recs, list) else [])


def load() -> Calibration:
    """The persisted calibration, refit from its raw records (the records
    are the source of truth; the fitted block is for humans/reports)."""
    return fit(load_records())


def record(template: str, algebra: str, model_cycles: float,
           measured_cycles: float,
           meta: Optional[Dict[str, Any]] = None) -> Calibration:
    """Append one measurement record, refit, persist, return the new fit.

    Re-recording the same (template, algebra) replaces prior samples for
    that pair — the tuner's newest measurement of a cell supersedes stale
    ones rather than diluting them in the geomean.
    """
    recs = [r for r in load_records()
            if not (r.get("template") == template
                    and r.get("algebra") == algebra)]
    entry: Dict[str, Any] = {
        "template": str(template), "algebra": str(algebra),
        "model_cycles": float(model_cycles),
        "measured_cycles": float(measured_cycles),
    }
    if meta:
        entry["meta"] = meta
    recs.append(entry)
    cal = fit(recs)
    path = calibration_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(_doc(recs, cal), indent=1, sort_keys=True))
    tmp.replace(path)
    return cal
