"""BENCH_tune.json: the machine-readable tuning report (ISSUE 6 sat. 1).

``benchmarks/perf_iterate.py --tune`` emits one document at the repo
root with, per registry cell: modeled vs measured cycles and tuned vs
untuned wall clock.  CI's tune smoke step re-validates the document with
:func:`validate_bench` and fails when the schema drifts — so the file is
a contract, not a printf.

Schema (version 1)::

    {
      "version": 1,
      "smoke": bool,
      "interpret": bool,
      "cells": [
        {
          "cell": str,            # registry cell name
          "algebra": str,
          "dataflow": str,        # winning dataflow name
          "template": str,
          "variant": {"blocks": [int, int, int],
                      "grid_order": str, "accum": str},
          "model_cycles": float,      # analytical prediction
          "calibrated_cycles": float, # prediction x fitted scale
          "measured_cycles": float,   # tuned median at model clock
          "untuned_s": float,         # measured medians (wall clock)
          "tuned_s": float,
          "speedup": float,           # untuned_s / tuned_s  (>= 1.0)
          "tune_cache_hit": bool
        }, ...
      ],
      "calibration": {"per_template": {str: float},
                      "anchors": [{"template": str, "algebra": str,
                                   "scale": float}, ...]}
    }
"""
from __future__ import annotations

from typing import Any, Dict, List

BENCH_SCHEMA_VERSION = 1

_CELL_REQUIRED = {
    "cell": str, "algebra": str, "dataflow": str, "template": str,
    "variant": dict, "model_cycles": (int, float),
    "calibrated_cycles": (int, float), "measured_cycles": (int, float),
    "untuned_s": (int, float), "tuned_s": (int, float),
    "speedup": (int, float), "tune_cache_hit": bool,
}


def validate_bench(doc: Any) -> List[str]:
    """Validate a BENCH_tune.json document; returns a list of problems
    (empty = valid).  Hand-rolled on purpose: no jsonschema dependency,
    and the error strings name the exact offending path."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["document root is not an object"]
    if doc.get("version") != BENCH_SCHEMA_VERSION:
        errors.append(f"version is {doc.get('version')!r}, "
                      f"expected {BENCH_SCHEMA_VERSION}")
    for field in ("smoke", "interpret"):
        if not isinstance(doc.get(field), bool):
            errors.append(f"{field} missing or not a bool")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errors.append("cells missing or empty")
        cells = []
    for i, cell in enumerate(cells):
        where = f"cells[{i}]"
        if not isinstance(cell, dict):
            errors.append(f"{where} is not an object")
            continue
        for name, typ in _CELL_REQUIRED.items():
            v = cell.get(name)
            if (v is None or not isinstance(v, typ)
                    or (typ is not bool and isinstance(v, bool))):
                errors.append(f"{where}.{name} missing or wrong type")
        var = cell.get("variant")
        if isinstance(var, dict):
            blocks = var.get("blocks")
            if not (isinstance(blocks, list) and len(blocks) == 3
                    and all(isinstance(b, int) and b > 0 for b in blocks)):
                errors.append(f"{where}.variant.blocks must be 3 "
                              f"positive ints")
            for f in ("grid_order", "accum"):
                if not isinstance(var.get(f), str):
                    errors.append(f"{where}.variant.{f} missing")
        sp = cell.get("speedup")
        if (isinstance(sp, (int, float)) and not isinstance(sp, bool)
                and sp <= 0):
            errors.append(f"{where}.speedup must be positive")
    cal = doc.get("calibration")
    if not isinstance(cal, dict):
        errors.append("calibration missing or not an object")
    else:
        pt = cal.get("per_template")
        if not isinstance(pt, dict) or not all(
                isinstance(k, str) and isinstance(v, (int, float))
                and not isinstance(v, bool) and v > 0
                for k, v in pt.items()):
            errors.append("calibration.per_template must map template -> "
                          "positive scale")
        anchors = cal.get("anchors")
        if not isinstance(anchors, list):
            errors.append("calibration.anchors must be a list")
        else:
            for j, a in enumerate(anchors):
                if not (isinstance(a, dict)
                        and isinstance(a.get("template"), str)
                        and isinstance(a.get("algebra"), str)
                        and isinstance(a.get("scale"), (int, float))
                        and not isinstance(a.get("scale"), bool)
                        and a["scale"] > 0):
                    errors.append(f"calibration.anchors[{j}] malformed")
    return errors


def cell_entry(*, cell: str, algebra: str, dataflow: str, template: str,
               variant: Dict[str, Any], model_cycles: float,
               calibrated_cycles: float, measured_cycles: float,
               untuned_s: float, tuned_s: float,
               tune_cache_hit: bool) -> Dict[str, Any]:
    """Build one schema-conformant cell entry (keeps the benchmark and
    the validator in one module, so they cannot drift apart)."""
    return {
        "cell": cell, "algebra": algebra, "dataflow": dataflow,
        "template": template,
        "variant": {"blocks": [int(b) for b in variant["blocks"]],
                    "grid_order": str(variant["grid_order"]),
                    "accum": str(variant["accum"])},
        "model_cycles": float(model_cycles),
        "calibrated_cycles": float(calibrated_cycles),
        "measured_cycles": float(measured_cycles),
        "untuned_s": float(untuned_s),
        "tuned_s": float(tuned_s),
        "speedup": float(untuned_s / tuned_s) if tuned_s else 1.0,
        "tune_cache_hit": bool(tune_cache_hit),
    }
