"""Measured autotuning (ISSUE 6): close the cost-model / machine gap.

Layer 2b of the stack — between the analytical pipeline (Layer 2:
``core.dse`` ranks, ``core.tiling`` picks blocks) and the executing
kernels (Layer 3):

* :mod:`~repro.tune.measure` — the one shared wall-clock harness,
* :mod:`~repro.tune.tuner` — timed variant search over candidate
  dataflows x kernel knobs, persisted winners,
* :mod:`~repro.tune.cache` — the on-disk tuning cache ``lower()``
  consults before the analytical tile chooser,
* :mod:`~repro.tune.calibrate` — measured/model cycle scales that turn
  ``PaperCycleModel`` predictions into machine-tracking ones,
* :mod:`~repro.tune.report` — the BENCH_tune.json schema + validator.
"""
from . import cache, calibrate, measure, report, tuner  # noqa: F401
from .calibrate import Calibration, fit as fit_calibration  # noqa: F401
from .calibrate import load as load_calibration  # noqa: F401
from .measure import Measurement, measure as measure_fn  # noqa: F401
from .tuner import TuneResult, Variant, rank_measured, tune  # noqa: F401
from .tuner import GroupTuneResult, GroupVariant, tune_group  # noqa: F401

__all__ = [
    "cache", "calibrate", "measure", "report", "tuner",
    "Calibration", "fit_calibration", "load_calibration",
    "Measurement", "measure_fn",
    "TuneResult", "Variant", "rank_measured", "tune",
    "GroupTuneResult", "GroupVariant", "tune_group",
]
