"""STT-selected Pallas GEMM templates — the paper's PE templates on TPU.

TensorLib's PE-internal modules (paper Fig. 3) map onto VMEM block residency
choices (DESIGN.md §2, level 1).  One template per stationary choice:

* ``output_stationary``  (paper (a)(a)(d), e.g. MNK-SST): the C block is the
  VMEM-resident accumulator across the reduction grid axis; A/B blocks are
  streamed by the Pallas pipeline (the software analogue of systolic
  injection — deviation D1).

* ``operand_stationary`` (paper (a)(c)(b), e.g. MNK-STS / MNK-TSS): the
  chosen operand block stays resident while the *output* streams through,
  read-modify-write accumulated in HBM via input/output aliasing — exactly
  the WS-vs-OS traffic trade the paper's dataflows expose.

* ``reduction_tree``     (paper (f)+tree, e.g. K-spatial dataflows): the
  whole reduction axis is materialized in one block and reduced inside the
  MXU pass — the combinational-adder-tree analogue.  Requires K blocks to
  fit VMEM.

Every template carries a leading **batch grid axis** (parallel, outermost):
operands may be rank 3 — ``(B, m, k) @ (B, k, n)`` — with a rank-2 operand
broadcast across the batch via its BlockSpec index map (the batch
coordinate is pinned to 0).  This is how the grid-folded algebra lowerings
(batched_gemv's batch loop, depthwise_conv's channel loop) execute exactly
the algebra's MACs: the batch iterator is a grid dimension, never
contraction padding.  Rank-2 inputs take the degenerate batch=1 path and
return rank-2 outputs, so plain GEMM call sites are unchanged.

All grids end with the revisited axis innermost, so the Mosaic pipeline
double-buffers streamed operands (compute/DMA overlap).  Block shapes
default to the MXU-aligned 128 and are validated in ``interpret=True``
mode on CPU (tests sweep shapes, batches and dtypes).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import epilogue as _ep
from . import pallas_compat as _compat


DEFAULT_BLOCK = 128
#: per-core VMEM available for kernel scratch (TPU ~16 MB/core); the
#: operand-stationary strip accumulator must fit in it.
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


def _validate(m, n, k, bm, bn, bk):
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks "
                         f"({bm},{bn},{bk}); ops.stt_matmul pads first")


def _as_batched(a: jax.Array, b: jax.Array
                ) -> Tuple[jax.Array, jax.Array, int, bool]:
    """Lift operands to rank 3 under a shared leading batch extent.

    A rank-2 operand becomes ``(1, m, k)`` and broadcasts across the batch
    grid axis (its index map pins the batch coordinate to 0).  Returns
    ``(a3, b3, nb, squeeze)`` where ``squeeze`` says both inputs were 2-D
    and the caller should return a rank-2 output.
    """
    if a.ndim not in (2, 3) or b.ndim not in (2, 3):
        raise ValueError(f"operands must be rank 2 or 3, got "
                         f"{a.shape} x {b.shape}")
    squeeze = a.ndim == 2 and b.ndim == 2
    a3 = a if a.ndim == 3 else a[None]
    b3 = b if b.ndim == 3 else b[None]
    nb = max(a3.shape[0], b3.shape[0])
    if a3.shape[0] not in (1, nb) or b3.shape[0] not in (1, nb):
        raise ValueError(f"batch dims must match or broadcast, got "
                         f"{a.shape} x {b.shape}")
    return a3, b3, nb, squeeze


def _bspec(block: Tuple[int, int], batched: bool, imap):
    """A rank-3 BlockSpec with batch block 1: ``imap`` gives the 2-D block
    coordinate; un-batched operands pin the batch coordinate to 0."""
    if batched:
        return pl.BlockSpec((1,) + block,
                            lambda bb, *ij: (bb,) + imap(*ij))
    return pl.BlockSpec((1,) + block, lambda bb, *ij: (0,) + imap(*ij))


def _check_epilogue(epilogue: Tuple[str, ...], bias, n: int, bn: int
                    ) -> Tuple[str, ...]:
    """Validate an epilogue spec against the template geometry.  Returns
    the normalized spec; the reshaped rank-2 bias ``(1, n)`` is produced
    by :func:`_bias2d`."""
    epilogue = _ep.validate_spec(epilogue)
    if _ep.needs_bias(epilogue) and bias is None:
        raise ValueError(f"epilogue {epilogue} needs a bias operand")
    if bias is not None and not _ep.needs_bias(epilogue):
        raise ValueError(f"bias operand given but epilogue {epilogue} "
                         f"has no 'bias' op")
    if _ep.has_softmax(epilogue) and bn != n:
        raise ValueError(
            f"softmax epilogue needs one output block spanning the full "
            f"row (bn == n), got bn={bn} n={n}; a partial row cannot be "
            f"normalized block-locally")
    return epilogue


def _bias2d(bias, n: int) -> jax.Array:
    bias = jnp.asarray(bias)
    if bias.shape != (n,):
        raise ValueError(f"bias must be rank-1 of length n={n}, "
                         f"got shape {bias.shape}")
    return bias.astype(jnp.float32).reshape(1, n)


def _flush_block(acc, bias_ref, epilogue: Tuple[str, ...], out_dtype):
    """The shared flush: epilogue on the fp32 block, then cast."""
    if epilogue:
        b = bias_ref[...] if bias_ref is not None else None
        acc = _ep.apply_epilogue(acc, epilogue, bias=b)
    return acc.astype(out_dtype)


def operand_stationary_strip_bytes(m: int, bn: int) -> int:
    """VMEM footprint of the (m, bn) fp32 strip accumulator the
    operand-stationary template allocates **per batch slice** (the batch
    grid axis is outermost, so only one slice's strip is live at a time —
    see matmul_operand_stationary)."""
    return m * bn * 4


# ---------------------------------------------------------------------------
# output-stationary (SST-class): C resident, A/B streamed
# ---------------------------------------------------------------------------
# Two tunable knobs (measured autotuning searches over both):
#
# * ``grid_order`` — the contraction grid order.  "mnk" (default) and
#   "nmk" keep the reduction innermost so the scratch accumulator stays
#   live across k-steps and Mosaic double-buffers the streamed A/B blocks
#   (the double-buffered operand-streaming variants differ in which
#   operand's blocks get the streaming reuse).  "kmn"/"knm" hoist the
#   reduction outermost — the output block is revisited and accumulated
#   in place instead, which trades accumulator residency for streaming
#   the full C through VMEM once per k-step.
#
# * ``accum`` — "scratch" accumulates in an fp32 VMEM scratch buffer and
#   casts once at the final k-step (exact for bf16 inputs); "inplace"
#   accumulates directly in the output block *in the output dtype* — the
#   bf16-direct accumulation strategy (cheaper residency, lossier sums).
#   k-outer grid orders require "inplace" (one scratch block cannot
#   survive a full sweep of the other axes between k-steps).

#: valid output-stationary grid orders (batch axis is always outermost)
OS_GRID_ORDERS = ("mnk", "nmk", "kmn", "knm")
ACCUM_MODES = ("scratch", "inplace")


def _os_kernel_scratch(a_ref, b_ref, *rest, n_k: int, k_axis: int,
                       out_dtype, epilogue: Tuple[str, ...] = ()):
    bias_ref = rest[0] if len(rest) == 3 else None
    o_ref, acc_ref = rest[-2], rest[-1]
    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
    acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                            preferred_element_type=jnp.float32)
    @pl.when(pl.program_id(k_axis) == n_k - 1)
    def _flush():
        o_ref[0] = _flush_block(acc_ref[...], bias_ref, epilogue, out_dtype)


def _os_kernel_inplace(a_ref, b_ref, *rest, n_k: int, k_axis: int,
                       out_dtype, epilogue: Tuple[str, ...] = ()):
    bias_ref = rest[0] if len(rest) == 2 else None
    o_ref = rest[-1]
    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])
    o_ref[0] += jnp.dot(a_ref[0], b_ref[0],
                        preferred_element_type=jnp.float32).astype(out_dtype)
    if epilogue:
        # the accumulated block is final at the last k-step; the epilogue
        # reads it back at fp32 (the in-place strategy's usual precision
        # trade applies to the pre-epilogue sums)
        @pl.when(pl.program_id(k_axis) == n_k - 1)
        def _epi():
            o_ref[0] = _flush_block(o_ref[0].astype(jnp.float32), bias_ref,
                                    epilogue, out_dtype)


def matmul_output_stationary(a: jax.Array, b: jax.Array, *,
                             bm: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK,
                             bk: int = DEFAULT_BLOCK,
                             grid_order: str = "mnk",
                             accum: str = "scratch",
                             out_dtype=None, interpret: bool = False,
                             epilogue: Tuple[str, ...] = (),
                             bias: Optional[jax.Array] = None
                             ) -> jax.Array:
    from jax.experimental.pallas import tpu as pltpu
    if grid_order == "default":
        grid_order = "mnk"
    elif grid_order in ("mn", "nm"):    # reduction-tree spelling: k innermost
        grid_order += "k"
    if grid_order not in OS_GRID_ORDERS:
        raise ValueError(f"grid_order must be one of {OS_GRID_ORDERS}, "
                         f"got {grid_order!r}")
    if accum not in ACCUM_MODES:
        raise ValueError(f"accum must be one of {ACCUM_MODES}, "
                         f"got {accum!r}")
    if accum == "scratch" and grid_order[-1] != "k":
        raise ValueError(
            f"grid_order {grid_order!r} revisits the output block between "
            f"k-steps, which a single scratch accumulator cannot survive; "
            f"use accum='inplace' for k-outer orders")
    a3, b3, nb, squeeze = _as_batched(a, b)
    (m, k), n = a3.shape[1:], b3.shape[2]
    _validate(m, n, k, bm, bn, bk)
    epilogue = _check_epilogue(epilogue, bias, n, bn)
    out_dtype = out_dtype or a.dtype
    n_k = k // bk
    counts = {"m": m // bm, "n": n // bn, "k": n_k}
    ix = {c: i for i, c in enumerate(grid_order)}   # imap arg position
    k_axis = 1 + ix["k"]                            # grid axis incl. batch
    if accum == "scratch":
        kernel = functools.partial(_os_kernel_scratch, n_k=n_k,
                                   k_axis=k_axis, out_dtype=out_dtype,
                                   epilogue=epilogue)
        scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    else:
        kernel = functools.partial(_os_kernel_inplace, n_k=n_k,
                                   k_axis=k_axis, out_dtype=out_dtype,
                                   epilogue=epilogue)
        scratch = []
    semantics = ("parallel",) + tuple(
        "arbitrary" if c == "k" else "parallel" for c in grid_order)
    in_specs = [_bspec((bm, bk), a3.shape[0] > 1,
                       lambda *ids: (ids[ix["m"]], ids[ix["k"]])),
                _bspec((bk, bn), b3.shape[0] > 1,
                       lambda *ids: (ids[ix["k"]], ids[ix["n"]]))]
    inputs = [a3, b3]
    if bias is not None:
        in_specs.append(pl.BlockSpec(
            (1, bn), lambda bb, *ids: (0, ids[ix["n"]])))
        inputs.append(_bias2d(bias, n))
    out = pl.pallas_call(
        kernel,
        grid=(nb,) + tuple(counts[c] for c in grid_order),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bm, bn),
            lambda bb, *ids: (bb, ids[ix["m"]], ids[ix["n"]])),
        out_shape=jax.ShapeDtypeStruct((nb, m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(*inputs)
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# operand-stationary (STS/TSS-class): operand resident, C strip accumulator
# ---------------------------------------------------------------------------
# On TPU there is no inter-PE wire to stream partial sums through (deviation
# D1), so the streamed-output systolic module (b) becomes a VMEM *strip*
# accumulator: while the stationary operand block is pinned, the entire
# output strip it contributes to lives in VMEM and the other operand streams
# past it.  VMEM bound: strip_len * block * 4B per batch slice (checked).

def _ws_kernel(a_ref, b_ref, *rest, n_k: int, bm: int, out_dtype,
               epilogue: Tuple[str, ...] = ()):
    bias_ref = rest[0] if len(rest) == 3 else None
    o_ref, acc_ref = rest[-2], rest[-1]
    kk, i = pl.program_id(2), pl.program_id(3)
    sl = pl.ds(i * bm, bm)
    @pl.when(kk == 0)
    def _init():
        acc_ref[sl, :] = jnp.zeros_like(acc_ref[sl, :])
    acc_ref[sl, :] += jnp.dot(a_ref[0], b_ref[0],
                              preferred_element_type=jnp.float32)
    @pl.when(kk == n_k - 1)
    def _flush():
        o_ref[0] = _flush_block(acc_ref[sl, :], bias_ref, epilogue,
                                out_dtype)


def matmul_operand_stationary(a: jax.Array, b: jax.Array, *,
                              stationary: str = "B",
                              bm: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK,
                              bk: int = DEFAULT_BLOCK,
                              out_dtype=None, interpret: bool = False,
                              vmem_budget: Optional[int] = DEFAULT_VMEM_BUDGET,
                              epilogue: Tuple[str, ...] = (),
                              bias: Optional[jax.Array] = None
                              ) -> jax.Array:
    """``stationary='B'``: grid (batch, n, k, m) keeps the B block pinned
    while A streams (weight-stationary);  ``stationary='A'`` is the
    symmetric input-stationary template (implemented by transposition
    symmetry: C^T = B^T A^T with B^T stationary, batch dims untouched).

    The strip accumulator scratch is (m, bn) fp32 per batch slice — a VMEM
    residency that grows with the *full* per-slice M extent, not a block
    (the batch grid axis is outermost, so slices reuse one strip).
    ``vmem_budget`` bounds it (pass None to skip the check);
    ``ops.stt_matmul`` auto-falls-back to the output-stationary template
    instead of tripping this error.
    """
    from jax.experimental.pallas import tpu as pltpu
    if stationary == "A":
        if epilogue:
            # the transposition realization swaps the m/n axes, so a
            # last-axis epilogue would act on the wrong dimension;
            # ops.stt_matmul reroutes epilogue'd calls to the
            # output-stationary template before reaching here
            raise ValueError("epilogue fusion is not supported on the "
                             "input-stationary (stationary='A') "
                             "transposition path")
        out = matmul_operand_stationary(
            jnp.swapaxes(b, -1, -2), jnp.swapaxes(a, -1, -2),
            stationary="B", bm=bn, bn=bm, bk=bk,
            out_dtype=out_dtype, interpret=interpret,
            vmem_budget=vmem_budget)
        return jnp.swapaxes(out, -1, -2)
    if stationary != "B":
        raise ValueError(stationary)
    a3, b3, nb, squeeze = _as_batched(a, b)
    (m, k), n = a3.shape[1:], b3.shape[2]
    _validate(m, n, k, bm, bn, bk)
    epilogue = _check_epilogue(epilogue, bias, n, bn)
    strip = operand_stationary_strip_bytes(m, bn)
    if vmem_budget is not None and strip > vmem_budget:
        raise ValueError(
            f"operand-stationary strip accumulator needs {strip} bytes of "
            f"VMEM per batch slice ((m={m}) x (bn={bn}) x 4B) but the "
            f"budget is {vmem_budget}; shrink bn, tile m outside the "
            f"kernel, or use the output_stationary template "
            f"(ops.stt_matmul falls back automatically)")
    out_dtype = out_dtype or a.dtype
    n_k = k // bk
    kernel = functools.partial(_ws_kernel, n_k=n_k, bm=bm,
                               out_dtype=out_dtype, epilogue=epilogue)
    in_specs = [_bspec((bm, bk), a3.shape[0] > 1,
                       lambda j, kk, i: (i, kk)),
                # B block constant along the inner m axis -> VMEM-resident
                _bspec((bk, bn), b3.shape[0] > 1,
                       lambda j, kk, i: (kk, j))]
    inputs = [a3, b3]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn),
                                     lambda bb, j, kk, i: (0, j)))
        inputs.append(_bias2d(bias, n))
    out = pl.pallas_call(
        kernel,
        grid=(nb, n // bn, n_k, m // bm),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn),
                               lambda bb, j, kk, i: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(*inputs)
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# reduction-tree (K-spatial class): full-K blocks, single MXU reduction
# ---------------------------------------------------------------------------

def _rt_kernel(a_ref, b_ref, *rest, out_dtype,
               epilogue: Tuple[str, ...] = ()):
    bias_ref = rest[0] if len(rest) == 2 else None
    o_ref = rest[-1]
    acc = jnp.dot(a_ref[0], b_ref[0], preferred_element_type=jnp.float32)
    o_ref[0] = _flush_block(acc, bias_ref, epilogue, out_dtype)


#: valid reduction-tree grid orders (no k axis: the whole reduction runs
#: inside one MXU pass)
RT_GRID_ORDERS = ("mn", "nm")


def matmul_reduction_tree(a: jax.Array, b: jax.Array, *,
                          bm: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK,
                          grid_order: str = "mn",
                          out_dtype=None, interpret: bool = False,
                          epilogue: Tuple[str, ...] = (),
                          bias: Optional[jax.Array] = None
                          ) -> jax.Array:
    if grid_order == "default":
        grid_order = "mn"
    if grid_order not in RT_GRID_ORDERS:
        raise ValueError(f"grid_order must be one of {RT_GRID_ORDERS}, "
                         f"got {grid_order!r}")
    a3, b3, nb, squeeze = _as_batched(a, b)
    (m, k), n = a3.shape[1:], b3.shape[2]
    _validate(m, n, k, bm, bn, k)
    epilogue = _check_epilogue(epilogue, bias, n, bn)
    out_dtype = out_dtype or a.dtype
    counts = {"m": m // bm, "n": n // bn}
    ix = {c: i for i, c in enumerate(grid_order)}
    kernel = functools.partial(_rt_kernel, out_dtype=out_dtype,
                               epilogue=epilogue)
    in_specs = [_bspec((bm, k), a3.shape[0] > 1,
                       lambda *ids: (ids[ix["m"]], 0)),
                _bspec((k, bn), b3.shape[0] > 1,
                       lambda *ids: (0, ids[ix["n"]]))]
    inputs = [a3, b3]
    if bias is not None:
        in_specs.append(pl.BlockSpec(
            (1, bn), lambda bb, *ids: (0, ids[ix["n"]])))
        inputs.append(_bias2d(bias, n))
    out = pl.pallas_call(
        kernel,
        grid=(nb,) + tuple(counts[c] for c in grid_order),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bm, bn), lambda bb, *ids: (bb, ids[ix["m"]], ids[ix["n"]])),
        out_shape=jax.ShapeDtypeStruct((nb, m, n), out_dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(*inputs)
    return out[0] if squeeze else out


TEMPLATES = {
    "output_stationary": matmul_output_stationary,
    "operand_stationary": matmul_operand_stationary,
    "reduction_tree": matmul_reduction_tree,
    # 'streaming' (all-unicast) has no reuse to exploit: realize as
    # reduction-tree (single pass, no residency) — documented equivalence.
    "streaming": matmul_reduction_tree,
}
