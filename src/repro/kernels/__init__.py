"""Pallas TPU kernels for the performance hot-spots, selected by STT plans.

Modules:
    stt_gemm         — GEMM templates (output/operand-stationary, reduction)
    flash_attention  — blockwise online-softmax attention (GQA/causal/SWA)
    ssd_scan         — Mamba-2 SSD chunked scan
    ops              — jit'd public wrappers (+ padding, dtype policy, XLA path)
    ref              — pure-jnp oracles (ground truth + CPU execution path)
"""
from . import flash_attention, ops, ref, ssd_scan, stt_gemm

__all__ = ["flash_attention", "ops", "ref", "ssd_scan", "stt_gemm"]
