"""Paged-cache gather: page-table indirection as a Pallas kernel.

The serving page pool (``repro.serve.pages``) stores every resident
sequence's K/V as fixed-size pages in one shared pool ``(P, page, F)``;
a per-slot page table maps slot ``c``'s logical page ``j`` to a physical
page id.  Assembling the contiguous per-slot decode view is a gather —
and a gather driven by a runtime index list is exactly the
scalar-prefetch + BlockSpec-index-map machinery the BSR kernel uses
(``pltpu.PrefetchScalarGridSpec``): the grid iterates (slot, logical
page) and the *input* index map dereferences the page table, so each
grid step DMAs one physical page straight into its view position.

``paged_gather`` is the jnp twin (a constant-free ``take`` the compiler
fuses); ``paged_gather_pallas`` is the kernel, bit-identical because both
are pure copies (tested).  CPU serving uses the jnp twin — interpret-mode
Pallas would dominate the step time — while the kernel is the TPU path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_compat as _compat


def paged_gather(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """pool (P, page, F) x page_table (C, n) int32 -> view (C, n*page, F).

    Unmapped table entries must already be clamped to a valid physical
    page (the pool reserves a scratch page); validity masking is the
    caller's job — attention masks by absolute position, so garbage rows
    contribute exactly zero.
    """
    p, page, f = pool.shape
    c, n = page_table.shape
    return jnp.take(pool, page_table.reshape(-1), axis=0).reshape(
        c, n * page, f)


def _gather_kernel(table_ref, pool_ref, out_ref):
    del table_ref  # dereferenced by the BlockSpec index maps
    out_ref[0, 0] = pool_ref[0]


def paged_gather_pallas(pool: jax.Array, page_table: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """The Pallas twin of :func:`paged_gather`: grid (C, n), one page DMA
    per step, page table scalar-prefetched into the index maps."""
    from jax.experimental.pallas import tpu as pltpu

    p, page, f = pool.shape
    c, n = page_table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c, n),
        in_specs=[
            pl.BlockSpec((1, page, f), lambda i, j, t: (t[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, page, f), lambda i, j, t: (i, j, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, n, page, f), pool.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pool)
    return out.reshape(c, n * page, f)


def paged_scatter_token(pool: jax.Array, page_id: jax.Array,
                        offset: jax.Array, values: jax.Array) -> jax.Array:
    """Write one token row per slot back into the pool.

    pool (P, page, F); page_id / offset (C,) int32 — the physical page and
    in-page offset each slot's write position resolves to; values (C, F).
    Slots that must not write are pointed at the pool's scratch page by
    the caller (exact no-op for live data).  Returns the updated pool.
    """
    return pool.at[page_id, offset].set(values.astype(pool.dtype))
