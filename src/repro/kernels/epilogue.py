"""Epilogue ops fused into the GEMM templates' output-block flush.

An *epilogue* is an ordered tuple of op strings applied to a kernel's
fp32 output block right before it is cast and written back — the
TensorLib analogue of folding a post-processing module onto the PE
array's drain path, and the fusion primitive `repro.graph` uses to
collapse ``gemm -> activation`` chains into one Pallas kernel (no HBM
round-trip for the intermediate).

Spec grammar (hashable, jit-static-argument friendly)::

    ("scale:0.125", "softmax")       # attention score epilogue
    ("bias", "gelu")                 # MLP hidden epilogue

* ``scale:<float>`` — multiply by a compile-time constant,
* ``bias``          — add a rank-1 bias over the last (n) axis; the
  templates stream the bias vector as an extra blocked operand,
* unary activations — ``relu`` / ``gelu`` / ``silu`` / ``tanh`` /
  ``exp``,
* ``softmax``       — row softmax over the last axis.  Only legal when
  one output block spans the *entire unpadded* n extent (``bn == n``):
  a partial row cannot be normalized block-locally.  ``ops.stt_matmul``
  enforces this.

Semantics: every op acts on the **2-D matmul output** ``(m, n)`` before
``LoweredForm.finish``.  For forms whose finish is a pure reshape that
keeps the last tensor axis equal to ``n`` (gemm is the canonical case)
this coincides with acting on the finished tensor — the graph layer's
fusion-legality check (`repro.graph.planner`) only fuses when the two
views agree, and otherwise applies the epilogue unfused on the finished
tensor.

``apply_epilogue`` is pure jnp so the same function runs inside a
Pallas kernel body (on a VMEM block) and outside (on a full array — the
unfused fallback and the oracle); ``apply_epilogue_np`` mirrors it in
numpy for ``AlgebraGraph.reference``.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: an ordered, hashable epilogue: tuple of op strings
EpilogueSpec = Tuple[str, ...]


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


_UNARY = {
    "relu": lambda x: jnp.maximum(x, jnp.zeros((), x.dtype)),
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "tanh": jnp.tanh,
    "exp": jnp.exp,
    "softmax": _softmax,
}


def parse_op(op: str) -> Tuple[str, Optional[float]]:
    """``"name"`` or ``"name:param"`` -> (name, param).  Raises on ops
    outside the registry (the spec doubles as a cache-key component, so
    unknown strings must fail loudly, not silently no-op)."""
    name, _, param = op.partition(":")
    if name == "scale":
        try:
            return name, float(param)
        except ValueError:
            raise ValueError(f"scale epilogue needs a float parameter, "
                             f"got {op!r}") from None
    if param:
        raise ValueError(f"epilogue op {name!r} takes no parameter "
                         f"(got {op!r})")
    if name == "bias" or name in _UNARY:
        return name, None
    raise ValueError(f"unknown epilogue op {op!r}; known: "
                     f"{sorted(_UNARY) + ['bias', 'scale:<f>']}")


def validate_spec(spec: Iterable[str]) -> EpilogueSpec:
    """Normalize to a tuple and validate every op; at most one ``bias``
    (the templates stream exactly one bias operand)."""
    out = tuple(spec)
    for op in out:
        parse_op(op)
    if sum(1 for op in out if op == "bias") > 1:
        raise ValueError(f"epilogue {out} has more than one 'bias' op")
    return out


def needs_bias(spec: Iterable[str]) -> bool:
    return "bias" in tuple(spec)


def has_softmax(spec: Iterable[str]) -> bool:
    return "softmax" in tuple(spec)


def apply_epilogue(x: jax.Array, spec: Iterable[str], *,
                   bias: Optional[jax.Array] = None) -> jax.Array:
    """Apply the spec to ``x`` (last axis = n).  Pure jnp: callable on a
    VMEM block inside a Pallas kernel and on a full array outside."""
    for op in spec:
        name, param = parse_op(op)
        if name == "scale":
            x = x * jnp.asarray(param, dtype=x.dtype)
        elif name == "bias":
            if bias is None:
                raise ValueError("epilogue 'bias' needs a bias operand")
            x = x + bias.astype(x.dtype)
        else:
            x = _UNARY[name](x)
    return x


# ---------------------------------------------------------------------------
# numpy mirror — the graph oracle's epilogue reference
# ---------------------------------------------------------------------------

def _np_gelu(x):
    # jax.nn.gelu(approximate=True): tanh approximation
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x ** 3)))


def _np_softmax(x):
    m = np.max(x, axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / np.sum(e, axis=-1, keepdims=True)


_UNARY_NP = {
    "relu": lambda x: np.maximum(x, 0.0),
    "gelu": _np_gelu,
    "silu": lambda x: x / (1.0 + np.exp(-x)),
    "tanh": np.tanh,
    "exp": np.exp,
    "softmax": _np_softmax,
}


def apply_epilogue_np(x: np.ndarray, spec: Iterable[str], *,
                      bias: Optional[np.ndarray] = None) -> np.ndarray:
    """numpy mirror of :func:`apply_epilogue` (fp64-friendly oracle)."""
    x = np.asarray(x, dtype=np.float64)
    for op in spec:
        name, param = parse_op(op)
        if name == "scale":
            x = x * param
        elif name == "bias":
            if bias is None:
                raise ValueError("epilogue 'bias' needs a bias operand")
            x = x + np.asarray(bias, dtype=np.float64)
        else:
            x = _UNARY_NP[name](x)
    return x
