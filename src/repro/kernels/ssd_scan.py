"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD recurrence  h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T,
y_t = C_t . h_t  is computed chunk-by-chunk: a quadratic (attention-like)
intra-chunk term feeds the MXU, while the inter-chunk state is the
*stationary* tensor of the dataflow — it lives in VMEM scratch across the
sequential chunk axis.  This is the same STT story as the GEMM templates:
the chunk axis is time, the state is rank-1 stationary (dp = 0, dt != 0).

Inputs are pre-processed by ops.ssd: dt is folded into x (xdt = dt * x), the
per-step log-decay da = dt * a is passed separately, and B/C are broadcast
from groups to heads.  Shapes inside the kernel (per (batch*head, chunk)):

    xdt (Q, P), b (Q, N), c (Q, N), da (Q,) -> y (Q, P), state (N, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_compat as _compat


def _ssd_kernel(da_ref, x_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    da = da_ref[0].astype(jnp.float32)            # (Q,)
    x = x_ref[0].astype(jnp.float32)              # (Q, P) — dt already folded
    b = b_ref[0].astype(jnp.float32)              # (Q, N)
    c = c_ref[0].astype(jnp.float32)              # (Q, N)

    lc = jnp.cumsum(da)                           # (Q,) inclusive log decay

    # intra-chunk (quadratic, MXU): y[i] = sum_{j<=i} e^{lc_i-lc_j} (C_i.B_j) x_j
    s = jnp.dot(c, b.T, preferred_element_type=jnp.float32)       # (Q, Q)
    dmat = lc[:, None] - lc[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >=
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    m = jnp.exp(jnp.where(tri, dmat, -1e9))   # mask before exp (see ref.py)
    y = jnp.dot(s * m, x, preferred_element_type=jnp.float32)     # (Q, P)

    # inter-chunk: y[i] += C_i . (e^{lc_i} * h_in)
    y += jnp.exp(lc)[:, None] * jnp.dot(c, state_ref[...],
                                        preferred_element_type=jnp.float32)

    # state update: h_out = e^{lc_Q} h_in + sum_j e^{lc_Q - lc_j} B_j x_j^T
    w = jnp.exp(lc[-1] - lc)                      # (Q,)
    state_ref[...] = jnp.exp(lc[-1]) * state_ref[...] + jnp.dot(
        (b * w[:, None]).T, x, preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(xdt: jax.Array, da: jax.Array, b: jax.Array, c: jax.Array, *,
             chunk: int = 64, interpret: bool = False) -> jax.Array:
    """Chunked SSD over flattened (batch*head) sequences.

    xdt: (BH, L, P) with dt folded in;  da: (BH, L) log decays;
    b, c: (BH, L, N) per-head (already group-broadcast).  Returns y (BH, L, P).
    """
    from jax.experimental.pallas import tpu as pltpu
    bh, l, p = xdt.shape
    n = b.shape[-1]
    if l % chunk:
        raise ValueError(f"L={l} not divisible by chunk={chunk}")
    nc = l // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(da, xdt, b, c)
