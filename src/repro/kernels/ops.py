"""Public jit'd wrappers for the Pallas kernels.

These handle padding to block multiples, dtype policy (bf16 in / fp32
accumulate), template dispatch from an STT ``KernelPlan``, and the
CPU fallback (``backend='xla'`` routes to the jnp oracle so the same call
sites work in dry-runs and on real TPUs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.plan import KernelPlan
from . import bsr_gemm as _bsr
from . import epilogue as _ep
from . import flash_attention as _fa
from . import ref as _ref
from . import ssd_scan as _ssd
from . import stt_gemm as _gemm


def _pad_to(x: jax.Array, mults: tuple) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------

def resolve_accum(accum: str, out_dtype) -> str:
    """The accumulation-strategy policy (the bf16 knob the tuner selects
    over): ``"auto"`` picks the numerically safe default — fp32 scratch
    accumulation — for *every* dtype, because bf16 inputs lose reduction
    precision when partial sums round to bf16 each k-step.  The tuner may
    explicitly select ``"inplace"`` (direct accumulation in the output
    dtype — for bf16, the bf16-direct strategy) when the variant still
    validates within tolerance; callers can force either mode."""
    if accum == "auto":
        return "scratch"
    if accum not in _gemm.ACCUM_MODES:
        raise ValueError(f"accum must be 'auto' or one of "
                         f"{_gemm.ACCUM_MODES}, got {accum!r}")
    return accum


def _rt_order(grid_order: str) -> str:
    """Project a 3-axis grid order onto the reduction-tree's (m, n) grid
    (its whole reduction runs inside one MXU pass, so 'k' drops out)."""
    if grid_order == "default":
        return "mn"
    order = "".join(c for c in grid_order if c in "mn")
    return order if order in _gemm.RT_GRID_ORDERS else "mn"


@functools.partial(jax.jit, static_argnames=(
    "template", "stationary", "bm", "bn", "bk", "backend", "interpret",
    "vmem_budget", "grid_order", "accum", "epilogue"))
def stt_matmul(a: jax.Array, b: jax.Array, *, template: str = "output_stationary",
               stationary: str = "B", bm: int = 128, bn: int = 128,
               bk: int = 128, backend: str = "pallas",
               interpret: bool = False,
               vmem_budget: Optional[int] = _gemm.DEFAULT_VMEM_BUDGET,
               grid_order: str = "default", accum: str = "auto",
               epilogue: tuple = (),
               bias: Optional[jax.Array] = None
               ) -> jax.Array:
    """C = A @ B with the Pallas template selected by an STT dataflow.

    Operands may carry a leading batch dim (``(B, m, k) @ (B, k, n)``; a
    rank-2 operand broadcasts across the batch) — the templates fold it
    onto a leading parallel grid axis, so a grid-folded algebra lowering
    executes exactly the algebra's MACs.  Per-slice m/n/k are padded to
    block multiples; the batch dim never needs padding (batch block = 1).

    ``vmem_budget`` caps the operand-stationary strip accumulator, which
    is allocated **per batch slice**: when the per-slice (m, bn) fp32
    strip would not fit, the call falls back to the output-stationary
    template (same math, block-local residency) instead of erroring — the
    compile pipeline relies on this safety net.

    ``grid_order`` and ``accum`` are the measured-autotuning knobs (see
    ``kernels/stt_gemm.py``): contraction grid order for the output-
    stationary / reduction-tree templates, and the accumulation strategy
    (``resolve_accum``).  The operand-stationary template has its own
    fixed streaming order, so the knobs apply to it only after the VMEM
    fallback reroutes to the output-stationary template.

    ``epilogue`` is a static tuple of post-processing ops
    (``kernels/epilogue.py``) fused into the template's output-block
    flush; ``bias`` is the extra rank-1 operand a ``"bias"`` op streams.
    A ``"softmax"`` op needs one block spanning the whole unpadded row
    (``bn >= n``) — a partial or padded row cannot be normalized
    block-locally — so the call raises instead of silently computing a
    wrong softmax; the graph planner treats that as fusion illegality
    and applies the epilogue outside the kernel.
    """
    epilogue = _ep.validate_spec(epilogue)
    if backend == "xla":
        out = _ref.matmul_ref(a, b, out_dtype=jnp.float32)
        if epilogue:
            out = _ep.apply_epilogue(out, epilogue, bias=bias)
        return out.astype(a.dtype)
    m, k = a.shape[-2:]
    n = b.shape[-1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if _ep.has_softmax(epilogue) and (bn != n or n % bn):
        raise ValueError(
            f"softmax epilogue needs one unpadded output block covering "
            f"the full row: bn >= n and n % bn == 0 (got bn={bn}, n={n})")
    ap = _pad_to(a, (1,) * (a.ndim - 2) + (bm, bk))
    bp = _pad_to(b, (1,) * (b.ndim - 2) + (bk, bn))
    if bias is not None:
        # padded n columns get bias 0 and are sliced off below
        bias = _pad_to(jnp.asarray(bias), (bn,))
    if epilogue and template == "operand_stationary" and stationary == "A":
        # the input-stationary realization transposes m/n (stt_gemm), so
        # a last-axis epilogue cannot ride it; same math, other template
        template = "output_stationary"
    if template == "operand_stationary" and vmem_budget is not None:
        # the strip extent follows the *streamed-output* dimension of one
        # batch slice: M for stationary B, N for stationary A
        # (transposition symmetry)
        strip_len = ap.shape[-2] if stationary == "B" else bp.shape[-1]
        strip_bn = bn if stationary == "B" else bm
        if (_gemm.operand_stationary_strip_bytes(strip_len, strip_bn)
                > vmem_budget):
            template = "output_stationary"
    kw = dict(bm=bm, bn=bn, bk=bk, interpret=interpret,
              epilogue=epilogue, bias=bias)
    if template == "output_stationary":
        out = _gemm.matmul_output_stationary(
            ap, bp, grid_order=grid_order,
            accum=resolve_accum(accum, a.dtype), **kw)
    elif template == "operand_stationary":
        out = _gemm.matmul_operand_stationary(ap, bp, stationary=stationary,
                                              vmem_budget=vmem_budget, **kw)
    elif template in ("reduction_tree", "streaming"):
        kw.pop("bk")
        out = _gemm.matmul_reduction_tree(ap, bp,
                                          grid_order=_rt_order(grid_order),
                                          **kw)
    else:
        raise ValueError(f"unknown template {template!r}")
    return out[..., :m, :n]


@functools.partial(jax.jit, static_argnames=(
    "coords", "block", "bstream", "side", "backend", "interpret"))
def bsr_matmul(sparse: jax.Array, dense: jax.Array, *,
               coords: _bsr.Coords, block: tuple, bstream: int = 128,
               side: str = "lhs", backend: str = "pallas",
               interpret: bool = False) -> jax.Array:
    """Block-sparse GEMM with one block-COO operand (zeros outside the
    static ``coords`` pattern are skipped by the kernel grid).

    ``side='lhs'``: C = sparse @ dense, ``sparse`` (m, k) with ``block`` =
    (bm, bk) blocks; ``bstream`` tiles the streamed n dimension.
    ``side='rhs'``: C = dense @ sparse, realized by transposition symmetry
    (C^T = sparse^T @ dense^T) so one kernel serves both operand sides.
    ``backend='xla'`` routes to a plain jnp matmul (the operand is already
    masked, so the dense product is the masked oracle).
    """
    if side not in ("lhs", "rhs"):
        raise ValueError(f"side must be 'lhs' or 'rhs', got {side!r}")
    if backend == "xla":
        out = (sparse @ dense) if side == "lhs" else (dense @ sparse)
        return out
    if side == "rhs":
        return bsr_matmul(sparse.T, dense.T,
                          coords=_bsr.transpose_coords(coords),
                          block=(block[1], block[0]), bstream=bstream,
                          side="lhs", backend=backend, interpret=interpret).T
    bm, bk = block
    return _bsr.bsr_matmul(sparse, dense, coords=coords, bm=bm, bk=bk,
                           bn=bstream, interpret=interpret)


def matmul_from_plan(plan: KernelPlan, a: jax.Array, b: jax.Array,
                     **kw) -> jax.Array:
    """Dispatch a GEMM according to a generated KernelPlan — the paper's
    'select modules from the dataflow' step, at call granularity."""
    stationary = "B" if plan.resident_tensor in (None, "B", "C") else "A"
    return stt_matmul(a, b, template=plan.template, stationary=stationary,
                      **kw)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "backend", "interpret"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              bq: int = 128, bkv: int = 128, backend: str = "pallas",
              interpret: bool = False) -> jax.Array:
    """GQA attention (B, Hq, Lq, D) x (B, Hkv, Lkv, D) -> (B, Hq, Lq, D)."""
    if backend == "xla":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    lq, lkv = q.shape[2], k.shape[2]
    bq, bkv = min(bq, lq), min(bkv, lkv)
    qp = _pad_to(q, (1, 1, bq, 1))
    kp = _pad_to(k, (1, 1, bkv, 1))
    vp = _pad_to(v, (1, 1, bkv, 1))
    # padded kv columns must not contribute: they are masked iff causal;
    # for non-causal padding we mask via window trick — instead just require
    # the caller to pad explicitly for cross-attention.
    if not causal and (kp.shape[2] != lkv):
        raise ValueError("cross-attention requires Lkv % bkv == 0")
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              bq=bq, bkv=bkv, interpret=interpret)
    return out[:, :, :lq]


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "backend", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, *, chunk: int = 64, backend: str = "pallas",
        interpret: bool = False) -> jax.Array:
    """Mamba-2 SSD:  x (B, L, H, P), dt (B, L, H), a (H,),
    b/c (B, L, G, N) -> y (B, L, H, P)."""
    if backend == "xla":
        return _ref.ssd_chunked_ref(x, dt, a, b, c, chunk=chunk)[0]
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    da = dt.astype(jnp.float32) * a.astype(jnp.float32)
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=2)
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=2)
    # flatten (B, H) and move L inside: (B*H, L, ...)
    def flat(t):
        return t.transpose(0, 2, 1, *range(3, t.ndim)).reshape(
            bsz * h, l, *t.shape[3:])
    y = _ssd.ssd_scan(flat(xdt), da.transpose(0, 2, 1).reshape(bsz * h, l),
                      flat(bf), flat(cf), chunk=chunk, interpret=interpret)
    return y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3).astype(x.dtype)
