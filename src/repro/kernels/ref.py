"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth (tests assert_allclose kernels against
them) and double as the XLA execution path used by the model zoo when Pallas
is unavailable (CPU dry-runs compile these; kernels are validated in
interpret mode).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------

def matmul_ref(a: jax.Array, b: jax.Array,
               out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """C[..., m, n] = sum_k A[..., m, k] B[..., k, n], fp32 accumulation.

    A leading batch dim on either operand broadcasts against the other
    (the XLA path of the grid-folded batched templates); plain rank-2
    inputs reproduce the historic 2-D behaviour exactly.
    """
    out = jnp.einsum("...mk,...kn->...mn", a, b,
                     preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)


# ---------------------------------------------------------------------------
# Retired block-diagonal GEMM-ization — kept as a test-only oracle
# ---------------------------------------------------------------------------
# Until the grid-folded refactor, batched_gemv and depthwise_conv lowered
# onto the dense templates by zero-padding their batch/channel loop into
# the contraction with a block-diagonal operand: exact, but the executed
# GEMM performed batch x the algebra's MACs.  The construction lives on
# here so tests can assert the grid-folded path is bit-exact against it
# (integer-valued operands make both paths exact at any dtype) and so
# benchmarks/batch_fold.py can measure what retiring it bought.

def block_diag_rows(rows: jax.Array) -> jax.Array:
    """(B, K) -> (B, B*K) with row i equal to rows[i] placed in block i.

    The zero blocks make cross-batch products vanish, so one plain GEMM
    computes every batch at once — at batch x the useful MACs.
    """
    b = rows.shape[0]
    return (jnp.eye(b, dtype=rows.dtype)[:, :, None]
            * rows[None, :, :]).reshape(b, -1)


def _im2col_oracle(a: jax.Array, y: int, x: int, p: int, q: int
                   ) -> jax.Array:
    """(C, y+p-1, x+q-1) -> (C*p*q, y*x), C-major then (p, q) — written
    as explicit loops, independently of the lowering's stacked version."""
    rows = []
    for cc in range(a.shape[0]):
        for pp in range(p):
            for qq in range(q):
                rows.append(a[cc, pp:pp + y, qq:qq + x].reshape(y * x))
    return jnp.stack(rows)


def batched_gemv_blockdiag_ref(a: jax.Array, b: jax.Array,
                               out_dtype: Optional[jnp.dtype] = None
                               ) -> jax.Array:
    """C[m, n] = sum_k A[m, k, n] * B[m, k] via the retired lowering:
    block_diag(B) (m, m*k) @ A.reshape(m*k, n)."""
    m, k, n = a.shape
    return matmul_ref(block_diag_rows(b), a.reshape(m * k, n),
                      out_dtype=out_dtype)


def depthwise_blockdiag_ref(a: jax.Array, b: jax.Array, *, y: int, x: int
                            ) -> jax.Array:
    """C[k, y, x] = sum_{p,q} A[k, y+p, x+q] * B[k, p, q] via the retired
    lowering: block_diag(B) (k, k*p*q) @ im2col(A) (k*p*q, y*x)."""
    k, p, q = b.shape
    out = matmul_ref(block_diag_rows(b.reshape(k, p * q)),
                     _im2col_oracle(a, y, x, p, q))
    return out.reshape(k, y, x)


# ---------------------------------------------------------------------------
# Attention (GQA + causal + sliding window + cross)
# ---------------------------------------------------------------------------

def attention_mask(q_len: int, kv_len: int, *, causal: bool,
                   window: Optional[int], q_offset: int = 0) -> jax.Array:
    """Boolean (q_len, kv_len) mask; True = attend.

    ``q_offset`` places the query block inside a longer sequence (used for
    decode, where q_len == 1 at absolute position q_offset).
    """
    qpos = jnp.arange(q_len)[:, None] + q_offset
    kpos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    return mask


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0,
                  ) -> jax.Array:
    """Reference multi-head attention.

    q: (B, Hq, Lq, D);  k, v: (B, Hkv, Lkv, D) with Hq % Hkv == 0 (GQA).
    Softmax in fp32. ``causal=False, window=None`` gives cross-attention.
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kg = jnp.repeat(k, group, axis=1)
    vg = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    mask = attention_mask(lq, k.shape[2], causal=causal, window=window,
                          q_offset=q_offset)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vg.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — chunked linear recurrence
# ---------------------------------------------------------------------------

def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
            b: jax.Array, c: jax.Array,
            h0: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, jax.Array]:
    """Sequential-scan oracle for the SSD recurrence.

      h_t = exp(dt_t * a) * h_{t-1} + dt_t * B_t x_t^T
      y_t = C_t . h_t

    Shapes: x (B, L, H, P), dt (B, L, H), a (H,) [negative],
            b, c (B, L, G, N) with H % G == 0; h0 (B, H, N, P) or None.
    Returns (y (B, L, H, P), h_final (B, H, N, P)).  fp32 internally.
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=2)   # (B, L, H, N)
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=2)
    decay = jnp.exp(dtf * a.astype(jnp.float32))          # (B, L, H)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    def step(hprev, inp):
        xt, bt, ct, dct, dtt = inp                        # (B,H,P),(B,H,N)...
        hnew = (dct[..., None, None] * hprev +
            jnp.einsum("bhn,bhp->bhnp", dtt[..., None] * bt, xt))
        yt = jnp.einsum("bhn,bhnp->bhp", ct, hnew)
        return hnew, yt

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(bf, 1, 0),
          jnp.moveaxis(cf, 1, 0), jnp.moveaxis(decay, 1, 0),
          jnp.moveaxis(dtf, 1, 0))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y, h_final


def ssd_chunked_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, chunk: int = 64,
                    h0: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Chunked (quadratic-within-chunk) SSD — the algorithm the Pallas kernel
    implements, in pure jnp.  Mathematically identical to ``ssd_ref``; also
    the XLA path used by the models (vectorized over chunks via scan).
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xf = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]  # dt folded
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=2)
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=2)
    da = dt.astype(jnp.float32) * a.astype(jnp.float32)             # (B, L, H)

    # reshape to chunks: (B, nc, Q, ...)
    xc = xf.reshape(bsz, nc, chunk, h, p)
    bc = bf.reshape(bsz, nc, chunk, h, n)
    cc = cf.reshape(bsz, nc, chunk, h, n)
    dac = da.reshape(bsz, nc, chunk, h)
    lc = jnp.cumsum(dac, axis=2)                                    # (B,nc,Q,H)

    # intra-chunk: y[i] = sum_{j<=i} exp(Lc[i]-Lc[j]) (C_i.B_j) xdt[j]
    s = jnp.einsum("bcihn,bcjhn->bchij", cc, bc)
    li = lc.transpose(0, 1, 3, 2)                  # (B, nc, H, Q)
    dmat = li[..., :, None] - li[..., None, :]     # Lc[i] - Lc[j]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: upper-triangular dmat is positive and would overflow
    # in the backward pass (inf * 0 = NaN) if masked after
    m = jnp.exp(jnp.where(tri[None, None, None], dmat, -1e9))
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", s * m, xc)

    # chunk-level states: contribution of chunk tokens to its end state
    wend = jnp.exp(lc[:, :, -1:, :] - lc)                           # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjhn,bcjhp->bchnp", bc * wend[..., None], xc)
    chunk_decay = jnp.exp(lc[:, :, -1, :])                          # (B,nc,H)

    # scan over chunks to produce incoming state per chunk
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    def scan_fn(hprev, inp):
        st, dec = inp                                # (B,H,N,P), (B,H)
        hnew = dec[..., None, None] * hprev + st
        return hnew, hprev

    (h_final, h_in) = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                  # (B,nc,H,N,P) pre-chunk

    # inter-chunk: y[i] += C_i . (exp(Lc[i]) * h_in)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         cc * jnp.exp(lc)[..., None], h_in)
    y = (y_intra + y_inter).reshape(bsz, l, h, p).astype(x.dtype)
    return y, h_final
