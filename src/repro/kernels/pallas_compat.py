"""Version compatibility shims for jax.experimental.pallas.tpu.

The Pallas TPU API renamed ``TPUCompilerParams`` to ``CompilerParams``
between jax releases; the kernels target the new name but must run on
images that ship the old one.  Centralizing the lookup here keeps every
kernel file on one import instead of four copies of the getattr dance.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = (getattr(pltpu, "CompilerParams", None)
    or getattr(pltpu, "TPUCompilerParams"))
