"""The fused-group megakernel: a whole gemm chain in ONE pallas_call.

PR 8's graph layer *schedules* fusion (tile agreement, folded epilogues)
but still dispatches one Pallas kernel per node, leaving VMEM residency
of the intermediates to XLA.  This template executes an entire fused
group — ``gemm -> gelu -> gemm``, the ``scores -> softmax -> attend``
attention pair, or the full 4-gemm attention+MLP chain — as a single
``pl.pallas_call``: every intermediate lives in a VMEM scratch buffer
and is **never written to HBM**.  This is TensorLib's parameterized-
template idea applied to the multi-op generation unit (TileLoom / LEGO
argue the fused group is the right unit — PAPERS.md).

Shape contract (what the planner's agreement pass guarantees):

* every stage is a 2-D gemm chained on its lhs: stage ``j`` computes
  ``x_{j+1} = cast(epilogue_j(x_j @ rhs_j), dtype)`` with ``x_0`` the
  group's external lhs ``(m, k_0)`` and ``rhs_j`` of shape
  ``(k_j, n_j)`` where ``k_{j+1} == n_j``,
* each ``rhs_j`` (and its optional ``(1, n_j)`` bias row) is fully
  VMEM-resident with its block index pinned — weights are small
  relative to the activation stream,
* only ``m`` is tiled (block ``bm``); each stage's full ``n_j`` row
  is produced at once, so a row ``softmax`` epilogue is always legal
  and the per-stage math is a single ``jnp.dot`` + the same
  ``_flush_block`` the per-node templates use.  With ``bm == m`` (the
  planner's whole-tensor fast path) the merged kernel runs the exact
  instruction sequence of the sequential whole-tensor dispatches —
  bit-identical output, one kernel launch.

Two interleave orders (the tuner's stage-order knob):

* ``"chain"`` — grid ``(m/bm,)``: all stages run back-to-back per
  m-block; intermediate scratch is one ``(bm, n_j)`` strip per stage.
* ``"stage"`` — grid ``(S, m/bm)`` stage-major: phase ``s`` runs stage
  ``s`` over every m-block (``pl.when(program_id(0) == j)``) before the
  next stage starts; scratch holds the full ``(m, n_j)`` intermediate.
  Trades scratch footprint for weight-stationarity: each ``rhs_j`` is
  touched in exactly one contiguous phase.

``m`` not divisible by ``bm`` is handled by zero-padding the lhs rows
and slicing the output; epilogues (bias/softmax) make padded rows
nonzero but never leak across rows, so the slice is exact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import epilogue as _ep
from . import pallas_compat as _compat
from .stt_gemm import _flush_block

#: valid stage interleave orders (the merged-kernel tuner knob)
FUSED_INTERLEAVES = ("chain", "stage")


@dataclasses.dataclass(frozen=True)
class ChainStage:
    """One gemm stage of a fused chain (hashable: jit-static + cache
    key component).  ``k`` is the stage's contraction extent (== the
    previous stage's ``n``), ``epilogue`` the in-kernel spec applied to
    the fp32 product, ``has_bias`` whether the spec streams a bias row.
    """

    k: int
    n: int
    epilogue: Tuple[str, ...] = ()
    has_bias: bool = False


def validate_chain(stages: Sequence[ChainStage], k0: int
                   ) -> Tuple[ChainStage, ...]:
    """Normalize + validate a stage list: shapes chain, epilogues parse,
    bias flags agree with the specs."""
    stages = tuple(stages)
    if not stages:
        raise ValueError("a fused chain needs at least one stage")
    k = k0
    for j, st in enumerate(stages):
        if st.k != k:
            raise ValueError(
                f"stage {j} contracts over k={st.k} but receives a "
                f"(m, {k}) input; stages must chain n -> k")
        if st.k <= 0 or st.n <= 0:
            raise ValueError(f"stage {j} has non-positive dims "
                             f"({st.k}, {st.n})")
        spec = _ep.validate_spec(st.epilogue)
        if _ep.needs_bias(spec) != st.has_bias:
            raise ValueError(
                f"stage {j} epilogue {spec} "
                f"{'needs' if _ep.needs_bias(spec) else 'has no'} bias "
                f"but has_bias={st.has_bias}")
        k = st.n
    return stages


# ---------------------------------------------------------------------------
# VMEM footprint estimates — what the planner's budget gate prices
# ---------------------------------------------------------------------------

def chain_scratch_bytes(stages: Sequence[ChainStage], bm: int,
                        itemsize: int) -> int:
    """Intermediate scratch for ``interleave='chain'``: one ``(bm, n)``
    strip per non-final stage, in the chain dtype."""
    return sum(bm * st.n * itemsize for st in tuple(stages)[:-1])


def stage_scratch_bytes(stages: Sequence[ChainStage], m: int,
                        itemsize: int) -> int:
    """Intermediate scratch for ``interleave='stage'``: the full
    ``(m, n)`` tensor per non-final stage survives across phases."""
    return sum(m * st.n * itemsize for st in tuple(stages)[:-1])


def chain_vmem_bytes(stages: Sequence[ChainStage], m: int, k0: int,
                     bm: int, itemsize: int,
                     interleave: str = "chain") -> int:
    """Total VMEM residency estimate of the merged kernel: lhs block +
    all pinned rhs (and bias rows, fp32) + output block + intermediate
    scratch.  The planner compares this against the array config's
    ``vmem_budget_bytes`` before committing to a merged lowering."""
    stages = tuple(stages)
    resident = bm * k0 * itemsize                     # lhs block
    resident += sum(st.k * st.n * itemsize for st in stages)   # weights
    resident += sum(4 * st.n for st in stages if st.has_bias)  # bias rows
    resident += bm * stages[-1].n * itemsize          # output block
    if interleave == "stage":
        resident += stage_scratch_bytes(stages, m, itemsize)
    else:
        resident += chain_scratch_bytes(stages, bm, itemsize)
    return resident


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _split_refs(refs, n_stage: int, n_bias: int):
    """Unpack the flat pallas ref list: lhs, rhs*, bias*, out, scratch*."""
    lhs_ref = refs[0]
    rhs_refs = refs[1:1 + n_stage]
    bias_refs = refs[1 + n_stage:1 + n_stage + n_bias]
    o_ref = refs[1 + n_stage + n_bias]
    scr_refs = refs[2 + n_stage + n_bias:]
    return lhs_ref, rhs_refs, bias_refs, o_ref, scr_refs


def _stage_bias_refs(stages, bias_refs):
    """Per-stage bias ref (None for stages without one)."""
    out, bi = [], 0
    for st in stages:
        if st.has_bias:
            out.append(bias_refs[bi])
            bi += 1
        else:
            out.append(None)
    return out


def _chain_kernel(*refs, stages: Tuple[ChainStage, ...], n_bias: int,
                  mid_dtype, out_dtype):
    """interleave='chain': all stages back-to-back for one m-block."""
    lhs_ref, rhs_refs, bias_refs, o_ref, scr = _split_refs(
        refs, len(stages), n_bias)
    biases = _stage_bias_refs(stages, bias_refs)
    x = lhs_ref[...]
    for j, st in enumerate(stages):
        acc = jnp.dot(x, rhs_refs[j][...],
                      preferred_element_type=jnp.float32)
        if j + 1 < len(stages):
            scr[j][...] = _flush_block(acc, biases[j], st.epilogue,
                                       mid_dtype)
            x = scr[j][...]
        else:
            o_ref[...] = _flush_block(acc, biases[j], st.epilogue,
                                      out_dtype)


def _stage_kernel(*refs, stages: Tuple[ChainStage, ...], n_bias: int,
                  bm: int, mid_dtype, out_dtype):
    """interleave='stage': grid (S, m/bm); phase s runs stage s over
    every m-block before phase s+1 starts (enforced by the 'arbitrary'
    grid semantics), reading/writing full-tensor scratch rows."""
    lhs_ref, rhs_refs, bias_refs, o_ref, scr = _split_refs(
        refs, len(stages), n_bias)
    biases = _stage_bias_refs(stages, bias_refs)
    s = pl.program_id(0)
    row = pl.ds(pl.program_id(1) * bm, bm)
    for j, st in enumerate(stages):
        @pl.when(s == j)
        def _run(j=j, st=st):
            x = lhs_ref[...] if j == 0 else scr[j - 1][row, :]
            acc = jnp.dot(x, rhs_refs[j][...],
                          preferred_element_type=jnp.float32)
            if j + 1 < len(stages):
                scr[j][row, :] = _flush_block(acc, biases[j], st.epilogue,
                                              mid_dtype)
            else:
                o_ref[...] = _flush_block(acc, biases[j], st.epilogue,
                                          out_dtype)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("stages", "bm", "interleave", "out_dtype",
                     "interpret"))
def _fused_chain(lhs, *operands, stages: Tuple[ChainStage, ...],
                 bm: int, interleave: str, out_dtype: str,
                 interpret: bool):
    from jax.experimental.pallas import tpu as pltpu

    n_stage = len(stages)
    n_bias = sum(1 for st in stages if st.has_bias)
    rhss = operands[:n_stage]
    bias_rows = operands[n_stage:]
    m = lhs.shape[0]
    mid_dtype = lhs.dtype
    n_last = stages[-1].n

    mp = -(-m // bm) * bm
    if mp != m:
        lhs = jnp.pad(lhs, ((0, mp - m), (0, 0)))
    n_m = mp // bm

    if interleave == "chain":
        grid = (n_m,)
        imap_m = lambda i: (i, 0)           # noqa: E731
        imap_pin = lambda i: (0, 0)         # noqa: E731
        kernel = functools.partial(
            _chain_kernel, stages=stages, n_bias=n_bias,
            mid_dtype=mid_dtype, out_dtype=jnp.dtype(out_dtype))
        scratch = [pltpu.VMEM((bm, st.n), mid_dtype)
                   for st in stages[:-1]]
        semantics = ("parallel",)
    else:
        grid = (n_stage, n_m)
        imap_m = lambda s, i: (i, 0)        # noqa: E731
        imap_pin = lambda s, i: (0, 0)      # noqa: E731
        kernel = functools.partial(
            _stage_kernel, stages=stages, n_bias=n_bias, bm=bm,
            mid_dtype=mid_dtype, out_dtype=jnp.dtype(out_dtype))
        scratch = [pltpu.VMEM((mp, st.n), mid_dtype)
                   for st in stages[:-1]]
        semantics = ("arbitrary", "arbitrary")

    in_specs = [pl.BlockSpec((bm, stages[0].k), imap_m)]
    in_specs += [pl.BlockSpec((st.k, st.n), imap_pin) for st in stages]
    in_specs += [pl.BlockSpec((1, st.n), imap_pin)
                 for st in stages if st.has_bias]

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, n_last), imap_m),
        out_shape=jax.ShapeDtypeStruct((mp, n_last), jnp.dtype(out_dtype)),
        scratch_shapes=scratch,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(lhs, *rhss, *bias_rows)
    return out[:m] if mp != m else out


def fused_chain_matmul(lhs: jax.Array,
                       rhss: Sequence[jax.Array],
                       biases: Sequence[jax.Array] = (), *,
                       stages: Sequence[ChainStage],
                       bm: Optional[int] = None,
                       interleave: str = "chain",
                       out_dtype=None,
                       interpret: bool = False,
                       vmem_budget: Optional[int] = None) -> jax.Array:
    """Run a fused gemm chain as one Pallas kernel.

    ``lhs`` is ``(m, k_0)``; ``rhss[j]`` is stage j's kernel-facing
    ``(k_j, n_j)`` operand (the caller applies the storage transpose —
    gemm stores B as ``(n, k)``); ``biases`` holds one ``(n_j,)`` vector
    per ``has_bias`` stage, in stage order.  ``bm=None`` runs the
    whole-tensor single-phase fast path (``bm = m``).  ``vmem_budget``
    (bytes) raises when the residency estimate exceeds it — the graph
    planner gates on the same estimate and falls back to sequential
    dispatch instead of tripping this.
    """
    m, k0 = lhs.shape
    stages = validate_chain(stages, k0)
    if interleave not in FUSED_INTERLEAVES:
        raise ValueError(f"interleave must be one of {FUSED_INTERLEAVES}, "
                         f"got {interleave!r}")
    if len(rhss) != len(stages):
        raise ValueError(f"{len(stages)} stages need {len(stages)} rhs "
                         f"operands, got {len(rhss)}")
    n_bias = sum(1 for st in stages if st.has_bias)
    if len(biases) != n_bias:
        raise ValueError(f"chain has {n_bias} bias stage(s) but "
                         f"{len(biases)} bias vector(s) were given")
    for j, (st, r) in enumerate(zip(stages, rhss)):
        if tuple(r.shape) != (st.k, st.n):
            raise ValueError(f"stage {j} rhs must be ({st.k}, {st.n}), "
                             f"got {tuple(r.shape)}")
    bm = m if bm is None else max(1, min(int(bm), m))
    out_dtype = jnp.dtype(out_dtype or lhs.dtype)
    if vmem_budget is not None:
        need = chain_vmem_bytes(stages, m, k0, bm, out_dtype.itemsize,
                                interleave)
        if need > vmem_budget:
            raise ValueError(
                f"fused chain needs ~{need} VMEM bytes "
                f"(bm={bm}, interleave={interleave}) but the budget is "
                f"{vmem_budget}; the planner falls back to sequential "
                f"dispatch instead")
    bias_rows = []
    bi = 0
    for st in stages:
        if st.has_bias:
            b = jnp.asarray(biases[bi])
            bi += 1
            if b.shape != (st.n,):
                raise ValueError(f"bias for a (*, {st.n}) stage must be "
                                 f"rank-1 of length {st.n}, got {b.shape}")
            bias_rows.append(b.astype(jnp.float32).reshape(1, st.n))
    return _fused_chain(lhs, *rhss, *bias_rows, stages=stages, bm=bm,
                        interleave=interleave, out_dtype=out_dtype.name,
                        interpret=interpret)


# ---------------------------------------------------------------------------
# DAG megakernel — rhs-landing edges, batched stages, residuals, taps
# ---------------------------------------------------------------------------

#: the DAG template's single interleave order (stage-major, whole-tensor
#: phases); recorded in the tuning cache alongside the chain knobs
DAG_INTERLEAVE = "dag"


@dataclasses.dataclass(frozen=True)
class DagStage:
    """One stage of a fused DAG group (hashable: jit-static + cache-key
    component).  Unlike :class:`ChainStage`, operands are *bound*: each
    source is ``("ext", i)`` (the i-th external kernel operand, already
    in kernel-facing layout) or ``("scr", j)`` (stage j's VMEM scratch).

    * ``kind == "dot"`` — ``out(m, n) = lhs(m, k) @ rhs(k, n)``; a
      scratch-sourced rhs is read **transposed** (the producer's (n, m)
      output lands on this stage's rhs — the rhs-landing fusion), so no
      materialized transpose exists anywhere.
    * ``kind == "batched"`` — the batched_gemv image
      ``out[b, n] = sum_k lhs[b, k, n] * rhs[b, k]`` with the batch axis
      aligned on the group's m axis (PR 4's LoweredForm batch folding,
      merged); ``lhs`` is the external 3-D tensor.

    ``res`` streams a same-shape residual added *after* the epilogue in
    fp32 (the graph's ``add`` node folded in-kernel); ``tap >= 0``
    exports this stage's block to HBM output slot ``tap`` so an unfused
    consumer can read it without re-running the producer.
    """

    m: int
    k: int
    n: int
    kind: str = "dot"                    # "dot" | "batched"
    lhs: Tuple[str, int] = ("ext", 0)
    rhs: Tuple[str, int] = ("ext", 0)
    res: Optional[Tuple[str, int]] = None
    epilogue: Tuple[str, ...] = ()
    has_bias: bool = False
    bias: int = -1                       # ext index of the (1, n) bias row
    tap: int = -1                        # HBM tap output slot (-1: none)


def validate_dag(stages: Sequence[DagStage]) -> Tuple[DagStage, ...]:
    """Validate a DAG stage list: scratch references point backwards with
    chaining shapes, epilogues parse, bias/tap wiring is consistent."""
    stages = tuple(stages)
    if not stages:
        raise ValueError("a fused DAG needs at least one stage")
    taps = []
    for j, st in enumerate(stages):
        if st.kind not in ("dot", "batched"):
            raise ValueError(f"stage {j}: unknown kind {st.kind!r}")
        if st.m <= 0 or st.k <= 0 or st.n <= 0:
            raise ValueError(f"stage {j} has non-positive dims "
                             f"({st.m}, {st.k}, {st.n})")
        for role, src in (("lhs", st.lhs), ("rhs", st.rhs),
                          ("res", st.res)):
            if src is None:
                continue
            where, idx = src
            if where not in ("ext", "scr"):
                raise ValueError(f"stage {j} {role}: bad source {src!r}")
            if where == "scr":
                if not 0 <= idx < j:
                    raise ValueError(f"stage {j} {role} reads scratch "
                                     f"{idx}: must be an earlier stage")
                p = stages[idx]
                want = {"lhs": (st.m, st.k), "res": (st.m, st.n),
                        "rhs": ((st.n, st.k) if st.kind == "dot"
                                else (st.m, st.k))}[role]
                if (p.m, p.n) != want:
                    raise ValueError(
                        f"stage {j} {role} reads stage {idx} "
                        f"({p.m}, {p.n}) but needs {want}")
        if st.kind == "batched" and st.lhs[0] != "ext":
            raise ValueError(f"stage {j}: a batched stage's 3-D tensor "
                             f"must be an external operand")
        spec = _ep.validate_spec(st.epilogue)
        if _ep.needs_bias(spec) != st.has_bias:
            raise ValueError(
                f"stage {j} epilogue {spec} "
                f"{'needs' if _ep.needs_bias(spec) else 'has no'} bias "
                f"but has_bias={st.has_bias}")
        if st.has_bias and st.bias < 0:
            raise ValueError(f"stage {j} has_bias without a bias ext "
                             f"index")
        if st.tap >= 0:
            if j == len(stages) - 1:
                raise ValueError("the final stage is the group result; "
                                 "it cannot also be a tap")
            taps.append(st.tap)
    if sorted(taps) != list(range(len(taps))):
        raise ValueError(f"tap slots must be 0..{len(taps) - 1} with no "
                         f"gaps, got {sorted(taps)}")
    return stages


def dag_scratch_bytes(stages: Sequence[DagStage], itemsize: int) -> int:
    """VMEM scratch of the DAG template: every non-final stage keeps its
    full ``(m, n)`` output resident across the stage-major phases."""
    return sum(st.m * st.n * itemsize for st in tuple(stages)[:-1])


def _dag_fetch(ext, scr, src, transpose=False):
    where, idx = src
    buf = ext[idx][...] if where == "ext" else scr[idx][...]
    return buf.T if transpose else buf


def _dag_kernel(*refs, stages: Tuple[DagStage, ...], n_ext: int,
                n_tap: int, dtype):
    """Stage-major DAG body: grid ``(S,)`` with 'arbitrary' semantics —
    phase ``j`` computes stage ``j`` whole-tensor, reading earlier
    stages' scratch (plain for lhs/res, transposed for a landed rhs)."""
    ext = refs[:n_ext]
    o_ref = refs[n_ext]
    tap_refs = refs[n_ext + 1:n_ext + 1 + n_tap]
    scr = refs[n_ext + 1 + n_tap:]
    s = pl.program_id(0)
    last = len(stages) - 1
    for j, st in enumerate(stages):
        @pl.when(s == j)
        def _run(j=j, st=st):
            if st.kind == "batched":
                a3 = _dag_fetch(ext, scr, st.lhs)       # (m, k, n)
                v = _dag_fetch(ext, scr, st.rhs)        # (m, k)
                acc = jax.lax.dot_general(
                    v, a3, (((1,), (1,)), ((0,), (0,))),
                    preferred_element_type=jnp.float32)
            else:
                x = _dag_fetch(ext, scr, st.lhs)
                r = _dag_fetch(ext, scr, st.rhs,
                               transpose=st.rhs[0] == "scr")
                acc = jnp.dot(x, r, preferred_element_type=jnp.float32)
            b_ref = ext[st.bias] if st.has_bias else None
            y = _flush_block(acc, b_ref, st.epilogue, dtype)
            if st.res is not None:
                r_ = _dag_fetch(ext, scr, st.res)
                # external residuals stream in fp32; scratch ones are in
                # the chain dtype — the add itself is always fp32 (the
                # standalone add node's exact math)
                y = (y.astype(jnp.float32)
                     + r_.astype(jnp.float32)).astype(dtype)
            if st.tap >= 0:
                tap_refs[st.tap][...] = y
            if j == last:
                o_ref[...] = y
            else:
                scr[j][...] = y


@functools.partial(
    jax.jit, static_argnames=("stages", "out_dtype", "interpret"))
def _fused_dag(*exts, stages: Tuple[DagStage, ...], out_dtype: str,
               interpret: bool):
    from jax.experimental.pallas import tpu as pltpu

    dt = jnp.dtype(out_dtype)
    last = stages[-1]
    n_tap = sum(1 for st in stages if st.tap >= 0)

    def pin(rank):
        return lambda s, _r=rank: (0,) * _r

    in_specs = [pl.BlockSpec(tuple(e.shape), pin(e.ndim)) for e in exts]
    out_shape = [jax.ShapeDtypeStruct((last.m, last.n), dt)]
    out_specs = [pl.BlockSpec((last.m, last.n), pin(2))]
    for st in sorted((s for s in stages if s.tap >= 0),
                     key=lambda s: s.tap):
        out_shape.append(jax.ShapeDtypeStruct((st.m, st.n), dt))
        out_specs.append(pl.BlockSpec((st.m, st.n), pin(2)))
    scratch = [pltpu.VMEM((st.m, st.n), dt) for st in stages[:-1]]
    kernel = functools.partial(_dag_kernel, stages=stages,
                               n_ext=len(exts), n_tap=n_tap, dtype=dt)
    out = pl.pallas_call(
        kernel,
        grid=(len(stages),),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*exts)
    return tuple(out) if isinstance(out, (tuple, list)) else (out,)


def fused_dag(exts: Sequence[jax.Array], *,
              stages: Sequence[DagStage],
              out_dtype=None,
              interpret: bool = False) -> Tuple[jax.Array, ...]:
    """Run a fused DAG group as one Pallas kernel.

    ``exts`` are the external operands in *kernel-facing* layout (the
    caller applies role casts: a landed external rhs is already
    ``(k, n)``, residual streams fp32, bias rows ``(1, n)`` fp32).
    Returns ``(result, *taps)`` — the final stage's output followed by
    the tapped intermediates in tap-slot order.
    """
    stages = validate_dag(stages)
    out_dtype = jnp.dtype(out_dtype or exts[0].dtype)
    return _fused_dag(*exts, stages=stages, out_dtype=out_dtype.name,
                      interpret=interpret)


def dag_reference(exts: Sequence[jax.Array], *,
                  stages: Sequence[DagStage],
                  out_dtype=None) -> Tuple[jax.Array, ...]:
    """Pure-jnp mirror of the DAG megakernel (the ``backend='xla'``
    route): identical per-stage math without the Pallas grid."""
    stages = validate_dag(stages)
    dt = jnp.dtype(out_dtype or exts[0].dtype)
    vals: list = []
    taps: dict = {}
    for j, st in enumerate(stages):
        def fetch(src, transpose=False):
            where, idx = src
            buf = exts[idx] if where == "ext" else vals[idx]
            return buf.T if transpose else buf
        if st.kind == "batched":
            acc = jax.lax.dot_general(
                fetch(st.rhs), fetch(st.lhs),
                (((1,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)
        else:
            acc = jnp.dot(fetch(st.lhs),
                          fetch(st.rhs, transpose=st.rhs[0] == "scr"),
                          preferred_element_type=jnp.float32)
        if st.epilogue:
            b = exts[st.bias].reshape(-1) if st.has_bias else None
            acc = _ep.apply_epilogue(acc, st.epilogue, bias=b)
        y = acc.astype(dt)
        if st.res is not None:
            y = (y.astype(jnp.float32)
                 + fetch(st.res).astype(jnp.float32)).astype(dt)
        vals.append(y)
        if st.tap >= 0:
            taps[st.tap] = y
    return (vals[-1],) + tuple(taps[i] for i in sorted(taps))


@functools.partial(jax.jit,
                   static_argnames=("stages", "out_dtype"))
def chain_reference(lhs, *operands, stages: Tuple[ChainStage, ...],
                    out_dtype: str):
    """Pure-jnp mirror of the megakernel (the ``backend='xla'`` route,
    same convention as ``ops.stt_matmul``): identical per-stage math —
    fp32 dot, epilogue, cast — without the Pallas grid."""
    n_stage = len(stages)
    rhss = operands[:n_stage]
    bias_rows = list(operands[n_stage:])
    mid_dtype = lhs.dtype
    x = lhs
    bi = 0
    for j, st in enumerate(stages):
        acc = jnp.dot(x, rhss[j], preferred_element_type=jnp.float32)
        if st.epilogue:
            b = None
            if st.has_bias:
                b = bias_rows[bi]
                bi += 1
            acc = _ep.apply_epilogue(acc, st.epilogue, bias=b)
        x = acc.astype(mid_dtype if j + 1 < n_stage
                       else jnp.dtype(out_dtype))
    return x
