"""Block-sparse (block-COO) GEMM Pallas kernel.

The dense STT templates (``stt_gemm.py``) iterate a *box* grid; this
kernel's grid iterates **only the nonzero blocks** of a block-sparse
operand: grid = (n-blocks, nnz), with a scalar-prefetched coordinate list
feeding the BlockSpec index maps (``pltpu.PrefetchScalarGridSpec``), so a
zero block costs neither a DMA nor an MXU pass.

Accumulation reuses the output-stationary discipline: ``coords`` is sorted
row-major, so all nonzero blocks of one output block-row are consecutive
grid steps — the fp32 scratch accumulator is initialized on a block-row
change and flushed on the last block of the row, and the k-blocks of each
output block are added in the *same ascending order* as the dense
output-stationary template.  At density 1.0 the coordinate list is the
full grid and the kernel reproduces the dense path bit-exactly (tested).

Block-rows with no nonzero block never appear in the grid; the wrapper
zeroes them from the (static) coordinate list.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import pallas_compat as _compat

#: static block-COO coordinate list: ((block_row, block_col), ...) sorted
Coords = Tuple[Tuple[int, int], ...]


def sort_coords(coords: Sequence[Sequence[int]]) -> Coords:
    """Canonical row-major, duplicate-free coordinate tuple."""
    return tuple(sorted(set(tuple(int(i) for i in c) for c in coords)))


def gather_blocks(x: jax.Array, coords: Coords, bm: int, bk: int
                  ) -> jax.Array:
    """(m, k) -> (nnz, bm, bk): the gather-of-nonzero-blocks step.

    ``coords`` is static, so under jit this is a constant-index gather the
    compiler folds into the operand layout."""
    m, k = x.shape
    g = x.reshape(m // bm, bm, k // bk, bk).transpose(0, 2, 1, 3)
    idx = np.asarray(coords, dtype=np.int32).reshape(-1, 2)
    return g[jnp.asarray(idx[:, 0]), jnp.asarray(idx[:, 1])]


def scatter_blocks(data: jax.Array, coords: Coords, m: int, k: int
                   ) -> jax.Array:
    """Inverse of :func:`gather_blocks`: reconstruct the masked dense
    operand (reference path / introspection)."""
    nnz, bm, bk = data.shape
    g = jnp.zeros((m // bm, k // bk, bm, bk), data.dtype)
    if nnz:
        idx = np.asarray(coords, dtype=np.int32).reshape(-1, 2)
        g = g.at[jnp.asarray(idx[:, 0]), jnp.asarray(idx[:, 1])].set(data)
    return g.transpose(0, 2, 1, 3).reshape(m, k)


def _row_presence(coords: Coords, n_rows: int) -> np.ndarray:
    present = np.zeros(n_rows, dtype=bool)
    for r, _ in coords:
        present[r] = True
    return present


def _bsr_kernel(coords_ref, a_ref, b_ref, o_ref, acc_ref, *, nnz: int,
                out_dtype):
    s = pl.program_id(1)
    row = coords_ref[s, 0]
    prev = jnp.where(s == 0, -1, coords_ref[jnp.maximum(s - 1, 0), 0])

    @pl.when(row != prev)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], b_ref[...],
                            preferred_element_type=jnp.float32)
    nxt = jnp.where(s == nnz - 1, -1,
                    coords_ref[jnp.minimum(s + 1, nnz - 1), 0])

    @pl.when(nxt != row)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def bsr_matmul(sparse: jax.Array, dense: jax.Array, *, coords: Coords,
               bm: int, bk: int, bn: int, out_dtype=None,
               interpret: bool = False) -> jax.Array:
    """``C = sparse @ dense`` with ``sparse`` (m, k) block-sparse.

    ``sparse`` is passed dense-but-masked (zeros outside the pattern);
    the nonzero blocks are gathered here and the Pallas grid runs one
    (block, n-block) step per *nonzero* block only.  ``coords`` must be
    the static, row-major-sorted block-COO list with (bm, bk) blocks;
    n is padded to a ``bn`` multiple.
    """
    from jax.experimental.pallas import tpu as pltpu

    (m, k), n = sparse.shape, dense.shape[1]
    if m % bm or k % bk:
        raise ValueError(f"sparse operand ({m},{k}) not tiled by blocks "
                         f"({bm},{bk})")
    out_dtype = out_dtype or sparse.dtype
    coords = sort_coords(coords)
    nnz = len(coords)
    if nnz == 0:
        return jnp.zeros((m, n), out_dtype)
    bn = min(bn, n)
    pad_n = (-n) % bn
    if pad_n:
        dense = jnp.pad(dense, ((0, 0), (0, pad_n)))
    data = gather_blocks(sparse, coords, bm, bk)
    coord_arr = jnp.asarray(np.asarray(coords, np.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=((n + pad_n) // bn, nnz),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda j, s, c: (s, 0, 0)),
            pl.BlockSpec((bk, bn), lambda j, s, c: (c[s, 1], j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, s, c: (c[s, 0], j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_bsr_kernel, nnz=nnz, out_dtype=out_dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n + pad_n), out_dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(coord_arr, data, dense)

    present = _row_presence(coords, m // bm)
    if not present.all():
        # block-rows with no nonzero block were never visited by the grid,
        # so their output memory is uninitialized — select, don't multiply
        # (0 * garbage can be nan)
        row_mask = jnp.asarray(np.repeat(present, bm))
        out = jnp.where(row_mask[:, None], out, jnp.zeros((), out_dtype))
    return out[:, :n]


def bsr_matmul_ref(sparse: jax.Array, dense: jax.Array, *, coords: Coords,
                   bm: int, bk: int) -> jax.Array:
    """jnp oracle: gather -> scatter -> dense matmul.  The gather/scatter
    round-trip asserts the pattern really covers the operand's support."""
    m, k = sparse.shape
    data = gather_blocks(sparse, sort_coords(coords), bm, bk)
    return scatter_blocks(data, sort_coords(coords), m, k) @ dense


def transpose_coords(coords: Coords) -> Coords:
    """Swap block coordinates (for the rhs-sparse transposition trick) and
    restore row-major order."""
    return sort_coords((c, r) for r, c in coords)
