"""Blockwise online-softmax attention (flash attention) for TPU.

TPU adaptation of the attention hot-spot: the KV sequence is the Pallas
*time* axis (innermost, "arbitrary" semantics), the running (m, l, acc)
statistics are the *stationary* tensors held in VMEM scratch — i.e. the
attention kernel is itself an output-stationary STT dataflow over the
(q_block, kv_block) loop nest, which is how the paper's technique picks this
template (see core.plan).

Features: GQA (q-head to kv-head mapping in the BlockSpec index_map), causal
masking, sliding-window (SWA), and cross-attention (no mask).  fp32 softmax,
inputs may be bf16/fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pallas_compat as _compat

NEG_INF = float(-1e30)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 bq: int, bkv: int, n_kv: int, out_dtype):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)               # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal or window is not None:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), dtype=bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                           # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    # guard fully-masked rows: s == m_cur == NEG_INF must give p = 0, not 1
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_cur[:, None]), 0.0)
    l_cur = alpha * l_ref[:, 0] + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)         # fully-masked rows -> 0
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(out_dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Lq, D);  k, v: (B, Hkv, Lkv, D);  Hq % Hkv == 0.

    Grid: (B, Hq, Lq/bq, Lkv/bkv) — kv innermost so the online-softmax
    statistics stay resident; q/k/v blocks stream through the pipeline.
    """
    from jax.experimental.pallas import tpu as pltpu
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq}, {hkv}")
    group = hq // hkv
    bq = min(bq, lq)
    bkv = min(bkv, lkv)
    if lq % bq or lkv % bkv:
        raise ValueError(f"seq lens ({lq},{lkv}) not divisible by blocks "
                         f"({bq},{bkv}); ops.attention pads first")
    n_kv = lkv // bkv
    kernel = functools.partial(
        _attn_kernel, scale=1.0 / (d ** 0.5), causal=causal, window=window,
        bq=bq, bkv=bkv, n_kv=n_kv, out_dtype=q.dtype)
    grid = (b, hq, lq // bq, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            # GQA: q head h reads kv head h // group
            pl.BlockSpec((1, 1, bkv, d),
                         lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max m
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
