"""repro — TensorLib (spatial accelerator generation) on TPU/jax_pallas.

The one front door:

    import repro
    acc = repro.generate("gemm", "output_stationary")
    c = acc({"A": a, "B": b})                    # single chip (Pallas)
    c = acc.sharded(mesh)({"A": a, "B": b})      # multi-chip (CommPlan)

``repro.generate`` runs classification -> plan -> compile and returns an
:class:`repro.api.Accelerator`; ``repro.search`` ranks the design space so
``generate(search=...)`` can consume it.  Subpackages stay importable on
their own (``repro.core``, ``repro.compile``, ``repro.dist``, ...) — the
lazy attribute hook below keeps ``import repro`` free of jax imports.
"""
from typing import TYPE_CHECKING

__all__ = ["Accelerator", "AlgebraGraph", "GraphNode", "Sparsity",
           "generate", "search", "search_graph"]

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .api import Accelerator, generate
    from .core.algebra import Sparsity
    from .core.dse import search, search_graph
    from .graph.ir import AlgebraGraph, GraphNode


def __getattr__(name):
    if name in ("generate", "Accelerator"):
        from . import api
        return getattr(api, name)
    if name in ("search", "search_graph"):
        from .core import dse
        return getattr(dse, name)
    if name in ("AlgebraGraph", "GraphNode"):
        from .graph import ir
        return getattr(ir, name)
    if name == "Sparsity":
        # pure-numpy descriptor: importable without dragging in jax
        from .core.algebra import Sparsity
        return Sparsity
    # plain submodule access (`import repro; repro.compile`) must keep
    # working even when the submodule wasn't imported yet
    import importlib
    try:
        return importlib.import_module(f".{name}", __name__)
    except ModuleNotFoundError:
        raise AttributeError(
            f"module 'repro' has no attribute {name!r}") from None
