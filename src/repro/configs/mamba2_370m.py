"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]
48L d_model=1024, ssm_state=128; d_inner = 2*1024 = 2048, 32 SSD heads of
dim 64.  Attention-free -> the paper's attention-sharding STTs are
inapplicable (DESIGN.md §Arch-applicability); STT schedules the SSD chunk
matmuls and projections instead.  long_500k runs (O(1) state decode).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,  # attn dims unused
    d_ff=0, vocab=50280, head_dim=64,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
)
