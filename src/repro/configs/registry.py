"""Architecture registry: --arch <id> -> ModelConfig."""
from importlib import import_module
from typing import Dict

from .base import ModelConfig

_MODULES = {
    "llama-3.2-vision-11b": ".llama_3_2_vision_11b",
    "whisper-small": ".whisper_small",
    "qwen1.5-110b": ".qwen1_5_110b",
    "qwen2.5-32b": ".qwen2_5_32b",
    "granite-8b": ".granite_8b",
    "h2o-danube-1.8b": ".h2o_danube_1_8b",
    "mamba2-370m": ".mamba2_370m",
    "zamba2-1.2b": ".zamba2_1_2b",
    "mixtral-8x22b": ".mixtral_8x22b",
    "grok-1-314b": ".grok_1_314b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return import_module(_MODULES[arch], package=__package__).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
