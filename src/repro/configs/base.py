"""Model configuration system.

One frozen dataclass covers all five architecture families (dense / moe /
ssm / hybrid / encdec / vlm); family-specific fields are zero/None when
unused.  Every assigned architecture gets a module in this package exposing
``CONFIG`` (the exact published dims) — see ``registry.get_config``.

``reduced()`` produces a same-family miniature for CPU smoke tests; the full
configs are only ever lowered via ShapeDtypeStruct in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    swa_window: Optional[int] = None  # sliding-window size; None = full attn
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25

    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 64
    conv_kernel: int = 4

    # hybrid (zamba2-style): shared attention block every N ssm layers
    attn_every: int = 0

    # encoder-decoder (whisper-style)
    n_enc_layers: int = 0

    # vlm (llama-3.2-vision-style): cross-attn layer period + stub frontend
    cross_attn_every: int = 0
    frontend_tokens: int = 0         # image patches (1601) / audio frames (1500)

    # training-time knobs
    remat: bool = True               # activation checkpointing per layer
    sequence_parallel: bool = True   # shard residual activations over 'model'
    explicit_collectives: bool = False  # STT-scheduled shard_map collectives
    #   (beyond-paper optimization; False = GSPMD-auto baseline — §Perf)
    dtype: str = "bfloat16"          # compute dtype (params are fp32 masters)

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0, self.name
        if self.family == "moe":
            assert self.n_experts > 0, self.name
        if self.family == "encdec":
            assert self.n_enc_layers > 0, self.name
        if self.family == "vlm":
            assert self.cross_attn_every > 0, self.name

    # -- derived dims --------------------------------------------------
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 524k-token decode cell?  True for SSM /
        hybrid / sliding-window archs (per the assignment's skip rule)."""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    # -- parameter counting (used for MODEL_FLOPS) ----------------------
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE counts top_k experts)."""
        return _param_count(self, active_only=True)

    # -- reduced config for CPU smoke tests -----------------------------
    def reduced(self) -> "ModelConfig":
        kw = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=min(self.n_layers, 4 if self.family in ("hybrid", "vlm")
                         else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
            else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            qkv_bias=self.qkv_bias,
            swa_window=16 if self.swa_window else None,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_expand=self.ssm_expand,
            ssm_head_dim=16,
            ssm_groups=1,
            ssm_chunk=8,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            frontend_tokens=16 if self.frontend_tokens else 0,
            remat=False,
            sequence_parallel=False,
            dtype="float32",
        )
        return ModelConfig(**kw)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d, dff = cfg.d_model, cfg.d_ff
    embed = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)

    def attn_params(kv_dim):
        p = d * cfg.q_dim + 2 * d * kv_dim + cfg.q_dim * d
        if cfg.qkv_bias:
            p += cfg.q_dim + 2 * kv_dim
        return p

    def mlp_params():
        return 3 * d * dff  # SwiGLU: gate, up, down

    def moe_params():
        n_e = cfg.top_k if active_only else cfg.n_experts
        return d * cfg.n_experts + n_e * 3 * d * dff  # router + experts

    def ssm_params():
        di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
        # in_proj (x, z, B, C, dt) + conv + out_proj + A/D/dt_bias
        inp = d * (2 * di + 2 * g * n + cfg.ssm_heads)
        conv = cfg.conv_kernel * (di + 2 * g * n)
        return inp + conv + di * d + 3 * cfg.ssm_heads

    per_layer = 0
    if cfg.family == "dense":
        per_layer = attn_params(cfg.kv_dim) + mlp_params()
        total = embed + cfg.n_layers * per_layer
    elif cfg.family == "moe":
        per_layer = attn_params(cfg.kv_dim) + moe_params()
        total = embed + cfg.n_layers * per_layer
    elif cfg.family == "ssm":
        total = embed + cfg.n_layers * ssm_params()
    elif cfg.family == "hybrid":
        n_shared = 1
        total = (embed + cfg.n_layers * ssm_params()
                 + n_shared * (attn_params(cfg.kv_dim) + mlp_params()))
    elif cfg.family == "encdec":
        dec = cfg.n_layers * (2 * attn_params(cfg.kv_dim) + mlp_params())
        enc = cfg.n_enc_layers * (attn_params(cfg.kv_dim) + mlp_params())
        total = embed + enc + dec
    elif cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        total = (embed + cfg.n_layers * (attn_params(cfg.kv_dim) + mlp_params())
                 + n_cross * attn_params(cfg.kv_dim))
    else:
        raise ValueError(cfg.family)
    # norms (2 per layer) + final norm
    total += (2 * cfg.n_layers + 1) * d
    return total


# ---------------------------------------------------------------------------
# Input shapes assigned to every LM arch (the 4 cells per arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ModelConfig) -> Tuple[str, ...]:
    """The shape cells this arch runs (long_500k only for sub-quadratic)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return tuple(cells)


# ---------------------------------------------------------------------------
# Serving workload mixes (open-loop load for the continuous-batching server)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeMix:
    """One open-loop serving workload: mixed prompt/output length buckets
    sampled per request, Poisson arrivals at ``rate_rps`` (0 = burst: all
    requests arrive at t=0, which is what the CI smoke uses so wall time
    measures compute, not the arrival clock)."""

    name: str
    prompt_lens: Tuple[int, ...]   # sampled uniformly per request
    output_lens: Tuple[int, ...]
    requests: int
    rate_rps: float = 0.0

    @property
    def arrival(self) -> str:
        return "poisson" if self.rate_rps > 0 else "burst"

    def max_context(self) -> int:
        return max(self.prompt_lens) + max(self.output_lens)


SERVE_MIXES = {
    # CI smoke: tiny burst mix, long/short prompts and outputs interleaved
    # so static batching pays padding + drain and continuous does not.
    "smoke": ServeMix("smoke", prompt_lens=(8, 16, 24), output_lens=(4, 24),
                      requests=12),
    # benchmark default: open-loop Poisson with a wider spread
    "mixed": ServeMix("mixed", prompt_lens=(8, 16, 24, 40),
                      output_lens=(4, 8, 16, 32), requests=32,
                      rate_rps=8.0),
}
