"""llama-3.2-vision-11b [vlm] — 40L cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
Backbone only; the vision frontend is a stub: input_specs() provides 1601
precomputed patch embeddings of width d_model.  Pure full attention ->
long_500k skipped (DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    cross_attn_every=5,           # 8 cross-attention layers among 40
    frontend_tokens=1601,         # stubbed image patch embeddings
    tie_embeddings=False,
)
