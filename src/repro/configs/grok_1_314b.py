"""grok-1-314b [moe] — 8 experts top-2.  [hf:xai-org/grok-1; unverified]

Full attention -> long_500k skipped.  Fitting 314B on v5e-512 needs the
8-bit optimizer-state option (EXPERIMENTS.md §Dry-run).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, head_dim=128,
    n_experts=8, top_k=2,
    tie_embeddings=False,
)
