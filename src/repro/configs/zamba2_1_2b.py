"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]
38 Mamba2 layers (ssm_state=64) with ONE shared attention+MLP block applied
every 6 layers (parameter sharing a la Zamba).  long_500k runs.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    attn_every=6,
)
