"""h2o-danube-1.8b [dense] — llama+mistral mix, SWA.  [arXiv:2401.16818; hf]

Sliding-window attention (4096) -> sub-quadratic -> long_500k runs.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, head_dim=80,
    swa_window=4096,
)
