"""whisper-small [audio] — enc-dec, conv frontend (stub).

[arXiv:2212.04356; unverified]
12 encoder + 12 decoder layers; the conv frontend is a stub: input_specs()
provides 1500 precomputed frame embeddings.  Decode shapes run mechanically
with a 32k self-KV cache (beyond Whisper's trained 448 ctx — noted; the
shapes are the assignment).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    frontend_tokens=1500,
    rope_theta=10_000.0,
)
