"""Assigned-architecture configs (+ the paper's own tensor-algebra ops)."""
from .base import SHAPES, InputShape, ModelConfig, cells_for
from .registry import ARCH_IDS, all_configs, get_config

__all__ = ["SHAPES", "InputShape", "ModelConfig", "cells_for",
           "ARCH_IDS", "all_configs", "get_config"]
