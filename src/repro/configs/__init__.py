"""Assigned-architecture configs (+ the paper's own tensor-algebra ops)."""
from .base import (SERVE_MIXES, SHAPES, InputShape, ModelConfig, ServeMix,
                   cells_for)
from .registry import ARCH_IDS, all_configs, get_config

__all__ = ["SERVE_MIXES", "SHAPES", "InputShape", "ModelConfig", "ServeMix",
           "cells_for", "ARCH_IDS", "all_configs", "get_config"]
