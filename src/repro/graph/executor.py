"""GraphAccelerator — the fused executable ``repro.generate(graph)``
returns.

Since ISSUE 9 a fused chain of gemm nodes no longer *relies* on XLA to
keep intermediates resident: every merged-eligible group in
``plan.groups`` lowers to ONE Pallas kernel
(``compile.pipeline.lower_group`` -> ``kernels/fused_chain.py``) whose
intermediates live in VMEM scratch, and ``__call__`` dispatches that
single kernel at the group's last stage instead of one ``pallas_call``
per member node.  Nodes outside any merged group — and every node of a
group that planned ineligible (VMEM overflow, non-gemm stage) or whose
tuned verdict says sequential wins — keep the PR 8 behavior: one
dispatch per node, fused edges realized as scheduled block agreement
plus XLA value residency (documented deviation, same spirit as
DESIGN.md D2).  The HBM accounting in ``cost_report()`` is the model's
(paper's) view of the same schedule either way.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..compile import pipeline
from ..core.costmodel import GraphCostReport
from ..kernels import epilogue as epilogue_mod
from .ir import AlgebraGraph
from .planner import GraphPlan, plan_graph


#: reserved operand-key prefix; ``build()`` rejects graphs whose tensor
#: or edge names use it (a collision would silently shadow the operand)
BIAS_KEY_PREFIX = "bias:"


def bias_operand_key(edge: str) -> str:
    """Operand-dict key a fused bias vector rides under (prefixed so it
    can never collide with an algebra tensor name)."""
    return f"{BIAS_KEY_PREFIX}{edge}"


def _check_bias_namespace(graph: AlgebraGraph) -> None:
    """Reject names inside the reserved ``bias:`` operand namespace.

    The executor injects fused bias vectors into each kernel's operand
    dict under ``bias_operand_key(edge)``; a user tensor or edge named
    inside that prefix would silently shadow (or be shadowed by) the
    injected operand.  Caught at build time instead (ISSUE 9 bugfix).
    """
    offenders = []
    for e in graph.inputs:
        if e.startswith(BIAS_KEY_PREFIX):
            offenders.append(f"graph input edge {e!r}")
    for node in graph.topo_nodes:
        if node.output.startswith(BIAS_KEY_PREFIX):
            offenders.append(f"edge {node.output!r} (node {node.name})")
        if node.algebra is not None:
            for t in (*node.algebra.inputs, node.algebra.output):
                if t.name.startswith(BIAS_KEY_PREFIX):
                    offenders.append(
                        f"tensor {t.name!r} (node {node.name})")
    if offenders:
        raise ValueError(
            f"name(s) collide with the reserved {BIAS_KEY_PREFIX!r} "
            f"operand-key prefix: {', '.join(sorted(set(offenders)))}; "
            f"rename them — the executor uses that namespace to route "
            f"fused bias vectors into kernels")


@dataclasses.dataclass
class GraphAccelerator:
    """Executable for a planned :class:`AlgebraGraph`.

    ``__call__`` takes one array per graph input edge and returns the
    graph output, running each planned node's compiled kernel once (a
    diamond fan-out reuses the memoized edge value — producers are never
    re-computed) with folded epilogues applied inside the kernels.
    Nodes belonging to a merged group (``group_kernels``) do not
    dispatch individually: the whole chain runs as one Pallas kernel at
    the group's last stage, intermediates never leaving VMEM.
    """

    graph: AlgebraGraph
    plan: GraphPlan
    kernels: Dict[str, pipeline.CompiledKernel]
    #: group name -> merged megakernel; populated only for eligible
    #: groups that actually merged (lowering may decline when a tuned
    #: verdict says sequential dispatch wins)
    group_kernels: Dict[str, pipeline.CompiledGroupKernel] = (
        dataclasses.field(default_factory=dict))
    #: group name -> tuner verdict (``tune_group`` result) when built
    #: with ``tune=``; benchmark/report introspection only
    group_tuning: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: whether ``build(merge=...)`` allowed merged lowering at all —
    #: lets ``describe()`` say *why* an eligible group runs sequentially
    merge_enabled: bool = True
    validated: bool = False

    @property
    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.plan.dtype)

    def __call__(self, operands: Mapping[str, jax.Array]) -> jax.Array:
        missing = [e for e in self.graph.inputs if e not in operands]
        if missing:
            raise ValueError(f"missing graph input(s): {missing}")
        values: Dict[str, jax.Array] = {
            e: jnp.asarray(operands[e]) for e in self.graph.inputs}
        folded = {n for p in self.plan.nodes.values() for n in p.folded}
        merged = {g.name: g for g in self.plan.groups
                  if g.name in self.group_kernels}
        member_of = {s: g for g in merged.values() for s in g.stages}
        # dispatch units: merged groups fire once (at any point where
        # their external inputs are ready), everything else per node.
        # A plain topo walk is NOT a valid schedule here: a tapped
        # intermediate only materializes when its whole group fires, so
        # an out-of-group consumer between two members must wait — a
        # ready-queue over units handles any interleaving.
        units = []
        for node in self.graph.topo_nodes:
            if node.name in folded:
                continue                 # runs inside its producer kernel
            g = member_of.get(node.name)
            if g is not None:
                if node.name != g.stages[-1]:
                    continue             # runs inside the merged kernel
                units.append(("group", g))
            else:
                units.append(("node", node))
        pending = units
        while pending:
            later = []
            for kind, u in pending:
                if all(e in values for e in self._unit_inputs(kind, u)):
                    self._run_unit(kind, u, values)
                else:
                    later.append((kind, u))
            if len(later) == len(pending):   # pragma: no cover
                raise RuntimeError(
                    f"graph execution deadlocked; unschedulable units: "
                    f"{[getattr(u, 'name', u) for _, u in later]}")
            pending = later
        return values[self.graph.output]

    def _unit_inputs(self, kind, u):
        """Edges a dispatch unit needs materialized before it can run."""
        if kind == "group":
            if u.kind == "dag":
                return [e for e, _ in u.ext_inputs]
            return ([u.lhs_edge] + list(u.rhs_edges)
                    + [e for e in u.bias_edges if e is not None])
        edges = list(u.inputs)
        p = self.plan.nodes.get(u.name)
        if p is not None:
            if p.bias_edge is not None:
                edges.append(p.bias_edge)
            if p.residual_edge is not None:
                edges.append(p.residual_edge)
        return edges

    def _run_unit(self, kind, u, values) -> None:
        if kind == "group":
            gk = self.group_kernels[u.name]
            if gk.kind == "dag":
                res, *taps = gk([values[e] for e, _ in u.ext_inputs])
                values[u.result_edge] = res
                # memoize tapped intermediates like ordinary edges:
                # out-of-group consumers read them, the producer never
                # re-runs
                for (_, tedge), t in zip(u.taps, taps):
                    values[tedge] = t
            else:
                values[u.result_edge] = gk(
                    values[u.lhs_edge],
                    [values[e] for e in u.rhs_edges],
                    [values[e] for e in u.bias_edges if e is not None])
            return
        node = u
        if node.algebra is not None:
            p = self.plan.nodes[node.name]
            kern = self.kernels[node.name]
            ops = {t.name: values[e]
                   for t, e in zip(node.algebra.inputs, node.inputs)}
            if kern.bias_tensor is not None:
                ops[kern.bias_tensor] = values[p.bias_edge]
            out = kern(ops)
            if p.epilogue and not p.epilogue_fused:
                # legal-but-not-in-kernel spec: apply on the finished
                # tensor (the cost model charged the round trip)
                bias = (None if p.bias_edge is None else
                    jnp.asarray(values[p.bias_edge], jnp.float32))
                out = epilogue_mod.apply_epilogue(
                    out.astype(jnp.float32), p.epilogue,
                    bias=bias).astype(kern.dtype)
            if p.residual_edge is not None:
                # folded external residual stream, dispatched
                # sequentially: fp32 add after the epilogue — the exact
                # math the merged dag kernel runs in-phase
                out = (out.astype(jnp.float32)
                       + jnp.asarray(values[p.residual_edge],
                                     jnp.float32)
                       ).astype(kern.dtype)
            values[p.result_edge] = out
        elif node.op == "add":
            a = jnp.asarray(values[node.inputs[0]], jnp.float32)
            b = jnp.asarray(values[node.inputs[1]], jnp.float32)
            values[node.output] = (a + b).astype(self.dtype)
        else:
            bias = (None if len(node.inputs) == 1 else
                jnp.asarray(values[node.inputs[1]], jnp.float32))
            x = jnp.asarray(values[node.inputs[0]], jnp.float32)
            values[node.output] = epilogue_mod.apply_epilogue(
                x, (node.op,), bias=bias).astype(self.dtype)

    def cost_report(self) -> GraphCostReport:
        """Graph-level cycle/byte totals — fused edges priced at zero
        HBM traffic, with the unfused baseline alongside."""
        return self.plan.cost_report()

    def validate(self, seed: int = 0, atol: float = 1e-3,
                 rtol: float = 1e-5) -> float:
        """Execute on random integer operands and compare against the
        graph's float64 numpy oracle; returns max abs error, raises on
        mismatch.  ``rtol`` scales with the output magnitude: a chain
        compounds fp32 rounding multiplicatively where a single exact
        integer gemm does not."""
        operands = self.graph.random_operands(seed)
        got = np.asarray(self(operands), dtype=np.float64)
        want = np.asarray(self.graph.reference(operands), np.float64)
        err = float(np.abs(got - want).max()) if got.size else 0.0
        bound = atol + rtol * (float(np.abs(want).max()) if want.size
                               else 0.0)
        if got.shape != want.shape or err > bound:
            raise AssertionError(
                f"graph execution diverged from reference: shape "
                f"{got.shape} vs {want.shape}, max err {err:.3e} "
                f"(bound {bound:.3e})")
        self.validated = True
        return err

    def describe(self) -> str:
        """Plan description + one line per fused group stating how it
        actually executes: merged (with the chosen knobs) or sequential
        **with the fallback reason verbatim** — "why didn't this fuse"
        must be diagnosable from here alone."""
        lines = [self.plan.describe()]
        for g in self.plan.groups:
            gk = self.group_kernels.get(g.name)
            if gk is not None:
                lines.append(
                    f"  merged {g.name}: one pallas_call, bm={gk.bm} "
                    f"interleave={gk.interleave} ({gk.source})")
                continue
            if not g.eligible:
                why = g.reason
            elif not self.merge_enabled:
                why = "merging disabled (merge=False)"
            else:
                res = self.group_tuning.get(g.name)
                why = ("tuner verdict: sequential dispatch measured "
                       "faster" if res is not None and not res.merged
                       else "tuned cache verdict: sequential dispatch "
                            "wins on this machine")
            lines.append(f"  sequential {g.name}: {why}")
        return "\n".join(lines)


def build(graph: AlgebraGraph, *,
          search: Optional[int] = None,
          plan: Optional[GraphPlan] = None,
          cfg=None, dtype=jnp.float32,
          interpret: bool = False, backend: str = "pallas",
          validate: Optional[bool] = None,
          mesh=None, merge: bool = True,
          tune: Optional[int] = None) -> GraphAccelerator:
    """Plan (unless a plan is given) and lower a graph to an executable.

    Each node lowers through the one compile pipeline (``pipeline.lower``)
    with the plan's agreed blocks, folded epilogue spec and fused-group
    tag; an unconstrained node lowers with none of them and therefore
    shares the standalone ``generate(alg)`` cache entry bit-for-bit.

    ``merge=True`` (default) additionally lowers every merged-eligible
    fused group to a single megakernel (``pipeline.lower_group``);
    ``merge=False`` forces PR 8 sequential per-node dispatch — the
    merged kernels' measured baseline.  ``tune=k`` measures merged
    variants (m-block ladder x interleave, at most ``k`` trials per
    group) against sequential dispatch and keeps whichever wins,
    persisting the verdict in the on-disk tuning cache.
    """
    if mesh is not None:
        raise ValueError(
            "graph execution on a mesh is not wired yet: pass mesh= to "
            "plan_graph/search_graph for partition-agreement pricing, "
            "and shard the per-node accelerators individually")
    _check_bias_namespace(graph)
    from ..core.costmodel import ArrayConfig
    cfg = cfg if cfg is not None else ArrayConfig()
    if plan is None:
        plan = plan_graph(graph, search=search, cfg=cfg,
                          dtype=jnp.dtype(dtype).name)
    kernels: Dict[str, pipeline.CompiledKernel] = {}
    for name, p in plan.nodes.items():
        fused_ep = p.epilogue if p.epilogue_fused else ()
        bias_key = (bias_operand_key(p.bias_edge)
            if (fused_ep and p.bias_edge is not None
                and epilogue_mod.needs_bias(fused_ep)) else None)
        kernels[name] = pipeline.lower(
            p.node.algebra, p.dataflow, cfg=cfg, dtype=p.dtype,
            interpret=interpret, backend=backend, validate=validate,
            blocks=p.blocks if p.blocks_constrained else None,
            epilogue=fused_ep, bias_tensor=bias_key,
            fused_group=plan.fused_group_for(name))
    group_kernels: Dict[str, pipeline.CompiledGroupKernel] = {}
    group_tuning: Dict[str, Any] = {}
    if merge:
        for g in plan.groups:
            if not g.eligible:
                continue                 # planner fallback: sequential
            if tune:
                from ..tune import tuner as tuner_mod
                res = tuner_mod.tune_group(
                    plan, g, interpret=interpret, backend=backend,
                    max_trials=tune)
                group_tuning[g.name] = res
                if res.merged and res.kernel is not None:
                    group_kernels[g.name] = res.kernel
                continue
            gk = pipeline.lower_group(
                plan, g, interpret=interpret, backend=backend,
                validate=validate)
            if gk is not None:          # None: tuned sequential verdict
                group_kernels[g.name] = gk
    return GraphAccelerator(graph=graph, plan=plan, kernels=kernels,
                            group_kernels=group_kernels,
                            group_tuning=group_tuning,
                            merge_enabled=bool(merge))
