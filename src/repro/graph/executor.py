"""GraphAccelerator — the fused executable ``repro.generate(graph)``
returns.

Realization note (documented deviation, same spirit as DESIGN.md D2):
the generated artifact executes the planned graph as a sequence of
Pallas kernel dispatches — a fused edge means the producer kernel was
*scheduled* so its output block agrees with the consumer's input block
(folded epilogue, whole-tensor or common-divisor tiles), and the cost
model prices that edge at zero HBM traffic.  The JAX arrays that carry
values between dispatches are XLA's realization of the VMEM residency
the schedule guarantees; the HBM accounting in ``cost_report()`` is the
model's (paper's) view of the same schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..compile import pipeline
from ..core.costmodel import GraphCostReport
from ..kernels import epilogue as epilogue_mod
from .ir import AlgebraGraph
from .planner import GraphPlan, plan_graph


def bias_operand_key(edge: str) -> str:
    """Operand-dict key a fused bias vector rides under (prefixed so it
    can never collide with an algebra tensor name)."""
    return f"bias:{edge}"


@dataclasses.dataclass
class GraphAccelerator:
    """Executable for a planned :class:`AlgebraGraph`.

    ``__call__`` takes one array per graph input edge and returns the
    graph output, running each planned node's compiled kernel once (a
    diamond fan-out reuses the memoized edge value — producers are never
    re-computed) with folded epilogues applied inside the kernels.
    """

    graph: AlgebraGraph
    plan: GraphPlan
    kernels: Dict[str, pipeline.CompiledKernel]
    validated: bool = False

    @property
    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.plan.dtype)

    def __call__(self, operands: Mapping[str, jax.Array]) -> jax.Array:
        missing = [e for e in self.graph.inputs if e not in operands]
        if missing:
            raise ValueError(f"missing graph input(s): {missing}")
        values: Dict[str, jax.Array] = {
            e: jnp.asarray(operands[e]) for e in self.graph.inputs}
        folded = {n for p in self.plan.nodes.values() for n in p.folded}
        for node in self.graph.topo_nodes:
            if node.name in folded:
                continue                 # runs inside its producer kernel
            if node.algebra is not None:
                p = self.plan.nodes[node.name]
                kern = self.kernels[node.name]
                ops = {t.name: values[e]
                       for t, e in zip(node.algebra.inputs, node.inputs)}
                if kern.bias_tensor is not None:
                    ops[kern.bias_tensor] = values[p.bias_edge]
                out = kern(ops)
                if p.epilogue and not p.epilogue_fused:
                    # legal-but-not-in-kernel spec: apply on the finished
                    # tensor (the cost model charged the round trip)
                    bias = (None if p.bias_edge is None else
                        jnp.asarray(values[p.bias_edge], jnp.float32))
                    out = epilogue_mod.apply_epilogue(
                        out.astype(jnp.float32), p.epilogue,
                        bias=bias).astype(kern.dtype)
                values[p.result_edge] = out
            else:
                bias = (None if len(node.inputs) == 1 else
                    jnp.asarray(values[node.inputs[1]], jnp.float32))
                x = jnp.asarray(values[node.inputs[0]], jnp.float32)
                values[node.output] = epilogue_mod.apply_epilogue(
                    x, (node.op,), bias=bias).astype(self.dtype)
        return values[self.graph.output]

    def cost_report(self) -> GraphCostReport:
        """Graph-level cycle/byte totals — fused edges priced at zero
        HBM traffic, with the unfused baseline alongside."""
        return self.plan.cost_report()

    def validate(self, seed: int = 0, atol: float = 1e-3,
                 rtol: float = 1e-5) -> float:
        """Execute on random integer operands and compare against the
        graph's float64 numpy oracle; returns max abs error, raises on
        mismatch.  ``rtol`` scales with the output magnitude: a chain
        compounds fp32 rounding multiplicatively where a single exact
        integer gemm does not."""
        operands = self.graph.random_operands(seed)
        got = np.asarray(self(operands), dtype=np.float64)
        want = np.asarray(self.graph.reference(operands), np.float64)
        err = float(np.abs(got - want).max()) if got.size else 0.0
        bound = atol + rtol * (float(np.abs(want).max()) if want.size
                               else 0.0)
        if got.shape != want.shape or err > bound:
            raise AssertionError(
                f"graph execution diverged from reference: shape "
                f"{got.shape} vs {want.shape}, max err {err:.3e} "
                f"(bound {bound:.3e})")
        self.validated = True
        return err

    def describe(self) -> str:
        return self.plan.describe()


def build(graph: AlgebraGraph, *,
          search: Optional[int] = None,
          plan: Optional[GraphPlan] = None,
          cfg=None, dtype=jnp.float32,
          interpret: bool = False, backend: str = "pallas",
          validate: Optional[bool] = None,
          mesh=None) -> GraphAccelerator:
    """Plan (unless a plan is given) and lower a graph to an executable.

    Each node lowers through the one compile pipeline (``pipeline.lower``)
    with the plan's agreed blocks, folded epilogue spec and fused-group
    tag; an unconstrained node lowers with none of them and therefore
    shares the standalone ``generate(alg)`` cache entry bit-for-bit.
    """
    if mesh is not None:
        raise ValueError(
            "graph execution on a mesh is not wired yet: pass mesh= to "
            "plan_graph/search_graph for partition-agreement pricing, "
            "and shard the per-node accelerators individually")
    from ..core.costmodel import ArrayConfig
    cfg = cfg if cfg is not None else ArrayConfig()
    if plan is None:
        plan = plan_graph(graph, search=search, cfg=cfg,
                          dtype=jnp.dtype(dtype).name)
    kernels: Dict[str, pipeline.CompiledKernel] = {}
    for name, p in plan.nodes.items():
        fused_ep = p.epilogue if p.epilogue_fused else ()
        bias_key = (bias_operand_key(p.bias_edge)
            if (fused_ep and p.bias_edge is not None
                and epilogue_mod.needs_bias(fused_ep)) else None)
        kernels[name] = pipeline.lower(
            p.node.algebra, p.dataflow, cfg=cfg, dtype=p.dtype,
            interpret=interpret, backend=backend, validate=validate,
            blocks=p.blocks if p.blocks_constrained else None,
            epilogue=fused_ep, bias_tensor=bias_key,
            fused_group=plan.fused_group_for(name))
    return GraphAccelerator(graph=graph, plan=plan, kernels=kernels)
