"""Whole-graph planning: dataflow selection with inter-node agreement.

``plan_graph`` turns a validated :class:`~repro.graph.ir.AlgebraGraph`
into a :class:`GraphPlan` in four passes:

1. **Epilogue folding** — a sole-consumer chain of epilogue nodes
   hanging off an algebra node's output is folded into that node's
   kernel epilogue spec (``kernels/epilogue.py``), so bias/activation/
   softmax run on the fp32 output block inside the producing Pallas
   kernel instead of as separate HBM round trips.  Folding stops at a
   fan-out, a dtype change, or a spec the registry rejects; a folded
   spec the *lowered form* rejects (row-wise op on a reshaped output)
   still executes with the node but outside the kernel, and the cost
   model charges it the round trip.

2. **Per-node dataflow selection** — extends ``dse.search`` node by
   node in topological order: each candidate dataflow is priced by its
   own compute cycles *plus* the HBM traffic of the node's input edges,
   where an edge that can fuse with its already-planned producer under
   this candidate's template costs nothing.  A candidate that keeps a
   producer's output VMEM-resident can therefore beat one with fewer
   raw cycles — fused vs unfused is ranked honestly, per edge.

3. **Tile agreement** — for every fusable algebra→algebra edge the
   producer's output block schedule is made to match the consumer's
   input block schedule: when the intermediate fits the VMEM residency
   budget both sides get whole-tensor blocks (the producer flushes one
   block, the consumer streams it as its full lhs — bit-exactly one
   ``jnp.dot`` per node); otherwise the block sizes are narrowed to a
   common divisor fixpoint.  On a mesh the producer's output partition
   must also land on the same axes as the consumer's lhs partition
   (``plan.solve_partition``) or the edge is demoted to a resharded
   materialization charged at the inter-chip link.

4. **Edge pricing** — every edge decision becomes bytes in a
   :class:`~repro.core.costmodel.GraphCostReport`: materialized edges
   pay a write plus a read per unfused consumer, fused edges pay
   nothing, and the same plan re-priced with fusion disabled gives the
   ``hbm_bytes_unfused`` baseline.

A final pass (3b) walks the fused edges into connected components and
emits one :class:`FusedGroupPlan` per >=2-member component — the
schedule of the merged Pallas megakernel (``kernels/fused_chain.py``)
that runs the whole group as ONE ``pallas_call`` with intermediates in
VMEM scratch.  A purely lhs-chained component keeps the streamed
``kind="chain"`` template (m-block ladder, two interleaves); anything
richer — an edge landing on a consumer's **rhs** (the transpose folds
into the kernel's scratch read), a **batched** producer (batched_gemv's
(batch, n) image), a folded **residual** stream, or an intermediate
that must also feed an out-of-group consumer (exported as a **tap**
output) — lowers through the stage-major ``kind="dag"`` template.
Each group carries a VMEM-budget verdict: when the scratch exceeds
``_vmem_resident_limit`` (or total residency exceeds the budget) the
group is marked ineligible and the executor dispatches its members
sequentially instead.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..compile import pipeline
from ..compile.lowering import LoweredForm, lower_form
from ..core import plan as plan_mod, tiling
from ..core.costmodel import (ArrayConfig, CostReport, GraphCostReport,
                              HBM_BYTES_PER_CYCLE, PaperCycleModel)
from ..core.stt import Dataflow
from ..kernels import epilogue as epilogue_mod
from ..kernels import fused_chain as fused_chain_mod
from .ir import AlgebraGraph, GraphNode


def _vmem_resident_limit(cfg: ArrayConfig) -> int:
    """fp32 bytes an intermediate may occupy and still be scheduled as a
    single whole-tensor block (VMEM-resident between producer flush and
    consumer stream).  1/8 of the budget leaves room for the producer's
    operand blocks and the consumer's accumulator alongside it."""
    return cfg.vmem_budget_bytes // 8


@dataclasses.dataclass
class NodePlan:
    """The planned schedule of one algebra node (epilogues folded in)."""

    node: GraphNode
    dataflow: Dataflow
    report: CostReport
    form: LoweredForm
    template: str
    blocks: Tuple[int, int, int]
    blocks_constrained: bool            # True: agreement overrode chooser
    epilogue: Tuple[str, ...]           # folded epilogue spec
    bias_edge: Optional[str]            # graph edge feeding the bias op
    epilogue_fused: bool                # spec runs inside the kernel
    folded: Tuple[str, ...]             # epilogue node names folded here
    result_edge: str                    # edge this node's execution yields
    dtype: str
    #: external residual stream folded onto this node's output (an
    #: ``add`` node whose other operand is a graph input); applied in
    #: fp32 after the epilogue, in-kernel when merged, post-kernel when
    #: dispatched sequentially
    residual_edge: Optional[str] = None


@dataclasses.dataclass
class EdgeDecision:
    """Fuse-or-materialize verdict for one consumed edge instance."""

    edge: str
    producer: Optional[str]             # planned algebra node name, or None
    consumer: str
    fused: bool
    reason: str                         # why not fused ("" when fused)
    bytes_hbm: float                    # read bytes this consumer pays
    reshard_bytes: float = 0.0          # inter-chip bytes (mesh mismatch)
    #: which consumer operand the edge lands on: "lhs" (streamed A) or
    #: "rhs" (the (n, k)-stored B — fused via a transposed scratch read)
    side: str = "lhs"


@dataclasses.dataclass
class FusedGroupPlan:
    """A component of fused nodes the executor may run as ONE merged
    Pallas kernel (``kernels/fused_chain.py``): stage order, per-stage
    specs, the agreed m-block, and the VMEM verdict.  ``eligible=False``
    keeps the group as documentation of why the executor falls back to
    sequential dispatch.

    ``kind="chain"`` is the streamed lhs-chained template (``chain`` /
    ``lhs_edge`` / ``rhs_edges`` / ``bias_edges`` describe it);
    ``kind="dag"`` is the stage-major template: ``dag`` holds the bound
    :class:`~repro.kernels.fused_chain.DagStage` specs, ``ext_inputs``
    the ordered external operands as ``(edge, role)`` with role in
    ``{"lhs", "rhs", "a3d", "vec", "res", "bias"}``, and ``taps`` the
    ``(stage name, edge)`` intermediates exported to HBM for
    out-of-group consumers."""

    name: str                           # group id ("mg:<s0>+<s1>+...")
    stages: Tuple[str, ...]             # algebra node names, chain order
    lhs_edge: str                       # external (m, k0) input edge
    rhs_edges: Tuple[str, ...]          # per-stage weight edge ((n, k))
    bias_edges: Tuple[Optional[str], ...]   # per-stage bias edge or None
    chain: Tuple[fused_chain_mod.ChainStage, ...]
    m: int
    k0: int
    bm: int                             # agreed m-block (grid phases)
    dtype: str
    result_edge: str                    # edge the group's primary out yields
    scratch_bytes: int                  # intermediate strip at bm
    vmem_bytes: int                     # total residency estimate
    eligible: bool
    reason: str = ""                    # why not eligible ("" when it is)
    kind: str = "chain"                 # "chain" | "dag"
    dag: Tuple[fused_chain_mod.DagStage, ...] = ()
    ext_inputs: Tuple[Tuple[str, str], ...] = ()    # (edge, role)
    taps: Tuple[Tuple[str, str], ...] = ()          # (stage name, edge)


@dataclasses.dataclass
class GraphPlan:
    """plan_graph's result: per-node schedules + per-edge verdicts."""

    graph: AlgebraGraph
    cfg: ArrayConfig
    dtype: str
    nodes: Dict[str, NodePlan]          # algebra node name -> plan (topo)
    edges: List[EdgeDecision]
    group: str                          # fused-group id for cache keys
    mesh_shape: Optional[Tuple[int, int]] = None
    axes: Tuple[str, str] = ("x", "y")
    #: fused-node chains the executor may merge into one Pallas kernel
    groups: List[FusedGroupPlan] = dataclasses.field(default_factory=list)

    @property
    def order(self) -> Tuple[str, ...]:
        return tuple(self.nodes)

    def node_plan_for_edge(self, edge: str) -> Optional[NodePlan]:
        for np_ in self.nodes.values():
            if np_.result_edge == edge:
                return np_
        return None

    def fused_group_for(self, name: str) -> Optional[str]:
        """The ``fused_group`` cache-key tag for one node's lowering —
        None when the node is entirely unconstrained by the graph, so a
        single-node graph shares the standalone ``lower(alg)`` entry."""
        p = self.nodes[name]
        if p.blocks_constrained or p.epilogue:
            return self.group
        return None

    def cost_report(self) -> GraphCostReport:
        return _price(self)

    def describe(self) -> str:
        rep = self.cost_report()
        lines = [
            f"GraphPlan(group={self.group!r}, dtype={self.dtype}, "
            f"mesh={self.mesh_shape})"
        ]
        for name, p in self.nodes.items():
            ep = (
                f" epilogue={list(p.epilogue)}"
                f"{'' if p.epilogue_fused else ' (unfused)'}"
                if p.epilogue
                else ""
            )
            lines.append(
                f"  {name}: {p.node.algebra.name} df={p.dataflow.name} "
                f"template={p.template} blocks={p.blocks}{ep} "
                f"-> {p.result_edge}"
            )
        for e in self.edges:
            if e.producer is None:
                continue
            verdict = "fused" if e.fused else f"HBM ({e.reason})"
            lines.append(f"  edge {e.producer}->{e.consumer} "
                         f"[{e.edge}]: {verdict}")
        for g in self.groups:
            verdict = ("merged kernel" if g.eligible
                       else f"sequential ({g.reason})")
            tap = (f" taps={[e for _, e in g.taps]}" if g.taps else "")
            lines.append(
                f"  group {g.name} [{g.kind}]: {len(g.stages)} stages "
                f"bm={g.bm} scratch={g.scratch_bytes}B{tap} -> {verdict}")
        lines.append(
            f"  hbm_bytes={rep.hbm_bytes:.0f} "
            f"unfused={rep.hbm_bytes_unfused:.0f} "
            f"saved={rep.saved_hbm_bytes:.0f} "
            f"cycles={rep.cycles:.0f}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Pass 1 — epilogue folding
# ---------------------------------------------------------------------------

def _fold_epilogues(graph: AlgebraGraph) -> Dict[str, dict]:
    """For each algebra node, walk the sole-consumer epilogue chain off
    its output and fold it; returns per-node folding records.  A
    sole-consumer ``add`` node whose *other* operand is a graph input
    folds too (an external residual stream: applied in fp32 after the
    epilogue) and ends the walk; an add whose other operand is produced
    inside the graph stays a standalone node — its group-internal read
    becomes a tap export instead."""
    out: Dict[str, dict] = {}
    for node in graph.topo_nodes:
        if node.algebra is None:
            continue
        spec: List[str] = []
        bias_edge: Optional[str] = None
        residual_edge: Optional[str] = None
        folded: List[str] = []
        edge = node.output
        while True:
            consumers = graph.consumers_of(edge)
            if len(consumers) != 1 or edge == graph.output:
                break
            c = consumers[0]
            if c.algebra is None and c.op == "add":
                if (c.dtype or None) != (node.dtype or None):
                    break
                other = [e for e in c.inputs if e != edge]
                if len(other) == 1 and other[0] in graph.inputs:
                    residual_edge = other[0]
                    folded.append(c.name)
                    edge = c.output
                break                       # nothing folds after the add
            if c.algebra is not None or c.inputs[0] != edge:
                break                       # algebra consumer / bias feed
            if (c.dtype or None) != (node.dtype or None):
                break                       # dtype change: materialize
            try:
                epilogue_mod.validate_spec(tuple(spec) + (c.op,))
            except ValueError:
                break                       # e.g. a second bias op
            spec.append(c.op)
            if epilogue_mod.parse_op(c.op)[0] == "bias":
                bias_edge = c.inputs[1]
            folded.append(c.name)
            edge = c.output
        out[node.name] = dict(epilogue=tuple(spec), bias_edge=bias_edge,
                              residual_edge=residual_edge,
                              folded=tuple(folded), result_edge=edge)
    return out


# ---------------------------------------------------------------------------
# Fusability — producer side, consumer side, partition agreement
# ---------------------------------------------------------------------------

def _producer_fusable(p: NodePlan) -> Optional[str]:
    """Why this node's output cannot stay on-chip for a consumer
    (None = eligible).  The output must be a 2-D identity-finished
    matmul image — either the plain (m, n) form or a batched form whose
    single batch axis IS the output's leading axis (batched_gemv's
    (batch, n) image, PR 4's LoweredForm batch folding) — and any
    folded epilogue must run in-kernel."""
    alg = p.node.algebra
    out_shape = alg.tensor_shape(alg.output)
    if p.form.batch:
        if len(p.form.batch) != 1 or p.form.m != 1:
            return (f"producer batch grid {p.form.batch} has no 2-D "
                    f"(batch, n) image the merged template can stream")
        if out_shape != (p.form.batch[0], p.form.n):
            return (f"producer finish reshapes "
                    f"{(p.form.batch[0], p.form.n)} -> {out_shape}")
    elif out_shape != (p.form.m, p.form.n):
        return (f"producer finish reshapes {(p.form.m, p.form.n)} "
                f"-> {out_shape}")
    if p.epilogue and not p.epilogue_fused:
        return "producer epilogue applies outside the kernel"
    return None


def _consumer_fusable(node: GraphNode, edge: str
                      ) -> Tuple[Optional[str], str]:
    """``(why-not, side)`` for this consumer streaming ``edge`` from
    VMEM (why None = it can).  A gemm's A operand maps identically onto
    the kernel lhs; its B operand fuses on the **rhs** side — the edge
    arrives in B's (n, k) storage layout and the kernel reads the
    producer's scratch transposed, so no materialized transpose exists.
    mttkrp/ttmc mix their rhs factors in ``prepare`` and stay unfused."""
    alg = node.algebra
    pos = node.inputs.index(edge)
    tname = alg.inputs[pos].name
    if alg.name != "gemm":
        return (f"consumer {alg.name} prepares its operands "
                f"(non-identity)", "lhs")
    return None, ("lhs" if tname == "A" else "rhs")


def _edge_fuse_reason(p: NodePlan, c_node: GraphNode, c_dtype: str,
                      c_template: str, edge: str,
                      graph: AlgebraGraph, cfg: ArrayConfig
                      ) -> Tuple[Optional[str], str]:
    """Full single-chip fusability verdict for producer-plan -> consumer
    as ``(why-not, side)`` (why None = fusable).  Residency constraints:
    a reduction-tree consumer streams full-k blocks; an rhs-landing edge
    is contracted over in full by every consumer row; and a batched
    producer computes whole-tensor in one stage-major phase — each needs
    the intermediate VMEM-resident."""
    why = _producer_fusable(p)
    if why is not None:
        return why, "lhs"
    why, side = _consumer_fusable(c_node, edge)
    if why is not None:
        return why, side
    if p.dtype != c_dtype:
        return (f"dtype changes {p.dtype} -> {c_dtype} across the edge",
                side)
    shape = graph.edge_shape(edge)
    nbytes = 4 * int(np.prod(shape))
    limit = _vmem_resident_limit(cfg)
    if side == "rhs" and nbytes > limit:
        return (f"rhs-landing intermediate {shape} must stay "
                f"VMEM-resident ({nbytes}B > {limit}B residency limit)",
                side)
    if p.form.batch and nbytes > limit:
        return (f"batched producer output {shape} must stay "
                f"VMEM-resident ({nbytes}B > {limit}B residency limit)",
                side)
    if (c_template == "reduction_tree" and nbytes > limit):
        return (f"consumer reduction-tree needs the full {shape} "
                f"intermediate resident ({nbytes}B > budget)", side)
    return None, side


def _solve(p_or_df: Dataflow, form: LoweredForm, axes, shape):
    return plan_mod.solve_partition(
        plan_mod.comm_plan_for(p_or_df, axes), form, axes=axes, shape=shape)


def _partition_agrees(p: NodePlan, c_df: Dataflow, c_form: LoweredForm,
                      axes: Tuple[str, str], shape: Tuple[int, int],
                      side: str = "lhs") -> Optional[str]:
    """Mesh agreement: the producer's out shards must land where the
    consumer's streamed operand expects them — lhs side pairs edge
    m <-> lhs m / n <-> lhs k; an rhs-landing edge arrives in B's (n, k)
    storage, pairing edge m <-> rhs n / n <-> rhs k — else the edge pays
    an inter-chip reshard (None = agrees)."""
    sol_p = _solve(p.dataflow, p.form, axes, shape)
    sol_c = _solve(c_df, c_form, axes, shape)
    out_ax = sol_p.out.axis_of
    if side == "rhs":
        c_ax, pairs, label = sol_c.rhs.axis_of, (("m", "n"), ("n", "k")), \
            "rhs"
    else:
        c_ax, pairs, label = sol_c.lhs.axis_of, (("m", "m"), ("n", "k")), \
            "lhs"
    for pd, cd in pairs:
        if out_ax.get(pd) != c_ax.get(cd):
            return (f"partition mismatch: producer out {pd}="
                    f"{out_ax.get(pd)!r} vs consumer {label} {cd}="
                    f"{c_ax.get(cd)!r}")
    return None


# ---------------------------------------------------------------------------
# Pass 3 — tile agreement
# ---------------------------------------------------------------------------

def _agree_blocks(plans: Dict[str, NodePlan], fused: List[EdgeDecision],
                  graph: AlgebraGraph, cfg: ArrayConfig) -> None:
    """Make producer output blocks match consumer lhs blocks on every
    fused edge (fixpoint: agreement on one edge can narrow another)."""
    limit = _vmem_resident_limit(cfg)
    for _ in range(1 + len(fused)):
        changed = False
        for e in fused:
            p, c = plans[e.producer], plans[e.consumer]
            m_e, n_e = graph.edge_shape(e.edge)
            bn_c = c.blocks[1]
            if 4 * m_e * n_e <= limit:
                bm, bn = m_e, n_e       # whole tensor: one resident block
                if 4 * m_e * c.form.n <= limit:
                    # consumer accumulator fits too: single-dot schedule
                    # (bit-identical to the oracle's one jnp.dot)
                    bn_c = c.form.n
            else:
                bm = math.gcd(math.gcd(p.blocks[0], c.blocks[0]), m_e)
                bn = math.gcd(math.gcd(p.blocks[1], c.blocks[2]), n_e)
            new_p = (bm, bn, p.blocks[2])
            new_c = (bm, bn_c, bn)
            if new_p != p.blocks:
                p.blocks, p.blocks_constrained, changed = new_p, True, True
            if new_c != c.blocks:
                c.blocks, c.blocks_constrained, changed = new_c, True, True
        if not changed:
            return
    raise RuntimeError("tile agreement did not converge")   # pragma: no cover


# ---------------------------------------------------------------------------
# Pass 3b — merged-kernel group derivation
# ---------------------------------------------------------------------------

def _group_eligibility(chain: List[str], plans: Dict[str, NodePlan],
                       cfg: ArrayConfig) -> Optional[str]:
    """Why this fused component cannot run as one megakernel (None = it
    can).  Stages must be gemms — or batched forms with a 2-D (batch, n)
    image — with in-kernel epilogues and one shared dtype; anything else
    dispatches sequentially (still fused in the schedule/cost-model
    sense)."""
    for name in chain:
        p = plans[name]
        if p.node.algebra.name != "gemm" and not p.form.batch:
            return (f"stage {name} is {p.node.algebra.name}; the merged "
                    f"template chains gemm stages only")
        if p.form.batch and _producer_fusable(p) is not None:
            return f"stage {name}: {_producer_fusable(p)}"
        if p.epilogue and not p.epilogue_fused:
            return (f"stage {name} epilogue applies outside the kernel")
    dtypes = {plans[n].dtype for n in chain}
    if len(dtypes) > 1:
        return f"stages disagree on dtype ({sorted(dtypes)})"
    return None


def _components(plans: Dict[str, NodePlan],
                decisions: List[EdgeDecision]) -> List[List[str]]:
    """Connected components of the fused producer->consumer edges, each
    in topo order (``plans`` preserves the graph's topo order)."""
    parent: Dict[str, str] = {n: n for n in plans}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in decisions:
        if e.fused and e.producer is not None:
            parent[find(e.producer)] = find(e.consumer)
    comps: Dict[str, List[str]] = {}
    for name in plans:
        comps.setdefault(find(name), []).append(name)
    return [names for names in comps.values() if len(names) >= 2]


def _schedulable_subgroups(names: List[str],
                           plans: Dict[str, NodePlan],
                           graph: AlgebraGraph) -> List[List[str]]:
    """Split a fused component into single-dispatch-schedulable runs.

    A merged group fires as ONE kernel at its last member, so none of
    its *external* inputs may depend — through out-of-group nodes — on
    any member's output (an out-of-group consumer of a tap that feeds a
    later member would deadlock the dispatch).  Greedy topo scan: a
    member whose inputs reach the open subgroup's outputs from outside
    closes the subgroup and starts the next one (the closed group's
    results materialize before the next group fires, so later reads of
    them are legal ext inputs)."""
    dep_cache: Dict[Tuple[str, frozenset], bool] = {}

    def depends_on(edge: str, outputs: frozenset) -> bool:
        key = (edge, outputs)
        if key in dep_cache:
            return dep_cache[key]
        dep_cache[key] = False          # cycle-safe default (DAG anyway)
        if edge in outputs:
            hit = True
        else:
            prod = graph.producer_of(edge)
            hit = prod is not None and any(
                depends_on(e, outputs) for e in prod.inputs)
        dep_cache[key] = hit
        return hit

    subgroups: List[List[str]] = []
    current: List[str] = []
    cur_outs: frozenset = frozenset()
    for n in names:
        p = plans[n]
        ins = list(p.node.inputs)
        if p.bias_edge is not None:
            ins.append(p.bias_edge)
        if p.residual_edge is not None:
            ins.append(p.residual_edge)
        internal = {plans[m].result_edge for m in current}
        conflict = any(e not in internal and depends_on(e, cur_outs)
                       for e in ins)
        if conflict:
            subgroups.append(current)
            current, cur_outs = [], frozenset()
        current.append(n)
        cur_outs = cur_outs | {p.result_edge}
    subgroups.append(current)
    return [s for s in subgroups if len(s) >= 2]


def _derive_groups(plans: Dict[str, NodePlan],
                   decisions: List[EdgeDecision],
                   graph: AlgebraGraph, cfg: ArrayConfig
                   ) -> List[FusedGroupPlan]:
    """Turn each connected component of fused edges into a
    :class:`FusedGroupPlan`.

    A purely lhs-chained component whose intermediates are sole-consumed
    keeps the streamed ``kind="chain"`` template.  Everything else —
    rhs-landing edges, batched stages, folded residual streams, and
    intermediates that also feed out-of-group consumers — lowers through
    the stage-major ``kind="dag"`` template; an intermediate some
    outsider reads is exported as a **tap** output, so the producer
    still runs exactly once.
    """
    folded_names = {n for p in plans.values() for n in p.folded}
    groups: List[FusedGroupPlan] = []
    runs = [sub for comp in _components(plans, decisions)
            for sub in _schedulable_subgroups(comp, plans, graph)]
    for names in runs:
        p0, p_last = plans[names[0]], plans[names[-1]]
        gname = "mg:" + "+".join(names)
        member_set = set(names)
        owner_at = {plans[n].result_edge: i for i, n in enumerate(names)}

        def out_of_group_readers(edge):
            return [c.name for c in graph.consumers_of(edge)
                    if c.name not in member_set
                    and c.name not in folded_names]

        # which members must export their intermediate to HBM
        tap_members: List[Tuple[str, str]] = []
        for i, n in enumerate(names[:-1]):
            redge = plans[n].result_edge
            if out_of_group_readers(redge) or redge == graph.output:
                tap_members.append((n, redge))

        why = _group_eligibility(names, plans, cfg)
        if why is not None:
            groups.append(FusedGroupPlan(
                name=gname, stages=tuple(names),
                lhs_edge=p0.node.inputs[0], rhs_edges=(), bias_edges=(),
                chain=(), m=p0.form.m, k0=p0.form.k, bm=p0.blocks[0],
                dtype=p0.dtype, result_edge=p_last.result_edge,
                scratch_bytes=0, vmem_bytes=0, eligible=False,
                reason=why, kind="dag" if tap_members else "chain",
                taps=tuple(tap_members)))
            continue

        # chain-template test: linear lhs chaining, sole-consumed
        # intermediates, external weights, no batch/residual/taps
        is_chain = not tap_members and not any(
            plans[n].form.batch or plans[n].residual_edge is not None
            for n in names)
        if is_chain:
            for i, n in enumerate(names[:-1]):
                redge = plans[n].result_edge
                nxt = plans[names[i + 1]]
                readers = [c.name for c in graph.consumers_of(redge)
                           if c.name not in folded_names]
                if (nxt.node.inputs[0] != redge
                        or readers != [names[i + 1]]
                        or redge == graph.output):
                    is_chain = False
                    break
            if is_chain and any(plans[n].node.inputs[1] in owner_at
                                for n in names):
                is_chain = False        # an rhs lands in-group: dag

        if is_chain:
            groups.append(_chain_group(names, plans, cfg, gname))
        else:
            groups.append(_dag_group(names, plans, graph, cfg, gname,
                                     tap_members, owner_at))
    return groups


def _chain_group(chain: List[str], plans: Dict[str, NodePlan],
                 cfg: ArrayConfig, gname: str) -> FusedGroupPlan:
    """The streamed lhs-chained template (PR 9), unchanged."""
    p0 = plans[chain[0]]
    stage_specs = tuple(
        fused_chain_mod.ChainStage(
            k=plans[n].form.k, n=plans[n].form.n,
            epilogue=plans[n].epilogue,
            has_bias=(plans[n].bias_edge is not None
                      and epilogue_mod.needs_bias(plans[n].epilogue)))
        for n in chain)
    # gemm stores its inputs as (A, B): inputs[0] is the streamed
    # lhs edge, inputs[1] the (n, k)-stored weight edge
    rhs_edges = tuple(plans[n].node.inputs[1] for n in chain)
    bias_edges = tuple(
        plans[n].bias_edge if st.has_bias else None
        for n, st in zip(chain, stage_specs))
    m, k0, bm = p0.form.m, p0.form.k, p0.blocks[0]
    eb = _elem_bytes(p0.dtype)
    scratch = fused_chain_mod.chain_scratch_bytes(stage_specs, bm, eb)
    vmem = fused_chain_mod.chain_vmem_bytes(stage_specs, m, k0, bm, eb)
    eligible, reason = True, ""
    if scratch > _vmem_resident_limit(cfg):
        eligible = False
        reason = (f"intermediate scratch strip {scratch}B exceeds "
                  f"the VMEM residency limit "
                  f"{_vmem_resident_limit(cfg)}B")
    elif vmem > cfg.vmem_budget_bytes:
        eligible = False
        reason = (f"total residency {vmem}B exceeds the VMEM budget "
                  f"{cfg.vmem_budget_bytes}B")
    return FusedGroupPlan(
        name=gname, stages=tuple(chain),
        lhs_edge=p0.node.inputs[0], rhs_edges=rhs_edges,
        bias_edges=bias_edges, chain=stage_specs, m=m, k0=k0, bm=bm,
        dtype=p0.dtype, result_edge=plans[chain[-1]].result_edge,
        scratch_bytes=scratch, vmem_bytes=vmem,
        eligible=eligible, reason=reason)


def _dag_group(names: List[str], plans: Dict[str, NodePlan],
               graph: AlgebraGraph, cfg: ArrayConfig, gname: str,
               tap_members: List[Tuple[str, str]],
               owner_at: Dict[str, int]) -> FusedGroupPlan:
    """Bind a component to the stage-major DAG template: resolve every
    operand to an external slot or an earlier stage's scratch, assign
    tap output slots, and gate on whole-tensor VMEM residency."""
    ext: List[Tuple[str, str]] = []
    ext_slots: Dict[Tuple[str, str], int] = {}

    def ext_slot(edge: str, role: str) -> int:
        key = (edge, role)
        if key not in ext_slots:
            ext_slots[key] = len(ext)
            ext.append(key)
        return ext_slots[key]

    tap_of = {n: slot for slot, (n, _) in enumerate(tap_members)}
    dag: List[fused_chain_mod.DagStage] = []
    for i, n in enumerate(names):
        p = plans[n]
        node = p.node
        if p.form.batch:
            m_eff, k_eff, n_eff = p.form.batch[0], p.form.k, p.form.n
            kind = "batched"
            lhs_src = ("ext", ext_slot(node.inputs[0], "a3d"))
            j = owner_at.get(node.inputs[1])
            rhs_src = (("scr", j) if j is not None and j < i
                       else ("ext", ext_slot(node.inputs[1], "vec")))
        else:
            m_eff, k_eff, n_eff = p.form.m, p.form.k, p.form.n
            kind = "dot"
            j = owner_at.get(node.inputs[0])
            lhs_src = (("scr", j) if j is not None and j < i
                       else ("ext", ext_slot(node.inputs[0], "lhs")))
            j = owner_at.get(node.inputs[1])
            rhs_src = (("scr", j) if j is not None and j < i
                       else ("ext", ext_slot(node.inputs[1], "rhs")))
        res_src = None
        if p.residual_edge is not None:
            j = owner_at.get(p.residual_edge)
            res_src = (("scr", j) if j is not None and j < i
                       else ("ext", ext_slot(p.residual_edge, "res")))
        has_bias = (p.bias_edge is not None
                    and epilogue_mod.needs_bias(p.epilogue))
        bias_idx = ext_slot(p.bias_edge, "bias") if has_bias else -1
        dag.append(fused_chain_mod.DagStage(
            m=m_eff, k=k_eff, n=n_eff, kind=kind, lhs=lhs_src,
            rhs=rhs_src, res=res_src, epilogue=p.epilogue,
            has_bias=has_bias, bias=bias_idx, tap=tap_of.get(n, -1)))

    p0, p_last = plans[names[0]], plans[names[-1]]
    eb = _elem_bytes(p0.dtype)
    scratch = fused_chain_mod.dag_scratch_bytes(dag, eb)
    ext_bytes = 0
    for edge, role in ext:
        nel = int(np.prod(graph.edge_shape(edge)))
        ext_bytes += nel * (4 if role in ("res", "bias") else eb)
    out_bytes = dag[-1].m * dag[-1].n * eb
    out_bytes += sum(st.m * st.n * eb for st in dag if st.tap >= 0)
    vmem = ext_bytes + out_bytes + scratch
    eligible, reason = True, ""
    if scratch > _vmem_resident_limit(cfg):
        eligible = False
        reason = (f"DAG intermediate scratch {scratch}B exceeds the "
                  f"VMEM residency limit {_vmem_resident_limit(cfg)}B")
    elif vmem > cfg.vmem_budget_bytes:
        eligible = False
        reason = (f"total residency {vmem}B exceeds the VMEM budget "
                  f"{cfg.vmem_budget_bytes}B")
    else:
        try:
            fused_chain_mod.validate_dag(dag)
        except ValueError as e:         # defensive: unbindable wiring
            eligible, reason = False, f"DAG binding failed: {e}"
    return FusedGroupPlan(
        name=gname, stages=tuple(names),
        lhs_edge=p0.node.inputs[0], rhs_edges=(), bias_edges=(),
        chain=(), m=dag[-1].m, k0=dag[0].k, bm=dag[-1].m,
        dtype=p0.dtype, result_edge=p_last.result_edge,
        scratch_bytes=scratch, vmem_bytes=vmem,
        eligible=eligible, reason=reason, kind="dag", dag=tuple(dag),
        ext_inputs=tuple(ext), taps=tuple(tap_members))


# ---------------------------------------------------------------------------
# Pass 4 — pricing
# ---------------------------------------------------------------------------

def _elem_bytes(dtype: str) -> int:
    return int(np.dtype(dtype if dtype != "bfloat16" else "float16"
                        ).itemsize)


def _price(plan: GraphPlan, assume_unfused: bool = False
           ) -> GraphCostReport:
    graph, cfg = plan.graph, plan.cfg
    edge_bytes: Dict[str, float] = {}
    reshard: Dict[str, float] = {}

    def size_bytes(edge: str, dtype: str) -> float:
        return float(np.prod(graph.edge_shape(edge))) * _elem_bytes(dtype)

    def charge(edge: str, b: float) -> None:
        edge_bytes[edge] = edge_bytes.get(edge, 0.0) + b

    fused_edges: List[str] = []
    materialized: List[Tuple[str, str]] = []
    # reads: one per consumed edge instance unless the edge fuses
    for e in plan.edges:
        dtype = (
            plan.nodes[e.consumer].dtype
            if e.consumer in plan.nodes
            else plan.dtype
        )
        if e.fused and not assume_unfused:
            fused_edges.append(f"{e.producer}->{e.consumer}:{e.edge}")
            continue
        charge(e.edge, size_bytes(e.edge, dtype))
        if e.producer is not None:
            why = e.reason or ("fusion disabled" if assume_unfused
                               else "")
            materialized.append((f"{e.producer}->{e.consumer}:{e.edge}",
                                 why))
        if e.reshard_bytes and not assume_unfused:
            reshard[e.edge] = reshard.get(e.edge, 0.0) + e.reshard_bytes
    # writes: a produced edge hits HBM unless every consumer fused it
    for name, p in plan.nodes.items():
        consumers = [e for e in plan.edges if e.producer == name]
        all_fused = (
            consumers
            and all(e.fused for e in consumers)
            and not assume_unfused
        )
        if p.result_edge == graph.output or not all_fused:
            charge(p.result_edge, size_bytes(p.result_edge, p.dtype))
        if p.epilogue and (assume_unfused or not p.epilogue_fused):
            # outside-the-kernel epilogue: one extra round trip
            charge(p.result_edge, 2 * size_bytes(p.result_edge, p.dtype))
    # standalone epilogue nodes (never folded): read + write round trip;
    # their *input* read is already charged via plan.edges
    folded = {n for p in plan.nodes.values() for n in p.folded}
    for node in graph.topo_nodes:
        if node.algebra is None and node.name not in folded:
            charge(node.output, size_bytes(node.output, plan.dtype))

    # tap attribution: a merged group's exported intermediates are
    # already inside edge_bytes (write + out-of-group reads); name them
    tapped: List[str] = []
    tap_bytes = 0.0
    if not assume_unfused:
        for g in plan.groups:
            if not g.eligible:
                continue
            for _, tedge in g.taps:
                tapped.append(f"{g.name}:{tedge}")
                tap_bytes += edge_bytes.get(tedge, 0.0)

    node_cycles = {n: p.report.cycles for n, p in plan.nodes.items()}
    compute = sum(node_cycles.values())
    hbm = sum(edge_bytes.values())
    if assume_unfused:
        unfused = hbm
    else:
        unfused = _price(plan, assume_unfused=True).hbm_bytes_unfused
    return GraphCostReport(
        node_cycles=node_cycles, compute_cycles=compute,
        edge_bytes=edge_bytes, hbm_bytes=hbm, hbm_bytes_unfused=unfused,
        fused_edges=tuple(fused_edges),
        materialized_edges=tuple(materialized),
        reshard_bytes=reshard, mesh_shape=plan.mesh_shape,
        tapped_edges=tuple(tapped), tap_hbm_bytes=tap_bytes)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def plan_graph(graph: AlgebraGraph, *,
               search: Optional[int] = None,
               cfg: ArrayConfig = ArrayConfig(),
               dtype: str = "float32",
               mesh=None,
               axes: Tuple[str, str] = ("x", "y")) -> GraphPlan:
    """Plan a graph: fold epilogues, pick per-node dataflows, agree
    tiles/partitions across fusable edges, price every edge.

    ``search=None`` uses the default output-stationary schedule per
    node; ``search=k`` runs the per-node DSE over the top-k candidates
    of ``dse.search``, ranking each candidate by its compute cycles plus
    the HBM traffic its input edges would actually pay (fused = free).
    ``mesh`` (a Mesh or (rows, cols)) adds the partition-agreement
    constraint and prices disagreeing edges as inter-chip reshards.
    """
    from ..core import dse

    mesh_shape = None if mesh is None else dse._mesh_shape(mesh)
    folds = _fold_epilogues(graph)
    model = PaperCycleModel(cfg)
    group = (
        "g:"
        + "|".join(n.name for n in graph.topo_nodes)
        + "->"
        + graph.output
    )

    plans: "Dict[str, NodePlan]" = {}
    result_owner: Dict[str, str] = {}   # result edge -> planned node name
    decisions: List[EdgeDecision] = []

    for node in graph.topo_nodes:
        if node.algebra is None:
            continue
        alg = node.algebra
        fold = folds[node.name]
        node_dtype = node.dtype or dtype
        form = lower_form(alg)
        epilogue = fold["epilogue"]
        ep_reason = (
            pipeline._epilogue_legal_for_form(alg, form, epilogue)
            if epilogue
            else None
        )
        epilogue_fused = bool(epilogue) and ep_reason is None

        if search:
            candidates = dse.search(alg, top_k=search, cfg=cfg)
        else:
            df0 = pipeline.default_dataflow(alg)
            candidates = [(model.evaluate(alg, df0), df0)]

        best = None
        for rep, df in candidates:
            template = plan_mod.kernel_plan_for(df).template
            extra = 0.0
            for pos, edge in enumerate(node.inputs):
                owner = result_owner.get(edge)
                if owner is None:
                    continue
                why, side = _edge_fuse_reason(
                    plans[owner], node, node_dtype, template, edge,
                    graph, cfg)
                if why is None and mesh_shape is not None:
                    why = _partition_agrees(plans[owner], df, form,
                                            axes, mesh_shape, side=side)
                if why is not None:
                    shape = graph.edge_shape(edge)
                    extra += (float(np.prod(shape))
                              * _elem_bytes(node_dtype)
                              / HBM_BYTES_PER_CYCLE)
            score = rep.cycles + extra
            if best is None or score < best[0]:
                best = (score, rep, df, template)
        _, rep, df, template = best

        blocks = tiling.form_blocks(alg, df, form, cfg.pe_dims)
        if epilogue_fused and epilogue_mod.has_softmax(epilogue):
            blocks = (blocks[0], form.n, blocks[2])
        p = NodePlan(
            node=node, dataflow=df, report=rep, form=form,
            template=template, blocks=blocks, blocks_constrained=False,
            epilogue=epilogue, bias_edge=fold["bias_edge"],
            epilogue_fused=epilogue_fused, folded=fold["folded"],
            result_edge=fold["result_edge"], dtype=node_dtype,
            residual_edge=fold["residual_edge"])
        plans[node.name] = p
        result_owner[p.result_edge] = node.name

        # decide each input edge against its (already planned) producer
        for pos, edge in enumerate(node.inputs):
            owner = result_owner.get(edge)
            if owner is None or owner == node.name:
                decisions.append(EdgeDecision(
                    edge=edge, producer=None, consumer=node.name,
                    fused=False, reason="graph input",
                    bytes_hbm=float(np.prod(graph.edge_shape(edge)))
                    * _elem_bytes(node_dtype)))
                continue
            why, side = _edge_fuse_reason(
                plans[owner], node, node_dtype, template, edge, graph,
                cfg)
            reshard_b = 0.0
            if why is None and mesh_shape is not None:
                why = _partition_agrees(plans[owner], df, form,
                                        axes, mesh_shape, side=side)
                if why is not None:
                    reshard_b = (
                        float(np.prod(graph.edge_shape(edge)))
                        * _elem_bytes(node_dtype)
                    )
            nbytes = (
                0.0
                if why is None
                else float(np.prod(graph.edge_shape(edge)))
                * _elem_bytes(node_dtype)
            )
            decisions.append(EdgeDecision(
                edge=edge, producer=owner, consumer=node.name,
                fused=why is None, reason=why or "", bytes_hbm=nbytes,
                reshard_bytes=reshard_b, side=side))
        if fold["bias_edge"] is not None:
            decisions.append(EdgeDecision(
                edge=fold["bias_edge"], producer=None,
                consumer=node.name, fused=False, reason="graph input",
                bytes_hbm=float(np.prod(
                    graph.edge_shape(fold["bias_edge"])))
                * _elem_bytes(node_dtype)))
        if fold["residual_edge"] is not None:
            # external residual stream folded onto this node's output:
            # still a real HBM read
            decisions.append(EdgeDecision(
                edge=fold["residual_edge"], producer=None,
                consumer=node.name, fused=False, reason="graph input",
                bytes_hbm=float(np.prod(
                    graph.edge_shape(fold["residual_edge"])))
                * _elem_bytes(node_dtype)))

    # standalone (unfolded) epilogue nodes read their tensor input too
    folded_names = {n for p in plans.values() for n in p.folded}
    for node in graph.topo_nodes:
        if node.algebra is None and node.name not in folded_names:
            for e in node.inputs:
                decisions.append(EdgeDecision(
                    edge=e, producer=result_owner.get(e),
                    consumer=node.name, fused=False,
                    reason="standalone epilogue node",
                    bytes_hbm=float(np.prod(graph.edge_shape(e)))
                    * _elem_bytes(dtype)))

    plan = GraphPlan(graph=graph, cfg=cfg, dtype=dtype, nodes=plans,
                     edges=decisions, group=group, mesh_shape=mesh_shape,
                     axes=axes)
    # block agreement drives the *streamed* chain template: only
    # lhs-landing edges off non-batched producers constrain m/n blocks
    # (rhs-landing and batched edges are whole-tensor VMEM-resident by
    # construction — the dag template pins them full-size)
    _agree_blocks(plans,
                  [e for e in decisions
                   if e.fused and e.side == "lhs"
                   and not plans[e.producer].form.batch],
                  graph, cfg)
    plan.groups = _derive_groups(plans, decisions, graph, cfg)
    return plan
