"""The AlgebraGraph IR: a DAG of tensor algebras and epilogue ops.

Nodes are either

* **algebra** nodes — one :class:`~repro.core.algebra.TensorAlgebra`
  whose ordered ``inputs`` edges bind to ``alg.inputs`` by position, or
* **epilogue** nodes — one elementwise / row-wise post-processing op
  from the ``kernels/epilogue.py`` registry (``"gelu"``,
  ``"scale:0.125"``, ``"softmax"``, ``"bias"``).  A ``"bias"`` node
  takes a second input edge: the rank-1 bias vector.
* **add** nodes — ``op == "add"``: elementwise sum of two same-shape
  edges (the transformer residual stream).  Adds are not epilogue ops:
  the planner folds one into the producing kernel only when its other
  operand is a graph input (an external residual stream); otherwise it
  stays a standalone node and the edge it reads from a merged group is
  exported as a tap.

Edges are tensors, named by strings; every edge has exactly one
producer (a node or the graph input list) and any number of consumers.
Shapes are inferred from the algebras' loop bounds and validated at
construction — a shape-mismatched wiring fails here, not at trace time.

The IR is deliberately *functional*: ``reference(operands)`` evaluates
the whole graph with the numpy loop-nest oracle
(``TensorAlgebra.reference``) composed with the numpy epilogue mirror,
which is the bit-for-bit semantics every execution plan must reproduce.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core.algebra import TensorAlgebra
from ..kernels import epilogue as epilogue_mod


@dataclasses.dataclass(frozen=True)
class GraphNode:
    """One node: an algebra or a single epilogue op.

    ``inputs`` are edge names; for algebra nodes they bind positionally
    to ``algebra.inputs`` (e.g. gemm's ``("A", "B")``), for epilogue
    nodes the first is the tensor and an optional second is the bias
    vector (``op == "bias"`` only).  ``dtype`` overrides the graph-level
    compute dtype for this node (None = inherit).
    """

    name: str
    inputs: Tuple[str, ...]
    output: str
    algebra: Optional[TensorAlgebra] = None
    op: Optional[str] = None
    dtype: Optional[str] = None

    @property
    def kind(self) -> str:
        return "algebra" if self.algebra is not None else "epilogue"

    def __post_init__(self):
        if (self.algebra is None) == (self.op is None):
            raise ValueError(f"node {self.name!r}: exactly one of "
                             f"algebra= or op= must be given")
        if self.algebra is not None:
            want = len(self.algebra.inputs)
            if len(self.inputs) != want:
                raise ValueError(
                    f"node {self.name!r}: algebra {self.algebra.name} has "
                    f"{want} input tensors, got {len(self.inputs)} edges")
        elif self.op == "add":
            if len(self.inputs) != 2:
                raise ValueError(
                    f"node {self.name!r}: add takes 2 input edges, "
                    f"got {len(self.inputs)}")
        else:
            opname, _ = epilogue_mod.parse_op(self.op)
            want = 2 if opname == "bias" else 1
            if len(self.inputs) != want:
                raise ValueError(
                    f"node {self.name!r}: epilogue op {self.op!r} takes "
                    f"{want} input edge(s), got {len(self.inputs)}")


@dataclasses.dataclass(frozen=True)
class AlgebraGraph:
    """A validated DAG of :class:`GraphNode`.

    ``inputs`` are the external edge names (the operand-dict keys of the
    generated :class:`~repro.graph.executor.GraphAccelerator`);
    ``output`` is the edge whose value ``__call__`` returns.
    """

    nodes: Tuple[GraphNode, ...]
    inputs: Tuple[str, ...]
    output: str

    def __post_init__(self):
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate node names: {names}")
        produced: Dict[str, str] = {}
        for n in self.nodes:
            if n.output in produced:
                raise ValueError(
                    f"edge {n.output!r} produced by both "
                    f"{produced[n.output]!r} and {n.name!r}")
            if n.output in self.inputs:
                raise ValueError(f"edge {n.output!r} is both a graph "
                                 f"input and {n.name!r}'s output")
            produced[n.output] = n.name
        known = set(self.inputs) | set(produced)
        for n in self.nodes:
            for e in n.inputs:
                if e not in known:
                    raise ValueError(f"node {n.name!r} consumes unknown "
                                     f"edge {e!r}")
        if self.output not in produced:
            raise ValueError(f"graph output {self.output!r} is not "
                             f"produced by any node")
        # topo-sort (also rejects cycles) and cache derived maps; the
        # dataclass is frozen so object.__setattr__ is the sanctioned way
        object.__setattr__(self, "_topo", self._topo_sort())
        object.__setattr__(self, "_shapes", self._infer_shapes())

    # -- topology ---------------------------------------------------------
    def producer_of(self, edge: str) -> Optional[GraphNode]:
        """The node producing ``edge`` (None for graph inputs)."""
        for n in self.nodes:
            if n.output == edge:
                return n
        return None

    def consumers_of(self, edge: str) -> Tuple[GraphNode, ...]:
        return tuple(n for n in self.nodes if edge in n.inputs)

    def node(self, name: str) -> GraphNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def _topo_sort(self) -> Tuple[GraphNode, ...]:
        ready = set(self.inputs)
        order: List[GraphNode] = []
        pending = list(self.nodes)
        while pending:
            nxt = [n for n in pending if all(e in ready for e in n.inputs)]
            if not nxt:
                raise ValueError(
                    f"graph has a cycle through "
                    f"{sorted(n.name for n in pending)}")
            for n in nxt:
                order.append(n)
                ready.add(n.output)
                pending.remove(n)
        return tuple(order)

    @property
    def topo_nodes(self) -> Tuple[GraphNode, ...]:
        """Nodes in a topological order (producers before consumers)."""
        return self._topo

    # -- shapes -----------------------------------------------------------
    def _expected_input_shape(self, node: GraphNode, pos: int,
                              shapes: Dict[str, Tuple[int, ...]]
                              ) -> Optional[Tuple[int, ...]]:
        if node.algebra is not None:
            return node.algebra.tensor_shape(node.algebra.inputs[pos])
        x_shape = shapes.get(node.inputs[0])
        if pos == 0:
            return None          # epilogue x: any shape, propagated below
        if node.op == "add":
            return x_shape       # both addends share one shape
        return None if x_shape is None else (x_shape[-1],)

    def _infer_shapes(self) -> Dict[str, Tuple[int, ...]]:
        shapes: Dict[str, Tuple[int, ...]] = {}
        for node in self._topo:
            for pos, e in enumerate(node.inputs):
                want = self._expected_input_shape(node, pos, shapes)
                if want is None:
                    continue
                have = shapes.get(e)
                if have is None:
                    shapes[e] = want
                elif have != want:
                    raise ValueError(
                        f"edge {e!r} shape mismatch: produced/used as "
                        f"{have}, but node {node.name!r} expects {want}")
            if node.algebra is not None:
                shapes[node.output] = node.algebra.tensor_shape(
                    node.algebra.output)
            else:
                if node.inputs[0] not in shapes:
                    raise ValueError(
                        f"cannot infer shape of edge {node.inputs[0]!r} "
                        f"feeding epilogue node {node.name!r}")
                shapes[node.output] = shapes[node.inputs[0]]
        return shapes

    def edge_shape(self, edge: str) -> Tuple[int, ...]:
        try:
            return self._shapes[edge]
        except KeyError:
            raise KeyError(f"edge {edge!r} has no inferred shape "
                           f"(unused graph input?)") from None

    # -- oracle -----------------------------------------------------------
    def reference(self, operands: Mapping[str, np.ndarray]) -> np.ndarray:
        """Evaluate the graph with the numpy loop-nest oracle + numpy
        epilogue mirror — the semantics every execution must match."""
        values: Dict[str, np.ndarray] = {
            e: np.asarray(operands[e]) for e in self.inputs}
        for node in self._topo:
            if node.algebra is not None:
                ins = dict(zip((t.name for t in node.algebra.inputs),
                               (values[e] for e in node.inputs)))
                values[node.output] = node.algebra.reference(ins)
            elif node.op == "add":
                values[node.output] = (
                    np.asarray(values[node.inputs[0]], np.float64)
                    + np.asarray(values[node.inputs[1]], np.float64))
            else:
                bias = (values[node.inputs[1]] if len(node.inputs) == 2
                    else None)
                values[node.output] = epilogue_mod.apply_epilogue_np(
                    values[node.inputs[0]], (node.op,), bias=bias)
        return values[self.output]

    def random_operands(self, seed: int = 0) -> Dict[str, np.ndarray]:
        """Random integer operands for every graph input (same
        convention as ``TensorAlgebra.random_operands``)."""
        rng = np.random.default_rng(seed)
        return {e: rng.integers(-4, 5, size=self.edge_shape(e)
                                ).astype(np.int64)
                for e in self.inputs}

    def describe(self) -> str:
        lines = [f"AlgebraGraph(inputs={list(self.inputs)}, "
                 f"output={self.output!r})"]
        for n in self._topo:
            what = n.algebra.name if n.algebra is not None else n.op
            lines.append(f"  {n.name}: {what}({', '.join(n.inputs)}) "
                         f"-> {n.output} {self.edge_shape(n.output)}")
        return "\n".join(lines)
