"""Whole-model graph export — the dense family's per-layer forward as an
:class:`~repro.graph.ir.AlgebraGraph`.

:func:`transformer_layer_graph` emits the simplified single-head layer that
:func:`repro.models.transformer.dense_layer_forward` computes, in the
paper's ``(out, in)`` weight storage:

    q  = x @ wq.T                  k  = x @ wk.T
    vt = wv_t @ x.T                            (values born transposed)
    p  = softmax(q @ k.T / sqrt(d))
    a  = p @ vt.T                              (vt lands on attend's rhs)
    r1 = a @ wo.T + x                          (residual folds into oproj)
    h  = gelu(r1 @ w1.T + b1)
    out = h @ w2.T + r1                        (standalone add; r1 tapped)

Under :func:`repro.graph.planner.plan_graph` the eight algebra nodes merge
into ONE dag-kind group spanning attention and the MLP: the ``k`` and
``vt`` edges fuse on consumer rhs sides (zero materialised transposes),
``res1`` folds into ``oproj`` as a streamed residual, and ``r1`` — read by
both the MLP up-projection (in-group) and the final residual add
(out-of-group) — is exported as a tap, so the closing ``add`` reads it
from HBM without re-running attention.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

from ..configs.base import ModelConfig
from ..core.algebra import get_algebra
from .ir import AlgebraGraph, GraphNode

LAYER_INPUTS = ("x", "wq", "wk", "wv_t", "wo", "w1", "b1", "w2")


def transformer_layer_graph(l: int = 64, d: int = 64,
                            dv: Optional[int] = None,
                            f: Optional[int] = None) -> AlgebraGraph:
    """One dense-family layer (seq ``l``, model dim ``d``, value dim
    ``dv``, hidden ``f``) as an algebra graph with residual taps."""
    dv = d if dv is None else dv
    f = 2 * d if f is None else f
    scale = f"scale:{1.0 / math.sqrt(d)}"
    nodes = (
        GraphNode(name="qp", inputs=("x", "wq"), output="q",
                  algebra=get_algebra("gemm", m=l, n=d, k=d)),
        GraphNode(name="kp", inputs=("x", "wk"), output="k",
                  algebra=get_algebra("gemm", m=l, n=d, k=d)),
        GraphNode(name="vtp", inputs=("wv_t", "x"), output="vt",
                  algebra=get_algebra("gemm", m=dv, n=l, k=d)),
        GraphNode(name="scores", inputs=("q", "k"), output="s_raw",
                  algebra=get_algebra("gemm", m=l, n=l, k=d)),
        GraphNode(name="scale", inputs=("s_raw",), output="s_scaled",
                  op=scale),
        GraphNode(name="softmax", inputs=("s_scaled",), output="p",
                  op="softmax"),
        GraphNode(name="attend", inputs=("p", "vt"), output="a",
                  algebra=get_algebra("gemm", m=l, n=dv, k=l)),
        GraphNode(name="oproj", inputs=("a", "wo"), output="o",
                  algebra=get_algebra("gemm", m=l, n=d, k=dv)),
        GraphNode(name="res1", inputs=("o", "x"), output="r1", op="add"),
        GraphNode(name="up", inputs=("r1", "w1"), output="h_raw",
                  algebra=get_algebra("gemm", m=l, n=f, k=d)),
        GraphNode(name="bias1", inputs=("h_raw", "b1"), output="h_biased",
                  op="bias"),
        GraphNode(name="act", inputs=("h_biased",), output="h", op="gelu"),
        GraphNode(name="down", inputs=("h", "w2"), output="y",
                  algebra=get_algebra("gemm", m=l, n=d, k=f)),
        GraphNode(name="res2", inputs=("y", "r1"), output="out", op="add"),
    )
    return AlgebraGraph(nodes=nodes, inputs=LAYER_INPUTS, output="out")


def layer_graph_from_config(cfg: ModelConfig,
                            l: int = 64) -> AlgebraGraph:
    """Export one layer of a dense-family :class:`ModelConfig` (its
    ``d_model``/``d_ff``) at sequence length ``l``."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"only the dense family is graph-exportable, got {cfg.family!r}")
    return transformer_layer_graph(l=l, d=cfg.d_model, dv=cfg.d_model,
                                   f=cfg.d_ff)


def layer_oracle(operands: Dict[str, "object"], dtype: str = "float32"):
    """Run :func:`repro.models.transformer.dense_layer_forward` on a
    graph-operand dict (edge name -> array), for bit-parity checks."""
    from ..models.transformer import dense_layer_forward

    return dense_layer_forward(*(operands[e] for e in LAYER_INPUTS),
                               dtype=dtype)
