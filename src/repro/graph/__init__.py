"""repro.graph — whole-graph accelerator generation.

Lifts the front door from one :class:`~repro.core.algebra.TensorAlgebra`
to a DAG of them (attention = gemm·softmax·gemm, MLP = gemm·gelu·gemm):

* :mod:`repro.graph.ir`       — the :class:`AlgebraGraph` IR (nodes are
  tensor algebras or elementwise epilogues, edges are tensors),
* :mod:`repro.graph.planner`  — per-node dataflow selection with
  inter-node tile/partition agreement + epilogue folding,
* :mod:`repro.graph.executor` — the fused :class:`GraphAccelerator`
  ``repro.generate(graph)`` returns.
"""
from .executor import GraphAccelerator
from .ir import AlgebraGraph, GraphNode
from .planner import FusedGroupPlan, GraphPlan, plan_graph

__all__ = ["AlgebraGraph", "GraphNode", "GraphAccelerator",
           "FusedGroupPlan", "GraphPlan", "plan_graph"]
