"""Fault-tolerant runtime."""
from . import driver
from .driver import RunConfig, SimulatedFailure, TrainDriver, run_with_restarts
