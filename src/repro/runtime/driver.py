"""Fault-tolerant training runtime.

Production posture for thousands of nodes:
  * periodic async checkpoints (atomic publish; restart-safe data pipeline),
  * crash/preemption recovery: ``run_with_restarts`` resumes from the latest
    checkpoint — tested by injecting failures mid-run,
  * straggler watchdog: per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are recorded (on a real cluster this signal
    feeds the re-mesh/evict controller; here it is surfaced in metrics and
    tested with a simulated slow step),
  * elastic re-mesh: checkpoints are logical, so a restart may build a
    different mesh and reshard on restore (checkpoint/store.restore).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import store
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, SyntheticPipeline, frontend_stub
from ..optim import adamw
from ..train import trainer


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / preemption in tests."""


@dataclasses.dataclass
class RunConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    ema_alpha: float = 0.3
    keep_ckpts: int = 3


class TrainDriver:
    """Single-process driver (multi-host launch wires one per host)."""

    def __init__(self, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                 data_cfg: DataConfig, run_cfg: RunConfig,
                 mesh=None, rules=None,
                 failure_at: Optional[int] = None,
                 slow_step_at: Optional[int] = None):
        self.cfg, self.opt_cfg = cfg, opt_cfg
        self.data_cfg, self.run_cfg = data_cfg, run_cfg
        self.mesh = mesh
        self.failure_at = failure_at
        self.slow_step_at = slow_step_at
        self.ckpt = store.AsyncCheckpointer(run_cfg.ckpt_dir,
                                            keep=run_cfg.keep_ckpts)
        self.stragglers: List[int] = []
        self.metrics_log: List[Dict] = []

        key = jax.random.PRNGKey(data_cfg.seed)
        self.state, self.axes = trainer.init_state(key, cfg, opt_cfg)
        if mesh is not None:
            self.step_fn, self.state_sh, _ = trainer.make_sharded_train_step(
                cfg, opt_cfg, mesh, self.state, self.axes,
                rules or __import__(
                    "repro.models.common", fromlist=["DEFAULT_RULES"]
                ).DEFAULT_RULES, donate=False)
            self.state = jax.device_put(self.state, self.state_sh)
        else:
            self.step_fn = jax.jit(trainer.make_train_step(cfg, opt_cfg))
        self.pipeline = SyntheticPipeline(data_cfg)
        self.start_step = 0
        self._maybe_restore()

    # ------------------------------------------------------------------
    def _maybe_restore(self) -> None:
        latest = store.latest_step(self.run_cfg.ckpt_dir)
        if latest is None:
            return
        shardings = getattr(self, "state_sh", None)
        self.state, step, extra = store.restore(
            self.run_cfg.ckpt_dir, self.state, shardings=shardings)
        self.start_step = step
        self.pipeline.restore(extra.get("data", {"step": step}))

    def _checkpoint(self, step: int) -> None:
        self.ckpt.save_async(step, self.state,
                             extra={"data": self.pipeline.state()})

    # ------------------------------------------------------------------
    def _device_batch(self, np_batch: Dict[str, np.ndarray]) -> Dict:
        batch = {k: jax.numpy.asarray(v) for k, v in np_batch.items()}
        if self.cfg.family in ("encdec", "vlm"):
            batch["frontend"] = jax.numpy.asarray(frontend_stub(
                np_batch["tokens"].shape[0], self.cfg.frontend_tokens,
                self.cfg.d_model, step=0, seed=self.data_cfg.seed))
        return batch

    def run(self) -> Dict[str, Any]:
        ema = None
        step = self.start_step
        while step < self.run_cfg.total_steps:
            if self.failure_at is not None and step == self.failure_at:
                self.failure_at = None   # fail exactly once
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self._device_batch(self.pipeline.next())
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.slow_step_at is not None and step == self.slow_step_at:
                time.sleep(max(0.2, 4 * (ema or 0.05)))   # simulated straggler
                dt = time.perf_counter() - t0
            # straggler watchdog
            if ema is not None and dt > self.run_cfg.straggler_factor * ema:
                self.stragglers.append(step)
            ema = dt if ema is None else (
                self.run_cfg.ema_alpha * dt
                + (1 - self.run_cfg.ema_alpha) * ema)
            step += 1
            if step % self.run_cfg.ckpt_every == 0:
                self._checkpoint(step)
            if step % self.run_cfg.log_every == 0 or step == 1:
                self.metrics_log.append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
        self.ckpt.wait()
        self._checkpoint_final(step)
        return {"final_step": step, "metrics": self.metrics_log,
                "stragglers": self.stragglers}

    def _checkpoint_final(self, step: int) -> None:
        store.save(self.run_cfg.ckpt_dir, step, jax.tree.map(
            np.asarray, self.state),
            extra={"data": self.pipeline.state()})


def run_with_restarts(make_driver: Callable[[], TrainDriver],
                      max_restarts: int = 3) -> Dict[str, Any]:
    """Cluster-controller stand-in: restart the driver (which restores from
    the latest checkpoint) whenever a node failure surfaces."""
    restarts = 0
    while True:
        driver = make_driver()
        try:
            out = driver.run()
            out["restarts"] = restarts
            return out
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
