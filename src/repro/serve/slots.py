"""Continuous-batching slot engine: a fixed-capacity decode batch.

The decode batch has ``capacity`` slots.  Each slot holds one in-flight
sequence: its last sampled token, its absolute position, and its share of
the paged KV/SSM cache (``pages.py``).  The jitted decode step is keyed
on **capacity, never occupancy** — insert (a freshly prefilled request
lands in a free slot) and evict (a finished sequence frees its pages)
mutate host-side state and tiny device inputs only, so the batch never
drains and the step never recompiles (asserted via
:attr:`SlotEngine.decode_compiles`).

Prefill/decode split: prefill runs per request at its exact prompt
length (jit cached per length — bounded, bucket your workload), decode
runs the whole slot batch every step.  Per-slot positions ride the
``(B,)``-vector ``cache["pos"]`` support in ``models/decode.py``, so
sequences of different lengths coexist in one step.

Every step returns a :class:`ResultTokens`: tokens + validity + lengths
packed into **one** array — one device→host copy per step is much
faster than three (the JetStream observation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import decode as dec
from .engine import ServeConfig
from .pages import PagedKVCache, _flatten_cache, _nest


@dataclasses.dataclass(frozen=True)
class ResultTokens:
    """One decode step's results, packed into a single (capacity, 3)
    int32 array so only one device→host copy happens per step.

    Column ranges (JetStream-style index tuples):
    ``tokens_idx`` the sampled token, ``valid_idx`` whether the slot was
    live this step, ``length_idx`` the slot's absolute position after
    the step (prompt + generated so far).
    """

    data: np.ndarray
    tokens_idx: Tuple[int, int] = (0, 1)
    valid_idx: Tuple[int, int] = (1, 2)
    length_idx: Tuple[int, int] = (2, 3)

    def token_at(self, slot: int) -> int:
        return int(self.data[slot, self.tokens_idx[0]])

    def valid_at(self, slot: int) -> bool:
        return bool(self.data[slot, self.valid_idx[0]])

    def length_at(self, slot: int) -> int:
        return int(self.data[slot, self.length_idx[0]])


class SlotEngine:
    """Fixed-capacity continuous-batching decode engine over a paged
    cache.  Thread-compatible (one caller drives step/insert/evict; the
    async server in ``server.py`` is that caller)."""

    def __init__(self, params, cfg: ModelConfig, *, capacity: int = 8,
                 max_context: int = 256, page_size: int = 16,
                 total_pages: Optional[int] = None,
                 serve_cfg: Optional[ServeConfig] = None):
        self.params = params
        self.cfg = cfg
        self.capacity = int(capacity)
        self.max_context = int(max_context)
        self.serve_cfg = serve_cfg or ServeConfig()

        fe = None
        if cfg.family in ("encdec", "vlm"):
            fe = jax.ShapeDtypeStruct(
                (self.capacity, cfg.frontend_tokens, cfg.d_model),
                jnp.float32)
        # template prompt length: attention leaves are length-independent
        # (``_fit_cache`` pads/rolls to max_len) but the SSM conv window is
        # (B, min(s0, conv_kernel - 1), cd) — a full-length prompt yields
        # the steady-state shape every real insert must match.
        _, template = jax.eval_shape(
            functools.partial(dec.prefill, cfg=cfg, max_len=self.max_context),
            params,
            jax.ShapeDtypeStruct((self.capacity, self.max_context), jnp.int32),
            frontend=fe)
        self.cache = PagedKVCache(template, capacity=self.capacity,
                                  page_size=page_size,
                                  total_pages=total_pages)

        self._prefill = jax.jit(functools.partial(dec.prefill, cfg=cfg),
                                static_argnames=("max_len",))
        self._step_fn = jax.jit(self._build_step())
        self._base_key = jax.random.PRNGKey(self.serve_cfg.seed)
        self._step_count = 0
        self._prefill_count = 0

        c = self.capacity
        self._tokens = np.zeros((c, 1), np.int32)
        self._pos = np.zeros((c,), np.int32)
        self._active = np.zeros((c,), bool)
        #: device twin of (tokens, pos, active, table).  The jitted step
        #: carries tokens/pos forward on device, so steady-state decode
        #: does ZERO host->device transfers — the twin re-syncs from the
        #: host mirrors only after insert/evict touched them.
        self._dev: Optional[Tuple] = None

    # -- introspection ----------------------------------------------------
    @property
    def decode_compiles(self) -> int:
        """Jit cache entries of the decode step — stays 1 across any
        sequence of insert/evict (the continuous-batching contract)."""
        return self._step_fn._cache_size()

    @property
    def prefill_compiles(self) -> int:
        return self._prefill._cache_size()

    def free_slots(self) -> Tuple[int, ...]:
        return tuple(int(i) for i in np.flatnonzero(~self._active))

    def live_slots(self) -> Tuple[int, ...]:
        return tuple(int(i) for i in np.flatnonzero(self._active))

    @property
    def occupancy(self) -> float:
        return float(self._active.mean())

    def position(self, slot: int) -> int:
        return int(self._pos[slot])

    # -- the jitted step ---------------------------------------------------
    def _build_step(self):
        cfg, lay = self.cfg, self.cache.layout
        scfg = self.serve_cfg

        def sample(logits: jax.Array, key) -> jax.Array:
            if scfg.temperature <= 0.0:
                return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            scaled = logits / scfg.temperature
            return (jax.random.categorical(key, scaled, axis=-1)[:, None]
                .astype(jnp.int32))

        def step(params, tokens, pos, active, table, pools, lanes, key):
            views = lay.gather_views(pools, table)
            cache: Dict[str, Any] = _nest({**views, **lanes})
            cache["pos"] = pos
            logits, new_cache = dec.decode_step(params, tokens, cache, cfg)
            flat_new = _flatten_cache(new_cache)
            pools2 = lay.scatter_written(
                pools, table, {p: flat_new[p] for p, _ in lay.paged},
                pos, active)
            lanes2 = lay.freeze_inactive(
                lanes, {p: flat_new[p] for p in lanes}, active)
            tok = sample(logits, key)
            new_pos = jnp.where(active, pos + 1, pos)
            new_tokens = jnp.where(active[:, None], tok, tokens)
            packed = jnp.concatenate(
                [tok, active[:, None].astype(jnp.int32),
                 new_pos[:, None]], axis=1)
            return packed, (new_tokens, new_pos), pools2, lanes2

        return step

    # -- slot lifecycle ----------------------------------------------------
    def insert(self, prompt: np.ndarray, *, max_new_tokens: int,
               frontend: Optional[np.ndarray] = None
               ) -> Optional[Tuple[int, int]]:
        """Prefill one request and land it in a free slot.

        ``prompt``: (s0,) int32.  Returns ``(slot, first_token)`` — the
        first token is sampled from the prefill logits, exactly like
        ``DecodeEngine.generate`` — or None when no slot or not enough
        free pages (the caller keeps the request queued).
        """
        s0 = int(prompt.shape[-1])
        if s0 + max_new_tokens > self.max_context:
            raise ValueError(
                f"prompt ({s0}) + max_new_tokens ({max_new_tokens}) exceeds "
                f"max_context ({self.max_context})")
        if (self.cfg.family in ("ssm", "hybrid")
                and s0 < self.cfg.conv_kernel - 1):
            # model-level floor (the sequential path shares it): the SSM
            # decode recurrence needs a full conv window from prefill
            raise ValueError(
                f"prompt ({s0}) shorter than the SSM conv window "
                f"({self.cfg.conv_kernel - 1})")
        free = self.free_slots()
        if not free:
            return None
        slot = free[0]
        if not self.cache.alloc(slot, s0 + max_new_tokens):
            return None
        fe = None if frontend is None else jnp.asarray(frontend)
        logits, cache_p = self._prefill(
            self.params, jnp.asarray(prompt, jnp.int32)[None],
            frontend=fe, max_len=self.max_context)
        self._prefill_count += 1
        if self.serve_cfg.temperature <= 0.0:
            tok = int(jnp.argmax(logits, axis=-1)[0])
        else:
            key = jax.random.fold_in(self._base_key, self._prefill_count)
            tok = int(jax.random.categorical(
                key, logits / self.serve_cfg.temperature, axis=-1)[0])
        self.cache.insert(slot, cache_p)
        self._pos[slot] = s0
        self._tokens[slot, 0] = tok
        self._active[slot] = True
        self._dev = None
        return slot, tok

    def evict(self, slot: int) -> None:
        """Free a finished slot's pages; the decode batch keeps running
        for the other slots (no drain, no recompile)."""
        self.cache.free(slot)
        self._active[slot] = False
        self._pos[slot] = 0
        self._tokens[slot, 0] = 0
        self._dev = None

    # -- one decode step ---------------------------------------------------
    def step(self) -> ResultTokens:
        """Advance every live slot one token; packed device→host copy."""
        key = self._base_key
        if self.serve_cfg.temperature > 0.0:
            key = jax.random.fold_in(self._base_key, -1 - self._step_count)
        if self._dev is None:              # insert/evict since last step
            self._dev = (jnp.asarray(self._tokens), jnp.asarray(self._pos),
                         jnp.asarray(self._active),
                         self.cache.device_table())
        tokens, pos, active, table = self._dev
        packed, (tokens, pos), pools, lanes = self._step_fn(
            self.params, tokens, pos, active, table,
            self.cache.pools, self.cache.lanes, key)
        self._dev = (tokens, pos, active, table)
        self.cache.pools, self.cache.lanes = pools, lanes
        self._step_count += 1
        data = np.asarray(packed)          # the one device->host copy
        live = self._active
        self._tokens[live, 0] = data[live, 0]
        self._pos[live] += 1
        return ResultTokens(data)
