"""Batched decode engine: prefill + greedy/temperature decode loop.

Serving counterpart to the train driver: jit-compiled prefill and
decode_step (the same functions the decode dry-run cells lower), a batch of
independent sequences, and per-sequence EOS tracking — the minimal but real
engine the examples drive.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import decode as dec


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig,
                 serve_cfg: ServeConfig = ServeConfig()):
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self._prefill = jax.jit(functools.partial(dec.prefill, cfg=cfg),
                                static_argnames=("max_len",))
        self._step = jax.jit(functools.partial(dec.decode_step, cfg=cfg))

    def generate(self, prompts: np.ndarray, *,
                 frontend: Optional[np.ndarray] = None,
                 max_new_tokens: Optional[int] = None,
                 ) -> Tuple[np.ndarray, Dict]:
        """prompts: (B, S0) int32.  Returns (generated (B, T), stats)."""
        scfg = self.serve_cfg
        t_new = max_new_tokens or scfg.max_new_tokens
        b, s0 = prompts.shape
        max_len = s0 + t_new
        logits, cache = self._prefill(
            self.params, jnp.asarray(prompts),
            frontend=None if frontend is None else jnp.asarray(frontend),
            max_len=max_len)
        key = jax.random.PRNGKey(scfg.seed)
        out = []
        done = np.zeros((b,), bool)
        tok = self._sample(logits, key)
        for t in range(t_new):
            out.append(np.asarray(tok))
            if scfg.eos_id is not None:
                done |= out[-1][:, 0] == scfg.eos_id
                if done.all():
                    break
            logits, cache = self._step(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        gen = np.concatenate(out, axis=1)
        return gen, {"prefill_len": s0, "generated": gen.shape[1]}

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.serve_cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        scaled = logits / self.serve_cfg.temperature
        return jax.random.categorical(key, scaled, axis=-1)[:, None].astype(
            jnp.int32)
