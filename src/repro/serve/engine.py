"""Serving engines: batched LM decode + tensor-algebra accelerators.

``DecodeEngine`` is the serving counterpart to the train driver:
jit-compiled prefill and decode_step, a batch of independent sequences,
and per-sequence EOS tracking.

``AcceleratorEngine`` serves the STT side of the repo through the front
door: requests name a registry algebra (plus optional bounds / dataflow)
and the engine answers with the generated accelerator's output.  Repeat
shapes are free — ``repro.generate`` rides the bounded, thread-safe
compile cache — and a mesh-bound engine executes every request through
the CommPlan interpreter.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import decode as dec


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig,
                 serve_cfg: Optional[ServeConfig] = None):
        # NOTE: the default must be None + construct-per-instance.  A
        # ``serve_cfg: ServeConfig = ServeConfig()`` default evaluates ONE
        # shared instance at import time — mutating one engine's config
        # would silently reconfigure every other engine (regression-tested
        # in tests/test_serve_engine.py).
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg if serve_cfg is not None else ServeConfig()
        self._prefill = jax.jit(functools.partial(dec.prefill, cfg=cfg),
                                static_argnames=("max_len",))
        self._step = jax.jit(functools.partial(dec.decode_step, cfg=cfg))

    def generate(self, prompts: np.ndarray, *,
                 frontend: Optional[np.ndarray] = None,
                 max_new_tokens: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 ) -> Tuple[np.ndarray, Dict]:
        """prompts: (B, S0) int32.  Returns (generated (B, T), stats).

        ``cache_len`` overrides the decode cache's context budget (default
        ``S0 + max_new_tokens``).  The continuous-batching slot engine
        gathers fixed-length page views, so its sequential parity oracle
        is this method with ``cache_len`` pinned to the engine's
        ``max_context`` — same cache shape, bit-identical math."""
        scfg = self.serve_cfg
        t_new = max_new_tokens or scfg.max_new_tokens
        b, s0 = prompts.shape
        max_len = cache_len or (s0 + t_new)
        if max_len < s0 + t_new:
            raise ValueError(f"cache_len {max_len} < prompt {s0} + "
                             f"new tokens {t_new}")
        logits, cache = self._prefill(
            self.params, jnp.asarray(prompts),
            frontend=None if frontend is None else jnp.asarray(frontend),
            max_len=max_len)
        key = jax.random.PRNGKey(scfg.seed)
        out = []
        done = np.zeros((b,), bool)
        tok = self._sample(logits, key)
        for t in range(t_new):
            out.append(np.asarray(tok))
            if scfg.eos_id is not None:
                done |= out[-1][:, 0] == scfg.eos_id
                if done.all():
                    break
            logits, cache = self._step(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        gen = np.concatenate(out, axis=1)
        return gen, {"prefill_len": s0, "generated": gen.shape[1]}

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.serve_cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        scaled = logits / self.serve_cfg.temperature
        return jax.random.categorical(key, scaled, axis=-1)[:, None].astype(
            jnp.int32)


class AcceleratorEngine:
    """Serve generated tensor-algebra accelerators (the front door, as a
    service).

    ``submit("gemm", {"A": a, "B": b})`` generates (or cache-hits) the
    accelerator for the request's algebra/bounds/dataflow and executes
    it; with ``mesh=`` every request runs multi-chip through the CommPlan
    interpreter.  Request threads are safe: generation goes through the
    locked compile cache and the per-engine stats lock is local.
    """

    def __init__(self, mesh=None, dtype=jnp.float32,
                 interpret: Optional[bool] = None):
        self.mesh = mesh
        self.dtype = dtype
        self.interpret = interpret
        self._lock = threading.Lock()
        #: request signature -> Accelerator.  The compile cache already
        #: dedupes CompiledKernels, but a mesh-bound Accelerator also
        #: carries the compiled MeshProgram (shard_map trace) — reusing
        #: the handle is what makes repeat shapes free multi-chip too.
        self._accs: Dict = {}
        self._stats = {"requests": 0, "algebras": set(), "partitions": {}}

    def _accelerator(self, algebra: str, dataflow, bounds):
        # algebra (str or frozen TensorAlgebra) and dataflow (None, str or
        # frozen Dataflow) are both hashable as-is
        key = (algebra, dataflow, tuple(sorted((bounds or {}).items())))
        with self._lock:
            acc = self._accs.get(key)
        if acc is None:
            from .. import api
            acc = api.generate(algebra, dataflow, bounds=bounds,
                               mesh=self.mesh, dtype=self.dtype,
                               interpret=self.interpret, validate=False)
            with self._lock:
                acc = self._accs.setdefault(key, acc)
        return acc

    def submit(self, algebra: str, operands: Dict[str, jax.Array], *,
               dataflow=None, bounds: Optional[Dict[str, int]] = None
               ) -> jax.Array:
        acc = self._accelerator(algebra, dataflow, bounds)
        out = acc(operands)
        with self._lock:
            self._stats["requests"] += 1
            self._stats["algebras"].add(acc.algebra.name)
            if acc.mesh is not None:
                # the solved partition this request executed (the CI /
                # ops-facing proof no algebra silently replicates)
                sol = acc.partition
                self._stats["partitions"][acc.algebra.name] = {
                    "strategy": sol.strategy,
                    "batch_axis": sol.batch_axis,
                    "replicated_inputs": sol.replicated_inputs()}
        return out

    def describe(self, algebra: str, *, dataflow=None,
                 bounds: Optional[Dict[str, int]] = None) -> str:
        """The served accelerator's ``describe()`` — per-tensor partition
        and comm bytes included when the engine is mesh-bound."""
        return self._accelerator(algebra, dataflow, bounds).describe()

    def stats(self) -> Dict:
        from ..compile import cache_info
        with self._lock:
            return {"requests": self._stats["requests"],
                    "algebras": sorted(self._stats["algebras"]),
                    "partitions": dict(self._stats["partitions"]),
                    "compile_cache": cache_info()}
