"""BENCH_serve.json: the machine-readable serving-load report.

``benchmarks/serve_load.py`` emits one document at the repo root after
each open-loop run: the workload it generated, continuous-batching vs
static-batch results, and the throughput speedup.  CI's serve smoke step
re-validates the document with :func:`validate_serve` and fails when the
schema drifts — a contract, not a printf (same stance as
``tune/report.py``'s BENCH_tune.json).

Schema (version 1)::

    {
      "version": 1,
      "smoke": bool,
      "arch": str,                  # registry arch the load ran against
      "capacity": int,              # slot-engine decode batch capacity
      "page_size": int,
      "max_context": int,
      "workload": {
        "requests": int,
        "arrival": str,             # "poisson" | "burst"
        "rate_rps": float,          # Poisson arrival rate (0 for burst)
        "prompt_lens": [int, ...],  # the mixed-length buckets used
        "output_lens": [int, ...]
      },
      "continuous": {
        "throughput_tok_s": float,
        "p50_latency_s": float,
        "p99_latency_s": float,
        "mean_occupancy": float,    # mean live-slot fraction per step
        "steps": int,
        "decode_compiles": int      # must stay 1 across insert/evict
      },
      "static": {
        "throughput_tok_s": float,
        "p50_latency_s": float,
        "p99_latency_s": float
      },
      "speedup": float,             # continuous / static throughput
      "parity_checked": bool        # per-request tokens == sequential
    }
"""
from __future__ import annotations

from typing import Any, Dict, List

SERVE_SCHEMA_VERSION = 1

_NUM = (int, float)

_CONTINUOUS_REQUIRED = {
    "throughput_tok_s": _NUM, "p50_latency_s": _NUM, "p99_latency_s": _NUM,
    "mean_occupancy": _NUM, "steps": int, "decode_compiles": int,
}
_STATIC_REQUIRED = {
    "throughput_tok_s": _NUM, "p50_latency_s": _NUM, "p99_latency_s": _NUM,
}


def _check_fields(errors: List[str], where: str, obj: Any,
                  required: Dict[str, Any]) -> None:
    if not isinstance(obj, dict):
        errors.append(f"{where} missing or not an object")
        return
    for name, typ in required.items():
        v = obj.get(name)
        if v is None or not isinstance(v, typ) or isinstance(v, bool):
            errors.append(f"{where}.{name} missing or wrong type")


def validate_serve(doc: Any) -> List[str]:
    """Validate a BENCH_serve.json document; returns a list of problems
    (empty = valid).  Hand-rolled on purpose: no jsonschema dependency,
    and the error strings name the exact offending path."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["document root is not an object"]
    if doc.get("version") != SERVE_SCHEMA_VERSION:
        errors.append(f"version is {doc.get('version')!r}, "
                      f"expected {SERVE_SCHEMA_VERSION}")
    for field in ("smoke", "parity_checked"):
        if not isinstance(doc.get(field), bool):
            errors.append(f"{field} missing or not a bool")
    if not isinstance(doc.get("arch"), str):
        errors.append("arch missing or not a string")
    for field in ("capacity", "page_size", "max_context"):
        v = doc.get(field)
        if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
            errors.append(f"{field} missing or not a positive int")
    wl = doc.get("workload")
    if not isinstance(wl, dict):
        errors.append("workload missing or not an object")
    else:
        n = wl.get("requests")
        if not isinstance(n, int) or isinstance(n, bool) or n <= 0:
            errors.append("workload.requests missing or not a positive int")
        if wl.get("arrival") not in ("poisson", "burst"):
            errors.append("workload.arrival must be 'poisson' or 'burst'")
        rate = wl.get("rate_rps")
        if not isinstance(rate, _NUM) or isinstance(rate, bool) or rate < 0:
            errors.append("workload.rate_rps missing or negative")
        for field in ("prompt_lens", "output_lens"):
            lens = wl.get(field)
            if not (isinstance(lens, list) and lens and all(
                    isinstance(x, int) and not isinstance(x, bool) and x > 0
                    for x in lens)):
                errors.append(f"workload.{field} must be a non-empty list "
                              f"of positive ints")
    _check_fields(errors, "continuous", doc.get("continuous"),
                  _CONTINUOUS_REQUIRED)
    _check_fields(errors, "static", doc.get("static"), _STATIC_REQUIRED)
    cont = doc.get("continuous")
    if isinstance(cont, dict):
        occ = cont.get("mean_occupancy")
        if (isinstance(occ, _NUM) and not isinstance(occ, bool)
                and not (0.0 <= occ <= 1.0)):
            errors.append("continuous.mean_occupancy must be in [0, 1]")
        dc = cont.get("decode_compiles")
        if isinstance(dc, int) and not isinstance(dc, bool) and dc != 1:
            errors.append(f"continuous.decode_compiles is {dc}; continuous "
                          f"batching must not recompile (expected 1)")
    sp = doc.get("speedup")
    if not isinstance(sp, _NUM) or isinstance(sp, bool) or sp <= 0:
        errors.append("speedup missing or not positive")
    return errors


def serve_entry(*, smoke: bool, arch: str, capacity: int, page_size: int,
                max_context: int, workload: Dict[str, Any],
                continuous: Dict[str, Any], static: Dict[str, Any],
                parity_checked: bool) -> Dict[str, Any]:
    """Build one schema-conformant document (keeps the benchmark and the
    validator in one module, so they cannot drift apart)."""
    doc = {
        "version": SERVE_SCHEMA_VERSION,
        "smoke": bool(smoke),
        "arch": str(arch),
        "capacity": int(capacity),
        "page_size": int(page_size),
        "max_context": int(max_context),
        "workload": {
            "requests": int(workload["requests"]),
            "arrival": str(workload["arrival"]),
            "rate_rps": float(workload["rate_rps"]),
            "prompt_lens": [int(x) for x in workload["prompt_lens"]],
            "output_lens": [int(x) for x in workload["output_lens"]],
        },
        "continuous": {
            "throughput_tok_s": float(continuous["throughput_tok_s"]),
            "p50_latency_s": float(continuous["p50_latency_s"]),
            "p99_latency_s": float(continuous["p99_latency_s"]),
            "mean_occupancy": float(continuous["mean_occupancy"]),
            "steps": int(continuous["steps"]),
            "decode_compiles": int(continuous["decode_compiles"]),
        },
        "static": {
            "throughput_tok_s": float(static["throughput_tok_s"]),
            "p50_latency_s": float(static["p50_latency_s"]),
            "p99_latency_s": float(static["p99_latency_s"]),
        },
        "parity_checked": bool(parity_checked),
    }
    st = doc["static"]["throughput_tok_s"]
    doc["speedup"] = ((doc["continuous"]["throughput_tok_s"] / st) if st
        else 1.0)
    return doc
