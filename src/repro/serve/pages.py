"""Paged decode cache: fixed-size pages + slot→page-table indirection.

The per-call decode cache (``models/decode.py``) allocates one dense
``(L, B, S, kv)`` block per batch.  For a serving slot engine that is the
wrong shape twice over: every slot pays for the longest context whether
it uses it or not, and insert/evict would reallocate the batch.  This
module restructures the sequence-axis caches into **pages**:

* one shared pool per K/V leaf, ``(total_pages + 1, page, L * kv)`` — a
  page holds ``page_size`` token positions across *all* layers, and the
  last physical page is a scratch page that absorbs writes from inactive
  slots and backs unmapped table entries;
* a host-managed page table ``(capacity, pages_per_slot)`` with a free
  list — long and short sequences draw from the same pool, so a slot
  only reserves ``ceil((prompt + max_new) / page)`` pages;
* gather/scatter through the same index-map machinery the Pallas kernels
  use (``kernels/paged.py``: scalar-prefetched page table feeding
  BlockSpec index maps, with a bit-identical jnp twin for CPU).

Cache leaves without a sequence axis (SSM conv/state, static cross K/V)
are **lane pools**: the slot index is their batch axis directly.

Bit-exactness contract: gathering a slot's pages yields exactly the
dense cache the per-call path would hold (unmapped positions read the
scratch page, whose garbage is masked to an exact zero contribution by
the position-validity masks in ``_decode_attn``), so continuous decode
reproduces sequential decode token-for-token.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import paged as paged_kernels

#: decode-cache paths whose leaves carry a sequence axis (axis 2 of an
#: ``(Lx, B, S, kv)`` leaf) and are therefore paged; everything else
#: (minus "pos", which the slot engine owns) becomes a lane pool.
PAGED_PATHS = (("self", "k"), ("self", "v"), ("shared", "k"), ("shared", "v"))


def _flatten_cache(cache: Dict[str, Any]) -> Dict[Tuple[str, ...], Any]:
    flat = {}
    for k, v in cache.items():
        if k == "pos":
            continue
        if isinstance(v, dict):
            for k2, v2 in v.items():
                flat[(k, k2)] = v2
        else:
            flat[(k,)] = v
    return flat


def _nest(flat: Dict[Tuple[str, ...], Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for path, v in flat.items():
        d = out
        for k in path[:-1]:
            d = d.setdefault(k, {})
        d[path[-1]] = v
    return out


@dataclasses.dataclass(frozen=True)
class PageLayout:
    """Static geometry of one paged cache (hashable; closed over by the
    jitted decode step, so it must not hold arrays)."""

    capacity: int
    page_size: int
    pages_per_slot: int            # logical pages in every slot's view
    total_pages: int               # physical pages (excluding scratch)
    seq_len: int                   # gathered view length per slot
    #: paged leaves: path -> (stack, feat, dtype name); pool is
    #: (total_pages + 1, page, stack * feat)
    paged: Tuple[Tuple[Tuple[str, ...], Tuple[int, int, str]], ...]
    #: lane leaves: path -> (shape, dtype name); slot index is axis 1
    lanes: Tuple[Tuple[Tuple[str, ...], Tuple[Tuple[int, ...], str]], ...]

    @property
    def scratch_page(self) -> int:
        return self.total_pages

    # -- pure device-side ops (used inside the jitted decode step) -------
    def gather_views(self, pools: Dict[Tuple[str, ...], jax.Array],
                     table: jax.Array) -> Dict[Tuple[str, ...], jax.Array]:
        """pools + page table -> per-slot contiguous cache views
        ``(stack, capacity, seq_len, feat)`` (what decode_step expects)."""
        views = {}
        for path, (stack, feat, _) in self.paged:
            v = paged_kernels.paged_gather(pools[path], table)
            v = v.reshape(self.capacity, self.seq_len, stack, feat)
            views[path] = v.transpose(2, 0, 1, 3)
        return views

    def scatter_written(self, pools: Dict[Tuple[str, ...], jax.Array],
                        table: jax.Array, new_views: Dict[Tuple[str, ...],
                                                          jax.Array],
                        pos: jax.Array, active: jax.Array
                        ) -> Dict[Tuple[str, ...], jax.Array]:
        """Write back the single token position each slot just produced.

        ``new_views`` are decode_step's updated caches (the gathered view
        with one write at ``pos % seq_len`` per slot); only that position
        flows back to the pool — inactive slots are pointed at the
        scratch page so the write is an exact no-op for live data."""
        slot_pos = pos.astype(jnp.int32) % self.seq_len
        lpage = slot_pos // self.page_size
        off = slot_pos % self.page_size
        rows = jnp.arange(self.capacity)
        pid = table[rows, lpage]
        pid = jnp.where(active, pid, self.scratch_page)
        out = dict(pools)
        for path, (stack, feat, _) in self.paged:
            v = new_views[path]                      # (stack, C, S, feat)
            written = jnp.take_along_axis(
                v, slot_pos[None, :, None, None], axis=2)[:, :, 0]
            written = written.transpose(1, 0, 2).reshape(
                self.capacity, stack * feat)
            out[path] = paged_kernels.paged_scatter_token(
                pools[path], pid, off, written)
        return out

    def freeze_inactive(self, lanes: Dict[Tuple[str, ...], jax.Array],
                        new_lanes: Dict[Tuple[str, ...], jax.Array],
                        active: jax.Array) -> Dict[Tuple[str, ...],
                                                   jax.Array]:
        """Keep inactive slots' lane state (SSM conv/state, cross K/V)
        frozen: decode ran on garbage lanes for those slots and its
        updates must not stick."""
        out = {}
        for path, old in lanes.items():
            new = new_lanes.get(path, old)
            mask = active.reshape((1, self.capacity)
                                  + (1,) * (old.ndim - 2))
            out[path] = jnp.where(mask, new.astype(old.dtype), old)
        return out


class PagedKVCache:
    """Device pools + host page table / free list for one slot engine.

    Built from the *exact* leaf shapes and dtypes the real prefill path
    produces (``jax.eval_shape`` over ``models.decode.prefill``), so
    inserting a prefilled sequence is a pure copy — no casts, no parity
    drift.  Thread-safe: alloc/free/insert take the host lock.
    """

    def __init__(self, template_cache: Dict[str, Any], *, capacity: int,
                 page_size: int, total_pages: Optional[int] = None):
        flat = _flatten_cache(template_cache)
        paged_meta, lane_meta = [], []
        seq_len = None
        for path, leaf in sorted(flat.items()):
            if path in PAGED_PATHS:
                stack, b, s, feat = leaf.shape
                assert b == capacity, (path, leaf.shape, capacity)
                if seq_len is None:
                    seq_len = s
                assert s == seq_len, (
                    f"paged leaves disagree on seq len: {path} {s} != {seq_len}")
                paged_meta.append((path, (stack, feat,
                                          jnp.dtype(leaf.dtype).name)))
            else:
                assert leaf.shape[1] == capacity, (path, leaf.shape)
                lane_meta.append((path, (tuple(leaf.shape),
                                         jnp.dtype(leaf.dtype).name)))
        if seq_len is None:
            # pure-SSM family: no sequence-axis cache at all; keep a
            # 1-page geometry so the table/step machinery stays uniform
            seq_len = page_size
        if seq_len % page_size:
            raise ValueError(f"page_size {page_size} must divide the cache "
                             f"sequence length {seq_len}")
        pages_per_slot = seq_len // page_size
        if total_pages is None:
            total_pages = capacity * pages_per_slot
        self.layout = PageLayout(
            capacity=capacity, page_size=page_size,
            pages_per_slot=pages_per_slot, total_pages=total_pages,
            seq_len=seq_len, paged=tuple(paged_meta), lanes=tuple(lane_meta))
        lay = self.layout
        self.pools = {
            path: jnp.zeros((total_pages + 1, page_size, stack * feat), dt)
            for path, (stack, feat, dt) in lay.paged}
        self.lanes = {path: jnp.zeros(shape, dt)
                      for path, (shape, dt) in lay.lanes}
        self._lock = threading.Lock()
        self._free: List[int] = list(range(total_pages))
        self._slot_pages: Dict[int, List[int]] = {}
        self.table = np.full((capacity, pages_per_slot), lay.scratch_page,
                             np.int32)
        # one fused dispatch per insert (retraced per distinct page count,
        # bounded by pages_per_slot) — the unjitted per-leaf chain costs
        # milliseconds of dispatch on every admission otherwise
        self._insert_fn = jax.jit(self._build_insert())

    # -- host-side accounting --------------------------------------------
    def pages_needed(self, context_len: int) -> int:
        """Physical pages a request spanning ``context_len`` positions
        needs; a rolling (SWA) view cycles through every logical page."""
        lay = self.layout
        n = math.ceil(min(context_len, lay.seq_len) / lay.page_size)
        return lay.pages_per_slot if context_len > lay.seq_len else n

    def can_alloc(self, context_len: int) -> bool:
        with self._lock:
            return len(self._free) >= self.pages_needed(context_len)

    def alloc(self, slot: int, context_len: int) -> bool:
        """Reserve pages for one slot; False when the pool is exhausted
        (the scheduler keeps the request queued)."""
        n = self.pages_needed(context_len)
        with self._lock:
            if slot in self._slot_pages or len(self._free) < n:
                return False
            ids = [self._free.pop() for _ in range(n)]
            self._slot_pages[slot] = ids
            self.table[slot] = self.layout.scratch_page
            self.table[slot, :n] = ids
        return True

    def free(self, slot: int) -> None:
        with self._lock:
            ids = self._slot_pages.pop(slot, [])
            self._free.extend(ids)
            self.table[slot] = self.layout.scratch_page

    @property
    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    def occupancy(self) -> float:
        lay = self.layout
        with self._lock:
            return 1.0 - len(self._free) / max(lay.total_pages, 1)

    # -- insert (device) --------------------------------------------------
    def _build_insert(self):
        lay = self.layout

        def insert_fn(pools, lanes, flat, idx, slot):
            n = idx.shape[0]                        # static per trace
            out_pools = dict(pools)
            for path, (stack, feat, _) in lay.paged:
                leaf = flat[path]                   # (stack, 1, S, feat)
                rows = leaf[:, 0].transpose(1, 0, 2).reshape(
                    lay.pages_per_slot, lay.page_size, stack * feat)
                out_pools[path] = pools[path].at[idx].set(
                    rows[:n].astype(pools[path].dtype))
            out_lanes = dict(lanes)
            for path, _ in lay.lanes:
                out_lanes[path] = lanes[path].at[:, slot].set(
                    flat[path][:, 0].astype(lanes[path].dtype))
            return out_pools, out_lanes

        return insert_fn

    def insert(self, slot: int, cache: Dict[str, Any]) -> None:
        """Scatter one freshly-prefilled sequence (batch==1 cache pytree)
        into the slot's reserved pages + lane rows.  Pure copies, fused
        into one jitted dispatch; the jit cache is keyed on the page
        count (bounded by pages_per_slot), never on occupancy — the
        decode step's cache stays untouched."""
        flat = _flatten_cache(cache)
        with self._lock:
            ids = list(self._slot_pages.get(slot, ()))
        assert ids, f"slot {slot} has no pages allocated"
        idx = jnp.asarray(np.asarray(ids, np.int32))
        self.pools, self.lanes = self._insert_fn(
            self.pools, self.lanes, flat, idx, jnp.int32(slot))

    def device_table(self) -> jnp.ndarray:
        with self._lock:
            return jnp.asarray(self.table)


# ---------------------------------------------------------------------------
# mesh placement: pages through the partition solver
# ---------------------------------------------------------------------------

def solve_page_placement(cfg, layout: PageLayout,
                         axes: Tuple[str, str] = ("x", "y"),
                         shape: Tuple[int, int] = (2, 2)):
    """Solve the mesh partition for the decode-attention algebra and map
    it onto the page pools.

    Decode attention over a paged cache is a ``batched_gemv``:
    ``scores[b, s] = sum_d q[b, d] * K[b, s, d]`` with the slot x kv-head
    product as the batch dim.  The same front door that serves that
    algebra (``repro.generate``) yields the CommPlan whose
    ``plan.solve_partition`` decides which mesh axis shards the batch —
    and pages belong to slots, so the page axis of every pool shards over
    that axis.  Returns ``(PartitionSolution, PartitionSpec)``.
    """
    from jax.sharding import PartitionSpec as P

    from .. import api
    kv_heads = max(getattr(cfg, "n_kv_heads", 1), 1)
    acc = api.generate(
        "batched_gemv",
        bounds={"m": max(layout.capacity * kv_heads, 2),
                "k": max(getattr(cfg, "head_dim", 16), 2),
                "n": max(layout.seq_len, 2)},
        validate=False)
    sol = acc.kernel.partition_for(shape, axes)
    batch_axis = sol.batch_axis or sol.grid.get("m")
    if isinstance(batch_axis, tuple):
        batch_axis = batch_axis[0]
    spec = P(batch_axis, None, None)
    return sol, spec


def place_pools(cache: PagedKVCache, mesh, spec) -> None:
    """Shard every page pool over the mesh with the solved spec (page
    axis split over the batch-carrying mesh axis).  Divisibility caveat:
    the pool keeps its scratch page, so the page axis is padded up to a
    multiple of the axis size before placement."""
    from jax.sharding import NamedSharding

    axis = spec[0]
    n = (dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)
        if axis else 1)
    for path, pool in cache.pools.items():
        p = pool.shape[0]
        pad = (-p) % max(n, 1)
        if pad:
            pool = jnp.pad(pool, ((0, pad), (0, 0), (0, 0)))
        cache.pools[path] = jax.device_put(pool, NamedSharding(mesh, spec))
