"""Async dispatch loop: many concurrent requests onto one slot engine.

The server owns a :class:`~repro.serve.slots.SlotEngine` and runs a
single dispatch thread (the engine's jitted step is one device program;
parallelism comes from the batch, not from threads racing the device):

* ``submit()`` is thread-safe and returns a :class:`RequestFuture`
  immediately — any number of client threads can submit concurrently;
* the scheduler interleaves **prefill** of waiting requests with
  **decode** of resident slots: each loop iteration admits up to
  ``prefill_per_step`` queued requests into free slots (skipping
  admission when the page pool is exhausted), then advances every live
  slot one token;
* per-step results arrive as one packed :class:`ResultTokens` array
  (single device→host copy); finished sequences (EOS or length budget)
  are evicted without draining the batch, and their futures resolve.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue as queue_mod
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from .slots import SlotEngine


@dataclasses.dataclass
class Request:
    """One generation request (prompt -> up to max_new_tokens)."""

    prompt: np.ndarray                     # (s0,) int32
    max_new_tokens: int
    frontend: Optional[np.ndarray] = None  # encdec/vlm conditioning
    rid: int = -1
    submitted_at: float = 0.0


class RequestFuture:
    """Per-request future: blocks until the sequence finishes."""

    def __init__(self, request: Request):
        self.request = request
        self._done = threading.Event()
        self._tokens: List[int] = []
        self._error: Optional[BaseException] = None
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """The generated tokens (truncated at EOS when one is set)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.request.rid} not finished")
        if self._error is not None:
            raise self._error
        return np.asarray(self._tokens, np.int32)

    @property
    def latency_s(self) -> float:
        assert self.finished_at is not None
        return self.finished_at - self.request.submitted_at

    @property
    def ttft_s(self) -> float:
        assert self.first_token_at is not None
        return self.first_token_at - self.request.submitted_at

    # -- server side -------------------------------------------------------
    def _emit(self, token: int) -> None:
        if self.first_token_at is None:
            self.first_token_at = time.perf_counter()
        self._tokens.append(token)

    def _finish(self) -> None:
        self.finished_at = time.perf_counter()
        self._done.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self.finished_at = time.perf_counter()
        self._done.set()


class ContinuousServer:
    """Continuous-batching server over a :class:`SlotEngine`.

    Use as a context manager (starts/stops the dispatch thread), or call
    :meth:`start` / :meth:`shutdown` explicitly.  ``drain()`` blocks
    until everything submitted so far has finished.
    """

    def __init__(self, engine: SlotEngine, *, prefill_per_step: int = 1):
        self.engine = engine
        self.prefill_per_step = max(1, int(prefill_per_step))
        self._queue: "queue_mod.Queue[RequestFuture]" = queue_mod.Queue()
        self._resident: Dict[int, RequestFuture] = {}      # slot -> future
        self._budget: Dict[int, int] = {}                  # slot -> left
        self._ids = itertools.count()
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._all_done = threading.Event()
        self._all_done.set()
        self.stats = {"steps": 0, "prefills": 0, "tokens": 0,
                      "occupancy_sum": 0.0, "evictions": 0,
                      "admission_stalls": 0}

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, *,
               max_new_tokens: Optional[int] = None,
               frontend: Optional[np.ndarray] = None) -> RequestFuture:
        scfg = self.engine.serve_cfg
        req = Request(prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens or scfg.max_new_tokens,
                      frontend=frontend,
                      rid=next(self._ids),
                      submitted_at=time.perf_counter())
        fut = RequestFuture(req)
        with self._inflight_lock:
            self._inflight += 1
            self._all_done.clear()
        self._queue.put(fut)
        self._wake.set()
        return fut

    def drain(self, timeout: Optional[float] = None) -> None:
        if not self._all_done.wait(timeout):
            raise TimeoutError("server did not drain in time")

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "ContinuousServer":
        assert self._thread is None, "server already started"
        self._thread = threading.Thread(target=self._run,
                                        name="continuous-server", daemon=True)
        self._thread.start()
        return self

    def shutdown(self, *, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        if drain:
            self.drain(timeout)
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def __enter__(self) -> "ContinuousServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=not any(exc))

    # -- scheduler ---------------------------------------------------------
    def _admit(self) -> int:
        """Move up to ``prefill_per_step`` queued requests into free
        slots; requests the page pool cannot host yet go back to the
        front of the queue."""
        admitted = 0
        held: List[RequestFuture] = []
        while (admitted < self.prefill_per_step
                and self.engine.free_slots()):
            try:
                fut = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            req = fut.request
            try:
                res = self.engine.insert(req.prompt,
                                         max_new_tokens=req.max_new_tokens,
                                         frontend=req.frontend)
            except Exception as err:        # bad request (e.g. too long)
                fut._fail(err)
                self._request_done()
                continue
            if res is None:                 # pool exhausted: wait for evicts
                held.append(fut)
                self.stats["admission_stalls"] += 1
                break
            slot, first_tok = res
            self.stats["prefills"] += 1
            self.stats["tokens"] += 1
            fut._emit(first_tok)
            admitted += 1
            if self._finished_on(fut, first_tok, emitted=1):
                self.engine.evict(slot)
                self.stats["evictions"] += 1
                fut._finish()
                self._request_done()
            else:
                self._resident[slot] = fut
                self._budget[slot] = req.max_new_tokens - 1
        for fut in held:                    # preserve arrival order
            self._queue.queue.appendleft(fut)
        return admitted

    def _finished_on(self, fut: RequestFuture, token: int, *,
                     emitted: int) -> bool:
        eos = self.engine.serve_cfg.eos_id
        return ((eos is not None and token == eos)
            or emitted >= fut.request.max_new_tokens)

    def _request_done(self) -> None:
        with self._inflight_lock:
            self._inflight -= 1
            if self._inflight == 0:
                self._all_done.set()

    def _run(self) -> None:
        while not self._stop.is_set():
            self._admit()
            if not self._resident:
                if self._queue.empty():
                    self._wake.wait(timeout=0.05)
                    self._wake.clear()
                continue
            result = self.engine.step()
            self.stats["steps"] += 1
            self.stats["occupancy_sum"] += self.engine.occupancy
            for slot, fut in list(self._resident.items()):
                if not result.valid_at(slot):
                    continue
                tok = result.token_at(slot)
                fut._emit(tok)
                self.stats["tokens"] += 1
                self._budget[slot] -= 1
                done = self._finished_on(
                    fut, tok,
                    emitted=fut.request.max_new_tokens - self._budget[slot])
                if done or self._budget[slot] <= 0:
                    self.engine.evict(slot)
                    self.stats["evictions"] += 1
                    del self._resident[slot], self._budget[slot]
                    fut._finish()
                    self._request_done()
        # on shutdown without drain: fail whatever is left
        leftovers = list(self._resident.values())
        self._resident.clear()
        while True:
            try:
                leftovers.append(self._queue.get_nowait())
            except queue_mod.Empty:
                break
        for fut in leftovers:
            fut._fail(RuntimeError("server shut down"))
            self._request_done()

    # -- reporting ---------------------------------------------------------
    def mean_occupancy(self) -> float:
        steps = self.stats["steps"]
        return self.stats["occupancy_sum"] / steps if steps else 0.0
