"""Serving: batched LM decode, continuous batching, accelerator serving.

Layered like a real inference stack:

* ``engine``  — per-call engines: ``DecodeEngine`` (static batch, the
  sequential parity oracle) and ``AcceleratorEngine`` (STT front door as
  a service);
* ``pages``   — paged decode cache (fixed-size pages, slot→page-table
  indirection, shared pool) + mesh placement via the partition solver;
* ``slots``   — fixed-capacity continuous-batching slot engine over the
  paged cache (insert/evict without draining or recompiling);
* ``server``  — thread-safe async dispatch loop with per-request futures;
* ``report``  — BENCH_serve.json schema + validator.
"""
from . import engine, pages, report, server, slots
from .engine import AcceleratorEngine, DecodeEngine, ServeConfig
from .pages import PagedKVCache, PageLayout, place_pools, solve_page_placement
from .report import SERVE_SCHEMA_VERSION, serve_entry, validate_serve
from .server import ContinuousServer, Request, RequestFuture
from .slots import ResultTokens, SlotEngine

__all__ = [
    "engine", "pages", "report", "server", "slots",
    "AcceleratorEngine", "DecodeEngine", "ServeConfig",
    "PagedKVCache", "PageLayout", "place_pools", "solve_page_placement",
    "SERVE_SCHEMA_VERSION", "serve_entry", "validate_serve",
    "ContinuousServer", "Request", "RequestFuture",
    "ResultTokens", "SlotEngine",
]
