"""Batched decode engine."""
from . import engine
from .engine import DecodeEngine, ServeConfig
