"""Serving engines: batched LM decode + generated-accelerator serving."""
from . import engine
from .engine import AcceleratorEngine, DecodeEngine, ServeConfig

__all__ = ["engine", "AcceleratorEngine", "DecodeEngine", "ServeConfig"]
