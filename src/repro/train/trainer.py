"""Training step: loss, grads, AdamW update — sharded via logical axis rules.

``make_train_step`` returns a jit-compiled (in/out-sharded, donated) step:
  * params/opt-state sharded FSDP(+TP) from their logical axes,
  * batch sharded over (pod, data),
  * gradients reduced by GSPMD (psum inserted automatically from shardings),
  * optional int8 error-feedback gradient compression on the pod (DCI) axis
    is exercised in dist/collectives (the production flag plumbs it into the
    DP reduction; documented in DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import common, transformer
from ..optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState


def init_state(key, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig
               ) -> Tuple[TrainState, Any]:
    """Returns (state, logical axes tree for the params)."""
    params, axes = common.split(transformer.init_params(key, cfg))
    opt = adamw.init(params, opt_cfg)
    return TrainState(params, opt), axes


def loss_fn(params, batch: Dict, cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    logits, aux, _ = transformer.forward(
        params, batch["tokens"], cfg, frontend=batch.get("frontend"))
    ce = common.cross_entropy(logits, batch["targets"])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    """Unsharded (single-device / auto-sharded) train step."""

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch, cfg)
        new_params, new_opt, om = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return TrainState(new_params, new_opt), metrics

    return step


# ---------------------------------------------------------------------------
# sharded compilation
# ---------------------------------------------------------------------------

def state_shardings(state_shape: TrainState, axes: Any, mesh: Mesh,
                    rules: common.AxisRules = common.DEFAULT_RULES
                    ) -> TrainState:
    """NamedShardings for a TrainState from the params' logical axes.

    Optimizer moments reuse the param specs (same shapes); 8-bit moments
    (different shapes) shard their leading block dim over 'data' when
    divisible — the ZeRO property is preserved either way."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = rules.specs(axes, state_shape.params, mesh_shape)

    def moment_spec(like_shape) -> P:
        d = mesh_shape.get("data", 1)
        if len(like_shape) >= 1 and like_shape[0] % max(d, 1) == 0 and d > 1:
            return P("data", *([None] * (len(like_shape) - 1)))
        return P(*([None] * len(like_shape)))

    params_treedef = jax.tree.structure(state_shape.params)

    def moments(mtree):
        # match structure: fp32 moments mirror params; Q8 leaves flatten to
        # (q, scale, shape-static)
        flat_like = jax.tree.leaves(mtree,
                                    is_leaf=lambda x: isinstance(x, adamw.Q8))
        flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
        out = []
        for like, ps in zip(flat_like, flat_p):
            if isinstance(like, adamw.Q8):
                out.append(adamw.Q8(moment_spec(like.q.shape),
                                    moment_spec(like.scale.shape),
                                    like.shape))
            else:
                out.append(ps)
        return jax.tree.unflatten(params_treedef, out)

    def named(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            tree, is_leaf=lambda x: isinstance(x, P))

    return TrainState(
        params=named(pspecs),
        opt=adamw.OptState(
            NamedSharding(mesh, P()),
            named(moments(state_shape.opt.m)),
            named(moments(state_shape.opt.v))),
    )


def batch_shardings(mesh: Mesh, with_frontend: bool = False) -> Dict:
    bs = NamedSharding(mesh, P(
        tuple(a for a in ("pod", "data") if a in mesh.axis_names), None))
    out = {"tokens": bs, "targets": bs}
    if with_frontend:
        out["frontend"] = NamedSharding(mesh, P(
            tuple(a for a in ("pod", "data") if a in mesh.axis_names),
            None, None))
    return out


def make_sharded_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                            mesh: Mesh, state_shape: TrainState, axes: Any,
                            rules: common.AxisRules = common.DEFAULT_RULES,
                            donate: bool = True):
    """jit with explicit in/out shardings; state donated (in-place update)."""
    st_sh = state_shardings(state_shape, axes, mesh, rules)
    b_sh = batch_shardings(mesh, with_frontend=cfg.family in ("encdec", "vlm"))
    step = make_train_step(cfg, opt_cfg)
    metrics_sh = None  # replicated scalars
    return jax.jit(
        step,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, metrics_sh),
        donate_argnums=(0,) if donate else (),
    ), st_sh, b_sh
