"""Training step + sharding."""
from . import trainer
from .trainer import TrainState, init_state, make_sharded_train_step, make_train_step
