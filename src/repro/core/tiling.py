"""Shared tile-size selection for cost model and compiler.

``choose_tile`` used to be a private method of ``PaperCycleModel``; the
compile pipeline (``repro.compile``) needs the *same* tile decision so the
blocks a generated kernel runs with are the blocks the cost model priced.
Factoring it here is what keeps the two from drifting (ISSUE 1 tentpole
item 1): the cost model delegates to this module, and so does
``compile.lower``.

Also home to ``ArrayConfig`` (the paper's evaluation hardware, §VI-A) so
that both layers share one notion of the array geometry and the VMEM
budget used by the operand-stationary template's strip accumulator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .algebra import TensorAlgebra
from .stt import Dataflow


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    """The paper's evaluation hardware (§VI-A) + TPU VMEM budget (D1)."""

    pe_dims: Tuple[int, int] = (16, 16)
    freq_mhz: float = 320.0
    onchip_gbps: float = 32.0
    elem_bytes: int = 2            # INT16 for the DSE experiments
    #: per-core VMEM available to kernel scratch (TPU ~16 MB/core); caps the
    #: operand-stationary strip accumulator, see kernels/stt_gemm.py.
    vmem_budget_bytes: int = 16 * 1024 * 1024

    @property
    def n_pes(self) -> int:
        return self.pe_dims[0] * self.pe_dims[1]

    @property
    def bytes_per_cycle(self) -> float:
        return self.onchip_gbps * 1e9 / (self.freq_mhz * 1e6)


def row_extent(row: Sequence, tile: Sequence[int]) -> int:
    """Extent of a linear form over the box [0, tile_j) — exact for boxes."""
    hi = 0
    lo = 0
    for coef, b in zip(row, tile):
        c = int(coef)
        if c > 0:
            hi += c * (b - 1)
        elif c < 0:
            lo += c * (b - 1)
    return hi - lo + 1


def is_unit_row(row: Sequence) -> Optional[int]:
    """Return the column index if the row is +/- a unit vector, else None."""
    nz = [j for j, v in enumerate(row) if v != 0]
    if len(nz) == 1 and abs(int(row[nz[0]])) == 1:
        return nz[0]
    return None


def choose_tile(alg: TensorAlgebra, df: Dataflow,
                pe_dims: Tuple[int, int] = (16, 16)
                ) -> Tuple[List[int], Tuple[int, int], float]:
    """Tile the selected loops so the PE footprint fits the array.

    Returns (tile bounds for selected loops, packed parallel copies per
    space dim, spatial utilization).
    """
    cols = [alg.loop_index(s) for s in df.selected]
    bounds = [alg.bounds[c] for c in cols]
    T = df.T
    n_space = df.n_space
    P = pe_dims

    tile = list(bounds)
    # Shrink loops (time-loop last) until every space extent fits.
    space_rows = [T[i] for i in range(n_space)]
    order = sorted(range(len(tile)),
                   key=lambda j: sum(abs(int(r[j])) for r in space_rows),
                   reverse=True)
    for i, r in enumerate(space_rows):
        while row_extent(r, tile) > P[i]:
            j = next(jj for jj in order if int(r[jj]) != 0 and tile[jj] > 1)
            tile[j] -= 1

    # Packing: if a unit space row's loop bound is below the array dim,
    # replicate the tile along that dim (the paper's p=3 -> 15 rows).
    copies = [1, 1]
    for i, r in enumerate(space_rows):
        j = is_unit_row(r)
        ext = row_extent(r, tile)
        if j is not None and ext < P[i]:
            copies[i] = max(1, P[i] // ext)
    util_num = 1.0
    for i, r in enumerate(space_rows):
        ext = row_extent(r, tile)
        util_num *= min(P[i], ext * copies[i]) / P[i]
    return tile, (copies[0], copies[1]), util_num


def tile_by_loop(alg: TensorAlgebra, df: Dataflow,
                 pe_dims: Tuple[int, int] = (16, 16)) -> Dict[str, int]:
    """Per-loop tile bounds: chosen tile for selected loops, full bound for
    the sequential (outer) loops.  This is the form the compiler consumes
    when mapping loop tiles onto GEMM block sizes."""
    tile, _, _ = choose_tile(alg, df, pe_dims)
    out = {name: alg.bounds[i] for i, name in enumerate(alg.loops)}
    for name, t in zip(df.selected, tile):
        out[name] = t
    return out


def form_blocks(alg: TensorAlgebra, df: Dataflow, form,
                pe_dims: Tuple[int, int] = (16, 16)
                ) -> Tuple[int, int, int]:
    """Map the STT tile onto a lowered form's (bm, bn, bk) block sizes.

    Batch-aware: loops folded onto the form's leading batch grid dims
    (``form.dim_loops["b"]``) are executed one slice per grid step and
    therefore never inflate any GEMM block — in particular not the
    contraction, which is what made the retired block-diagonal lowering
    execute batch x the algebra's MACs.  Each remaining GEMM dim's block
    is the product of the tiles of the loops it folds, clamped to the dim
    extent.

    The per-batch-slice consequence matters for VMEM too: the
    operand-stationary strip accumulator is (per-slice m, bn) fp32, so
    the budget check in ``kernels/ops.stt_matmul`` sees the slice extent,
    not batch x it.
    """
    per_loop = tile_by_loop(alg, df, pe_dims)
    out = []
    for dim, full in (("m", form.m), ("n", form.n), ("k", form.k)):
        blk = 1
        for loop in form.dim_loops.get(dim, ()):
            blk *= per_loop[loop]
        out.append(max(1, min(blk, full)))
    return (out[0], out[1], out[2])
