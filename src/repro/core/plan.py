"""Plan generation: one STT matrix -> kernel template + collective schedule.

This is TensorLib's "hardware generation" step (§V) re-targeted at TPU
(DESIGN.md §2).  The same per-tensor classification drives two levels:

* **KernelPlan** (intra-chip): which Pallas GEMM template runs on a core —
  the stationary tensor decides which operand block stays resident in VMEM
  across the reduction grid axis (paper Fig. 3 module (c)/(d) = VMEM
  residency; systolic shift = the software pipeline's revolving buffer).

* **CommPlan** (inter-chip): which collectives connect the chip "PE array" —
  multicast = all_gather, reduction tree = psum / psum_scatter, systolic =
  ppermute ring, stationary = sharded with no motion, unicast = fully
  partitioned streaming (no collective).

``plan_for`` is the faithful analogue of the paper's module-selection table:
it is a *total* function of the classification, not of the algebra, which is
exactly the paper's reuse argument — new dataflows reuse the same templates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from .stt import Dataflow, DataflowClass


# map: (class, is_output) -> PE-internal module of paper Fig. 3
PAPER_PE_MODULES = {
    (DataflowClass.SYSTOLIC, False): "a:systolic-in",
    (DataflowClass.SYSTOLIC, True): "b:systolic-out",
    (DataflowClass.STATIONARY, False): "c:stationary-in(double-buffer)",
    (DataflowClass.STATIONARY, True): "d:stationary-out(double-buffer)",
    (DataflowClass.MULTICAST, False): "e:direct-in",
    (DataflowClass.UNICAST, False): "e:direct-in",
    (DataflowClass.UNICAST, True): "f:direct-out",
    (DataflowClass.REDUCTION, True): "f:direct-out(+reduction-tree)",
    (DataflowClass.BROADCAST, False): "e:direct-in",
    (DataflowClass.MULTICAST_STATIONARY, False): "e+c:tap+double-buffer",
    (DataflowClass.MULTICAST_STATIONARY, True): "f+d:tree+double-buffer",
    (DataflowClass.SYSTOLIC_MULTICAST, False): "e+a:tap+systolic",
    (DataflowClass.SYSTOLIC_MULTICAST, True): "f+b:tree+systolic",
    (DataflowClass.BROADCAST, True): "f:reduction-tree-2d",
}


@dataclasses.dataclass(frozen=True)
class TensorCommPlan:
    """Mesh-level realization for one tensor (DESIGN.md §2, level 2)."""

    tensor: str
    kind: str          # shard | all_gather | psum | ppermute_ring | stream
    #: every mesh axis the reuse direction moves along, major axis first.
    #: A diagonal direction (e.g. dp = (1, 1)) is realized as two chained
    #: collectives, one per axis — both axes are recorded here instead of
    #: silently dropping the minor one.
    mesh_axes: Tuple[str, ...] = ()
    ring_shift: Tuple[int, ...] = ()  # systolic direction on the mesh
    delay: int = 0
    #: block-level density of the tensor (1.0 = dense).  Sparse operands
    #: currently replicate/move their *masked dense* form between chips;
    #: the density annotates how much of that traffic is payload so mesh
    #: cost calibration can discount it.
    density: float = 1.0

    @property
    def is_sparse(self) -> bool:
        return self.density < 1.0

    @property
    def mesh_axis(self) -> Optional[str]:
        """Major axis of the collective (back-compat accessor)."""
        return self.mesh_axes[0] if self.mesh_axes else None

    @property
    def is_diagonal(self) -> bool:
        """True when the move spans more than one mesh axis (chained)."""
        return len(self.mesh_axes) > 1


@dataclasses.dataclass(frozen=True)
class CommPlan:
    dataflow: str
    tensors: Tuple[TensorCommPlan, ...]

    def by_tensor(self) -> Dict[str, TensorCommPlan]:
        return {t.tensor: t for t in self.tensors}

    @property
    def collective_kinds(self) -> Tuple[str, ...]:
        return tuple(sorted({t.kind for t in self.tensors}))


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Intra-chip Pallas template selection."""

    dataflow: str
    template: str                      # which kernels/stt_gemm template
    resident_tensor: Optional[str]     # block pinned in VMEM across k-steps
    streamed: Tuple[str, ...]          # operands double-buffered by pipeline
    reduction_in_kernel: bool          # accumulate over a grid axis?


def _axes_for(dp: Tuple[int, ...], axes: Tuple[str, str]) -> Tuple[str, ...]:
    """Every mesh axis a reuse direction moves along, major axis first.

    A diagonal move such as dp = (1, 1) yields both axes: the collective
    is realized as two chained per-axis collectives (or a 2-D collective
    over the axis tuple), not silently truncated to the major axis.
    """
    return tuple(axes[i] for i, d in enumerate(dp) if d != 0)


def comm_plan_for(df: Dataflow, axes: Tuple[str, str] = ("x", "y"),
                  densities: Optional[Dict[str, float]] = None) -> CommPlan:
    """Per-tensor mesh collectives generated from the classification.

    ``axes`` defaults to the ("x", "y") names the dist engines and the
    CommPlan interpreter (``dist/comm_engine.py``) use for the chip mesh.
    ``densities`` (tensor name -> block density) annotates sparse operands
    on the emitted plan — metadata only, the collective kinds are a
    function of the classification alone.
    """
    plans = []
    for t in df.tensors:
        c = t.cls
        if c is DataflowClass.STATIONARY:
            plans.append(TensorCommPlan(t.tensor, "shard"))
        elif c is DataflowClass.MULTICAST:
            plans.append(TensorCommPlan(t.tensor, "all_gather",
                                        _axes_for(t.dp, axes)))
        elif c is DataflowClass.BROADCAST:
            plans.append(TensorCommPlan(t.tensor, "all_gather", tuple(axes)))
        elif c is DataflowClass.REDUCTION:
            plans.append(TensorCommPlan(t.tensor, "psum",
                                        _axes_for(t.dp, axes)))
        elif c is DataflowClass.SYSTOLIC:
            plans.append(TensorCommPlan(t.tensor, "ppermute_ring",
                                        _axes_for(t.dp, axes),
                                        ring_shift=t.dp, delay=t.dt))
        elif c is DataflowClass.MULTICAST_STATIONARY:
            plans.append(TensorCommPlan(t.tensor, "all_gather",
                                        _axes_for(t.dp_multicast, axes)))
        elif c is DataflowClass.SYSTOLIC_MULTICAST:
            plans.append(TensorCommPlan(t.tensor, "ppermute_ring",
                                        _axes_for(t.dp, axes),
                                        ring_shift=t.dp, delay=t.dt))
        else:  # UNICAST
            plans.append(TensorCommPlan(t.tensor, "stream"))
    if densities:
        plans = [dataclasses.replace(p, density=densities.get(p.tensor, 1.0))
                 for p in plans]
    return CommPlan(df.name, tuple(plans))


def kernel_plan_for(df: Dataflow) -> KernelPlan:
    """Select the Pallas GEMM template from the classification.

    TPU adaptation (DESIGN.md D1): the MXU replaces the PE array, so
    "which tensor is stationary" becomes "which block is VMEM-resident
    across the reduction axis of the Pallas grid".
    """
    stationary = [t.tensor for t in df.tensors
                  if t.cls in (DataflowClass.STATIONARY,
                               DataflowClass.MULTICAST_STATIONARY)]
    out_name = df.tensors[-1].tensor
    out_cls = df.tensors[-1].cls

    if out_name in stationary:
        template = "output_stationary"
        resident = out_name
    elif stationary:
        template = "operand_stationary"
        resident = stationary[0]
    elif out_cls is DataflowClass.REDUCTION:
        template = "reduction_tree"
        resident = None
    else:
        template = "streaming"
        resident = None
    streamed = tuple(t.tensor for t in df.tensors if t.tensor != resident)
    return KernelPlan(df.name, template, resident, streamed,
                      reduction_in_kernel=(template == "output_stationary"))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The complete generated 'accelerator': paper modules for reference,
    kernel template, and mesh collective schedule."""

    dataflow: Dataflow
    pe_modules: Tuple[str, ...]
    kernel: KernelPlan
    comm: CommPlan


def plan_for(df: Dataflow, axes: Tuple[str, str] = ("x", "y"),
             densities: Optional[Dict[str, float]] = None) -> ExecutionPlan:
    is_out = {t.tensor: (t.tensor == df.tensors[-1].tensor)
              for t in df.tensors}
    modules = tuple(
        f"{t.tensor}->{PAPER_PE_MODULES[(t.cls, is_out[t.tensor])]}"
        for t in df.tensors)
    return ExecutionPlan(df, modules, kernel_plan_for(df),
                         comm_plan_for(df, axes, densities))
