"""Plan generation: one STT matrix -> kernel template + collective schedule.

This is TensorLib's "hardware generation" step (§V) re-targeted at TPU
(DESIGN.md §2).  The same per-tensor classification drives two levels:

* **KernelPlan** (intra-chip): which Pallas GEMM template runs on a core —
  the stationary tensor decides which operand block stays resident in VMEM
  across the reduction grid axis (paper Fig. 3 module (c)/(d) = VMEM
  residency; systolic shift = the software pipeline's revolving buffer).

* **CommPlan** (inter-chip): which collectives connect the chip "PE array" —
  multicast = all_gather, reduction tree = psum / psum_scatter, systolic =
  ppermute ring, stationary = sharded with no motion, unicast = fully
  partitioned streaming (no collective).

``plan_for`` is the faithful analogue of the paper's module-selection table:
it is a *total* function of the classification, not of the algebra, which is
exactly the paper's reuse argument — new dataflows reuse the same templates.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, Mapping, Optional, Tuple, Union

from .stt import Dataflow, DataflowClass


# map: (class, is_output) -> PE-internal module of paper Fig. 3
PAPER_PE_MODULES = {
    (DataflowClass.SYSTOLIC, False): "a:systolic-in",
    (DataflowClass.SYSTOLIC, True): "b:systolic-out",
    (DataflowClass.STATIONARY, False): "c:stationary-in(double-buffer)",
    (DataflowClass.STATIONARY, True): "d:stationary-out(double-buffer)",
    (DataflowClass.MULTICAST, False): "e:direct-in",
    (DataflowClass.UNICAST, False): "e:direct-in",
    (DataflowClass.UNICAST, True): "f:direct-out",
    (DataflowClass.REDUCTION, True): "f:direct-out(+reduction-tree)",
    (DataflowClass.BROADCAST, False): "e:direct-in",
    (DataflowClass.MULTICAST_STATIONARY, False): "e+c:tap+double-buffer",
    (DataflowClass.MULTICAST_STATIONARY, True): "f+d:tree+double-buffer",
    (DataflowClass.SYSTOLIC_MULTICAST, False): "e+a:tap+systolic",
    (DataflowClass.SYSTOLIC_MULTICAST, True): "f+b:tree+systolic",
    (DataflowClass.BROADCAST, True): "f:reduction-tree-2d",
}


@dataclasses.dataclass(frozen=True)
class TensorCommPlan:
    """Mesh-level realization for one tensor (DESIGN.md §2, level 2)."""

    tensor: str
    kind: str          # shard | all_gather | psum | ppermute_ring | stream
    #: every mesh axis the reuse direction moves along, major axis first.
    #: A diagonal direction (e.g. dp = (1, 1)) is realized as two chained
    #: collectives, one per axis — both axes are recorded here instead of
    #: silently dropping the minor one.
    mesh_axes: Tuple[str, ...] = ()
    ring_shift: Tuple[int, ...] = ()  # systolic direction on the mesh
    delay: int = 0
    #: block-level density of the tensor (1.0 = dense).  Sparse operands
    #: currently replicate/move their *masked dense* form between chips;
    #: the density annotates how much of that traffic is payload so mesh
    #: cost calibration can discount it.
    density: float = 1.0

    @property
    def is_sparse(self) -> bool:
        return self.density < 1.0

    @property
    def mesh_axis(self) -> Optional[str]:
        """Major axis of the collective (back-compat accessor)."""
        return self.mesh_axes[0] if self.mesh_axes else None

    @property
    def is_diagonal(self) -> bool:
        """True when the move spans more than one mesh axis (chained)."""
        return len(self.mesh_axes) > 1


@dataclasses.dataclass(frozen=True)
class CommPlan:
    dataflow: str
    tensors: Tuple[TensorCommPlan, ...]

    def by_tensor(self) -> Dict[str, TensorCommPlan]:
        return {t.tensor: t for t in self.tensors}

    @property
    def collective_kinds(self) -> Tuple[str, ...]:
        return tuple(sorted({t.kind for t in self.tensors}))


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Intra-chip Pallas template selection."""

    dataflow: str
    template: str                      # which kernels/stt_gemm template
    resident_tensor: Optional[str]     # block pinned in VMEM across k-steps
    streamed: Tuple[str, ...]          # operands double-buffered by pipeline
    reduction_in_kernel: bool          # accumulate over a grid axis?


def _axes_for(dp: Tuple[int, ...], axes: Tuple[str, str]) -> Tuple[str, ...]:
    """Every mesh axis a reuse direction moves along, major axis first.

    A diagonal move such as dp = (1, 1) yields both axes: the collective
    is realized as two chained per-axis collectives (or a 2-D collective
    over the axis tuple), not silently truncated to the major axis.
    """
    return tuple(axes[i] for i, d in enumerate(dp) if d != 0)


def comm_plan_for(df: Dataflow, axes: Tuple[str, str] = ("x", "y"),
                  densities: Optional[Dict[str, float]] = None) -> CommPlan:
    """Per-tensor mesh collectives generated from the classification.

    ``axes`` defaults to the ("x", "y") names the dist engines and the
    CommPlan interpreter (``dist/comm_engine.py``) use for the chip mesh.
    ``densities`` (tensor name -> block density) annotates sparse operands
    on the emitted plan — metadata only, the collective kinds are a
    function of the classification alone.
    """
    plans = []
    for t in df.tensors:
        c = t.cls
        if c is DataflowClass.STATIONARY:
            plans.append(TensorCommPlan(t.tensor, "shard"))
        elif c is DataflowClass.MULTICAST:
            plans.append(TensorCommPlan(t.tensor, "all_gather",
                                        _axes_for(t.dp, axes)))
        elif c is DataflowClass.BROADCAST:
            plans.append(TensorCommPlan(t.tensor, "all_gather", tuple(axes)))
        elif c is DataflowClass.REDUCTION:
            plans.append(TensorCommPlan(t.tensor, "psum",
                                        _axes_for(t.dp, axes)))
        elif c is DataflowClass.SYSTOLIC:
            plans.append(TensorCommPlan(t.tensor, "ppermute_ring",
                                        _axes_for(t.dp, axes),
                                        ring_shift=t.dp, delay=t.dt))
        elif c is DataflowClass.MULTICAST_STATIONARY:
            plans.append(TensorCommPlan(t.tensor, "all_gather",
                                        _axes_for(t.dp_multicast, axes)))
        elif c is DataflowClass.SYSTOLIC_MULTICAST:
            plans.append(TensorCommPlan(t.tensor, "ppermute_ring",
                                        _axes_for(t.dp, axes),
                                        ring_shift=t.dp, delay=t.dt))
        else:  # UNICAST
            plans.append(TensorCommPlan(t.tensor, "stream"))
    if densities:
        plans = [dataclasses.replace(p, density=densities.get(p.tensor, 1.0))
                 for p in plans]
    return CommPlan(df.name, tuple(plans))


def kernel_plan_for(df: Dataflow) -> KernelPlan:
    """Select the Pallas GEMM template from the classification.

    TPU adaptation (DESIGN.md D1): the MXU replaces the PE array, so
    "which tensor is stationary" becomes "which block is VMEM-resident
    across the reduction axis of the Pallas grid".
    """
    stationary = [t.tensor for t in df.tensors
                  if t.cls in (DataflowClass.STATIONARY,
                               DataflowClass.MULTICAST_STATIONARY)]
    out_name = df.tensors[-1].tensor
    out_cls = df.tensors[-1].cls

    if out_name in stationary:
        template = "output_stationary"
        resident = out_name
    elif stationary:
        template = "operand_stationary"
        resident = stationary[0]
    elif out_cls is DataflowClass.REDUCTION:
        template = "reduction_tree"
        resident = None
    else:
        template = "streaming"
        resident = None
    streamed = tuple(t.tensor for t in df.tensors if t.tensor != resident)
    return KernelPlan(df.name, template, resident, streamed,
                      reduction_in_kernel=(template == "output_stationary"))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The complete generated 'accelerator': paper modules for reference,
    kernel template, and mesh collective schedule."""

    dataflow: Dataflow
    pe_modules: Tuple[str, ...]
    kernel: KernelPlan
    comm: CommPlan


def plan_for(df: Dataflow, axes: Tuple[str, str] = ("x", "y"),
             densities: Optional[Dict[str, float]] = None) -> ExecutionPlan:
    is_out = {t.tensor: (t.tensor == df.tensors[-1].tensor)
              for t in df.tensors}
    modules = tuple(
        f"{t.tensor}->{PAPER_PE_MODULES[(t.cls, is_out[t.tensor])]}"
        for t in df.tensors)
    return ExecutionPlan(df, modules, kernel_plan_for(df),
                         comm_plan_for(df, axes, densities))


# ---------------------------------------------------------------------------
# Partition solver: (CommPlan, LoweredForm, mesh shape) -> PartitionSolution
# ---------------------------------------------------------------------------
# The solver is the single place where LoweredForm dims — batch, m, n, k and
# sparse block coordinates — are mapped onto mesh axes.  It is a *total*
# function of the CommPlan kinds (same reuse argument as plan_for): the
# interpreter (dist/comm_engine.py) materializes it as shard_map specs and
# ring loops, the cost model prices collectives from it, the DSE ranks
# dataflows with it, and Accelerator.describe() reports it.  It is jax-free
# so every consumer (including the pure-python cost model) can call it.

#: side-kind precedence: a GEMM operand fed by several algebra tensors
#: (mttkrp's Khatri-Rao rhs) moves the way its most mobile tensor does.
_KIND_ORDER = ("ppermute_ring", "all_gather", "stream", "shard")

#: bytes per block-COO coordinate component shipped with compressed payloads
INDEX_BYTES = 4


def side_kind(by_tensor: Mapping[str, TensorCommPlan],
              tensors: FrozenSet[str]) -> str:
    kinds = {by_tensor[t].kind for t in tensors if t in by_tensor}
    for k in _KIND_ORDER:
        if k in kinds:
            return k
    return "shard"


AxisSpec = Union[None, str, Tuple[str, ...]]


def _axis_factor(ax: AxisSpec, sizes: Mapping[str, int]) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return sizes[ax]
    return math.prod(sizes[a] for a in ax)


@dataclasses.dataclass(frozen=True)
class TensorPartition:
    """Stored mesh layout + motion of one GEMM-form side.

    ``dims`` are the LoweredForm dims of the operand in array order
    (batched sides lead with ``"b"``); ``placement`` shards each dim over
    a mesh axis (``None`` = that dim is whole on every device holding it).
    ``motion`` is the collective that moves the side between chips during
    execution (all_gather multicast, ppermute_ring systolic, or None for
    resident data); a compressed side moves as a padded block payload +
    block-COO coordinate list instead of its dense image.
    """

    side: str                             # lhs | rhs | out
    tensors: Tuple[str, ...]              # algebra tensors riding this side
    dims: Tuple[str, ...]
    placement: Tuple[AxisSpec, ...]
    motion: Optional[str] = None          # all_gather | ppermute_ring | None
    motion_axis: Optional[str] = None
    delay: int = 0                        # systolic dt carried by the plan
    density: float = 1.0
    compressed: bool = False              # shipped as BSR payload + coords

    @property
    def axis_of(self) -> Dict[str, AxisSpec]:
        return dict(zip(self.dims, self.placement))

    def shard_factor(self, sizes: Mapping[str, int]) -> int:
        return math.prod(_axis_factor(a, sizes) for a in self.placement)

    @property
    def is_replicated(self) -> bool:
        """True when no dim of the stored layout is sharded at all."""
        return all(a is None for a in self.placement)

    def describe(self) -> str:
        dims = " ".join(
            f"{d}:{'/'.join(a) if isinstance(a, tuple) else (a or '·')}"
            for d, a in zip(self.dims, self.placement))
        mot = f" {self.motion}[{self.motion_axis}]" if self.motion else ""
        comp = " bsr" if self.compressed else ""
        return f"{dims}{mot}{comp}"


@dataclasses.dataclass(frozen=True)
class PartitionSolution:
    """One solved (CommPlan, LoweredForm, mesh) triple.

    ``grid`` is the headline mapping: every LoweredForm dim -> the mesh
    axis (or axes) that spatially split its iteration range.  The
    per-side ``TensorPartition``s derive the stored layouts (which may
    split extra dims for motion, e.g. SUMMA's stored k-split), and
    ``macs_split`` is the product of axis sizes that divide the MAC
    space — the batch-shard / spatial speedup the cost model prices.
    """

    strategy: str
    axes: Tuple[str, str]
    shape: Tuple[int, int]
    grid: Mapping[str, AxisSpec]          # dim -> mesh axis/axes
    lhs: TensorPartition
    rhs: TensorPartition
    out: TensorPartition
    batch_axis: Optional[str] = None
    ring_axes: Tuple[str, ...] = ()
    k_axes: Tuple[str, ...] = ()
    stagger: bool = False                 # dt ppermute schedule active
    macs_split: int = 1
    notes: Tuple[str, ...] = ()           # degradations, for docs/CI

    # -- introspection ----------------------------------------------------
    @property
    def sizes(self) -> Dict[str, int]:
        return dict(zip(self.axes, self.shape))

    @property
    def n_devices(self) -> int:
        return self.shape[0] * self.shape[1]

    @property
    def sides(self) -> Tuple[TensorPartition, TensorPartition,
                             TensorPartition]:
        return (self.lhs, self.rhs, self.out)

    def replicated_inputs(self) -> Tuple[str, ...]:
        """Algebra tensors whose stored layout is fully replicated — the
        CI no-silent-replication assert reads this."""
        out = []
        for tp in (self.lhs, self.rhs):
            if tp.is_replicated:
                out.extend(tp.tensors)
        return tuple(sorted(out))

    # -- accounting (priced by the cost model and the benchmarks) ---------
    def _extents(self, form) -> Dict[str, int]:
        return {"b": form.batch_size, "m": form.m, "n": form.n, "k": form.k}

    def _side_elems(self, tp: TensorPartition, ext: Mapping[str, int]
                    ) -> float:
        # ceil per dim: a padded shard still occupies a full shard's
        # storage on every device (what 1xN meshes and size-1 dims see)
        elems = 1.0
        for d, a in zip(tp.dims, tp.placement):
            elems *= math.ceil(ext[d] / _axis_factor(a, self.sizes))
        return elems

    def per_device_elems(self, form) -> Dict[str, float]:
        """Stored elements per device per side.  Compressed payloads
        scale by block density (only nonzero blocks are materialized);
        masked-dense sides store their full shard, zeros included —
        that physical difference is exactly what the compressed-vs-dense
        footprint comparison measures."""
        ext = self._extents(form)
        out = {}
        for tp in self.sides:
            e = self._side_elems(tp, ext)
            out[tp.side] = e * (tp.density if tp.compressed else 1.0)
        return out

    def per_device_bytes(self, form, elem_bytes: int = 4) -> Dict[str, float]:
        """Stored bytes per device per side, incl. block-COO metadata for
        compressed sides (two int32 coords per nonzero block)."""
        ext = self._extents(form)
        out = {}
        for tp in self.sides:
            dense = self._side_elems(tp, ext)
            if tp.compressed and form.sparse is not None:
                be = form.sparse.block[0] * form.sparse.block[1]
                b = (
                    dense * tp.density * elem_bytes
                    + (dense * tp.density / be) * 2 * INDEX_BYTES
                )
            else:
                b = dense * elem_bytes
            out[tp.side] = b
        return out

    def comm_bytes(self, form, elem_bytes: int = 4) -> Dict[str, float]:
        """Bytes *received* per device per side over one execution: each
        hop of a ring and each remote shard of a gather moves one stored
        shard (nnz-scaled for compressed sides); psum / staggered-output
        reductions move one output shard per reduction hop."""
        stored = self.per_device_bytes(form, elem_bytes)
        out = {}
        for tp in (self.lhs, self.rhs):
            hops = 0
            if tp.motion is not None and tp.motion_axis is not None:
                hops = self.sizes[tp.motion_axis] - 1
            out[tp.side] = hops * stored[tp.side]
        hops = 0
        if self.stagger and self.ring_axes:
            hops = self.sizes[self.ring_axes[0]] - 1
        elif self.k_axes and not self.stagger:
            hops = math.prod(self.sizes[a] for a in self.k_axes) - 1
        out["out"] = hops * stored["out"]
        return out

    def per_device_macs(self, form) -> int:
        """MACs each device executes: the iteration space divided by the
        ``grid`` split, ceil'd per dim — splitting a size-1 dim is pure
        padding, not speedup, which is exactly what the replicating
        baselines show.  Scaled by block density on the BSR path."""
        ext = self._extents(form)
        macs = 1
        for d in ("b", "m", "n", "k"):
            macs *= math.ceil(ext[d] / _axis_factor(self.grid.get(d),
                                                    self.sizes))
        if form.sparse is not None:
            macs = round(macs * form.sparse.density)
        return max(1, macs)

    def describe(self) -> Dict[str, str]:
        def ax(a):
            return "/".join(a) if isinstance(a, tuple) else (a or "·")

        lines = {"strategy": self.strategy,
                 "grid": " ".join(f"{d}:{ax(a)}"
                                  for d, a in self.grid.items())}
        for tp in self.sides:
            lines[tp.side] = tp.describe()
        if self.notes:
            lines["notes"] = "; ".join(self.notes)
        return lines


def solve_partition(comm: CommPlan, form, axes: Tuple[str, str] = ("x", "y"),
                    shape: Tuple[int, int] = (2, 2), *,
                    shard_batch: bool = True,
                    compressed: Optional[bool] = None) -> PartitionSolution:
    """Derive the per-tensor mesh partition from the CommPlan kinds.

    This replaces the per-strategy shard/replicate decisions that used to
    live inside ``dist/comm_engine.py``: batch grid dims fold onto a mesh
    axis (replication only as the degenerate solution when no axis is
    free), compressed operands ship as per-shard BSR payloads, and
    input-systolic delay staggering is realized as a ppermute rotation
    schedule over the output ring.

    ``shard_batch=False`` / ``compressed=False`` request the replicating /
    masked-dense baselines (used for footprint A/B comparisons);
    ``compressed=None`` means "compressed whenever the form has a
    structured sparse operand".
    """
    ax0, ax1 = axes
    s0, s1 = int(shape[0]), int(shape[1])
    sizes = {ax0: s0, ax1: s1}
    by = comm.by_tensor()
    lhs_kind = side_kind(by, form.lhs_tensors)
    rhs_kind = side_kind(by, form.rhs_tensors)
    out_tp = comm.tensors[-1]
    out_kind = out_tp.kind

    batched = bool(form.batch) and shard_batch
    sparse_side = form.sparse.side if form.sparse is not None else None
    if compressed is None:
        compressed = sparse_side is not None
    compressed = (
        bool(compressed) and sparse_side is not None and not form.batch
    )
    notes = []

    def dens(tensors: FrozenSet[str]) -> float:
        return math.prod(by[t].density for t in tensors if t in by) or 1.0

    def delay_of(tensors: FrozenSet[str]) -> int:
        return max((by[t].delay for t in tensors if t in by), default=0)

    lhs_names = tuple(sorted(form.lhs_tensors))
    rhs_names = tuple(sorted(form.rhs_tensors))
    out_name = (out_tp.tensor,)
    lb, rb = form.lhs_batched, form.rhs_batched

    def part(side, tensors, dims, axis_of, motion=None, motion_axis=None,
             delay=0):
        placement = tuple(axis_of.get(d) for d in dims)
        return TensorPartition(
            side, tensors, dims, placement, motion, motion_axis, delay,
            density=dens(form.lhs_tensors if side == "lhs" else
                         form.rhs_tensors) if side != "out" else 1.0,
            compressed=compressed and side == sparse_side)

    if out_kind in ("shard", "stream"):
        return _solve_out_stationary(
            comm, form, axes, sizes, lhs_kind, rhs_kind, batched,
            compressed, sparse_side, part, lhs_names, rhs_names, out_name,
            lb, rb, delay_of, notes)
    return _solve_k_spatial(
        comm, form, axes, sizes, lhs_kind, rhs_kind, out_tp, batched,
        compressed, sparse_side, part, lhs_names, rhs_names, out_name,
        lb, rb, delay_of, notes)


def _solve_out_stationary(comm, form, axes, sizes, lhs_kind, rhs_kind,
                          batched, compressed, sparse_side, part,
                          lhs_names, rhs_names, out_name, lb, rb,
                          delay_of, notes):
    """Output (b?, m, n) blocks resident on their chip; the contraction is
    delivered by gathers, rings, or local full-k residency.

    m shards the first axis and n the second (the orientation the classic
    SUMMA/Cannon engines used); a batch dim *takes over the first axis*
    (m goes whole-per-device) — for the registry's batched forms m == 1,
    so this turns pure padding waste into a 1/|axis| batch shard, and for
    a hypothetical batched large-m form the per-device element count is
    identical either way.
    """
    ax0, ax1 = axes
    s0, s1 = sizes[ax0], sizes[ax1]
    square = s0 == s1

    grid = {"b": None, "m": ax0, "n": ax1, "k": None}
    if batched:
        grid["b"], grid["m"] = ax0, None

    # per-side motion: lhs moves along ax1 (its reuse spans n), rhs along
    # ax0.  A batched side whose batch shard occupies its motion axis
    # cannot also split k there: it degrades to resident full k.
    lhs_motion = (
        lhs_kind if lhs_kind in ("all_gather", "ppermute_ring") else None
    )
    rhs_motion = (
        rhs_kind if rhs_kind in ("all_gather", "ppermute_ring") else None
    )
    if batched and rb and rhs_motion is not None:
        rhs_motion = None
        notes.append("rhs k-motion degraded to resident: batch shard "
                     f"occupies {ax0}")

    double_ring = (
        lhs_motion == "ppermute_ring" and rhs_motion == "ppermute_ring"
    )
    if double_ring and (not square or
                        (compressed and sparse_side is not None)):
        # Cannon needs equal ring lengths (and skewed dense k-blocks,
        # which a compressed coordinate list cannot realign): keep the
        # systolic ring on one side — the longer axis, or the compressed
        # side — and degrade the other to all_gather multicast.
        keep_lhs = (sparse_side == "lhs") if compressed else (s1 >= s0)
        if keep_lhs:
            rhs_motion = "all_gather" if s0 > 1 else None
            notes.append("rhs ring degraded to all_gather "
                         "(dt staggering kept on lhs ring)")
        else:
            lhs_motion = "all_gather" if s1 > 1 else None
            notes.append("lhs ring degraded to all_gather "
                         "(dt staggering kept on rhs ring)")
        double_ring = False

    if (compressed and sparse_side == "lhs"
            and rhs_motion == "ppermute_ring"):
        # a ring on the *dense* side would hand the compressed side's
        # global-frame k coordinates only a rotating k-shard to index:
        # the dense side must be full-k at contract time, so its ring
        # degrades to all_gather (its dt collapses; the sparse side's
        # own motion is untouched)
        rhs_motion = "all_gather" if s0 > 1 else None
        notes.append("dense rhs ring degraded to all_gather (compressed "
                     "lhs needs full-k contract)")
    if (compressed and sparse_side == "rhs"
            and lhs_motion == "ppermute_ring"):
        lhs_motion = "all_gather" if s1 > 1 else None
        notes.append("dense lhs ring degraded to all_gather (compressed "
                     "rhs needs full-k contract)")

    ring_axes = tuple(ax for ax, mot in ((ax1, lhs_motion), (ax0, rhs_motion))
                      if mot == "ppermute_ring")

    lhs_axis_of = {"b": grid["b"] if lb else None, "m": grid["m"],
                   "k": ax1 if lhs_motion else None}
    rhs_axis_of = {"b": grid["b"] if rb else None, "n": grid["n"],
                   "k": ax0 if rhs_motion else None}
    out_axis_of = {"b": grid["b"], "m": grid["m"], "n": grid["n"]}

    lhs = part("lhs", lhs_names, ("b", "m", "k") if lb else ("m", "k"),
               lhs_axis_of, lhs_motion, ax1 if lhs_motion else None,
               delay_of(form.lhs_tensors))
    rhs = part("rhs", rhs_names, ("b", "k", "n") if rb else ("k", "n"),
               rhs_axis_of, rhs_motion, ax0 if rhs_motion else None,
               delay_of(form.rhs_tensors))
    out = part("out", out_name,
               ("b", "m", "n") if form.batch else ("m", "n"), out_axis_of)

    strategy = ("cannon" if double_ring else
                "summa" if lhs_motion == "all_gather"
                and rhs_motion == "all_gather" else
                "ring_hybrid" if ring_axes else
                "multicast_hybrid" if lhs_motion or rhs_motion else "local")
    macs_split = math.prod(_axis_factor(grid[d], sizes)
                           for d in ("b", "m", "n"))
    return PartitionSolution(
        strategy, axes, (s0, s1), grid, lhs, rhs, out,
        batch_axis=grid["b"], ring_axes=ring_axes, macs_split=macs_split,
        notes=tuple(notes))


def _solve_k_spatial(comm, form, axes, sizes, lhs_kind, rhs_kind, out_tp,
                     batched, compressed, sparse_side, part, lhs_names,
                     rhs_names, out_name, lb, rb, delay_of, notes):
    """The contraction dim is spatial over ``k_axes``; partial products
    reduce over those axes — one psum (reduction-class outputs) or a
    staggered accumulate-rotate ppermute ring (systolic-class outputs).

    Staggering (the executed dt schedule): with a ring output of length S
    the accumulator circulates in m-chunks — device r adds its partial
    for chunk ``(r - t) mod S`` at step t, the chip-scale image of the
    input-systolic time offset — so the mobile tensor (the rotating
    output) stores 1/S of itself per device instead of a full replica.
    """
    ax0, ax1 = axes
    out_kind = out_tp.kind
    if out_kind == "psum":
        k_axes = tuple(a for a in out_tp.mesh_axes if a in sizes) or (ax0,)
    elif out_kind == "ppermute_ring":
        k_axes = (out_tp.mesh_axis if out_tp.mesh_axis in sizes else ax1,)
    else:                         # all_gather: 2-D reduction tree
        k_axes = (ax0, ax1)
    other = next((a for a in axes if a not in k_axes), None)
    if batched and other is None:
        batched = False
        notes.append("batch replicated (degenerate): both axes carry the "
                     "reduction tree")

    ring = out_kind == "ppermute_ring"
    S = sizes[k_axes[0]] if ring else 0
    stagger = ring and S > 1

    # the fully-partitioned ("shard"/"stream") input also splits its non-k
    # dim over the remaining axis; batch takes that axis when present, and
    # a staggered output chunks m over the ring axis instead
    shard_m = (
        other is not None
        and not batched
        and lhs_kind in ("shard", "stream")
        and not stagger
    )
    shard_n = other is not None and not batched and not shard_m

    grid = {"b": other if batched else None,
            "m": other if shard_m else None,
            "n": other if shard_n else None,
            "k": k_axes if len(k_axes) > 1 else k_axes[0]}

    lhs_axis_of = {"b": grid["b"] if lb else None, "m": grid["m"],
                   "k": grid["k"]}
    rhs_axis_of = {"b": grid["b"] if rb else None, "n": grid["n"],
                   "k": grid["k"]}
    out_axis_of = {"b": grid["b"],
                   "m": k_axes[0] if stagger else grid["m"],
                   "n": grid["n"]}

    lhs = part("lhs", lhs_names, ("b", "m", "k") if lb else ("m", "k"),
               lhs_axis_of, None, None, delay_of(form.lhs_tensors))
    rhs = part("rhs", rhs_names, ("b", "k", "n") if rb else ("k", "n"),
               rhs_axis_of, None, None, delay_of(form.rhs_tensors))
    out_motion = "ppermute_ring" if stagger else None
    out = dataclasses.replace(
        part("out", out_name,
             ("b", "m", "n") if form.batch else ("m", "n"), out_axis_of),
        motion=out_motion, motion_axis=k_axes[0] if stagger else None,
        delay=out_tp.delay)

    macs_split = math.prod(_axis_factor(grid[d], sizes)
                           for d in ("b", "m", "n", "k"))
    strategy = (
        "k_spatial_stagger"
        if stagger
        else ("k_spatial_ring" if ring else "k_spatial")
    )
    return PartitionSolution(
        strategy, axes, (sizes[ax0], sizes[ax1]), grid, lhs, rhs, out,
        batch_axis=grid["b"], ring_axes=k_axes if ring else (),
        k_axes=k_axes, stagger=stagger, macs_split=macs_split,
        notes=tuple(notes))
