"""Target-hardware model: TPU v5e constants and roofline terms.

The container is CPU-only; TPU v5e is the *target*.  All roofline numbers in
EXPERIMENTS.md are derived from compiled-HLO statistics with these constants
(per the assignment):

    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI link bandwidth: ~50 GB/s per link
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12        # FLOP/s per chip
    hbm_bw: float = 819e9                  # bytes/s per chip
    ici_bw_per_link: float = 50e9          # bytes/s per link
    hbm_bytes: float = 16e9                # HBM capacity per chip
    vmem_bytes: float = 128 * 2 ** 20      # ~128 MiB VMEM per core
    mxu_dim: int = 128                     # systolic array tile


V5E = TpuSpec()


@dataclasses.dataclass
class RooflineTerms:
    """The three-term roofline for one (arch x shape x mesh) cell."""

    cell: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float          # summed over all chips
    model_flops: float               # 6*N*D (train) or 2*N_active*D (decode)
    spec: TpuSpec = dataclasses.field(default_factory=lambda: V5E)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * self.spec.peak_flops_bf16)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * self.spec.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * self.spec.ici_bw_per_link)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate = max of the three terms (perfect
        overlap assumption; the sum would be the no-overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — fraction of compiled compute that is
        'useful' (catches remat and redundancy waste).  Can exceed 1 only if
        the compiler fused away work; values << 1 indicate recompute."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU under the roofline: useful FLOPs / (chips * peak *
        step_time).  This is the score we hillclimb."""
        denom = self.chips * self.spec.peak_flops_bf16 * self.step_time_s
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> Dict:
        return {
            "cell": self.cell, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def dense_train_model_flops(n_params: float, tokens: float) -> float:
    """6*N*D: fwd 2ND + bwd 4ND."""
    return 6.0 * n_params * tokens


def decode_model_flops(n_active_params: float, tokens: float) -> float:
    """Forward-only decode: 2*N_active per generated token."""
    return 2.0 * n_active_params * tokens
