"""Tensor-algebra IR: perfect nested loops + per-tensor linear access maps.

This is TensorLib's input language (paper §II, Table II).  A computation is

    out[I_out] += in1[I_1] * in2[I_2] * ...

where every index vector is a *linear* function of the loop iteration vector:
``I = A·x`` with an integer access matrix ``A``.  Affine accesses such as the
convolution's ``y + p`` are linear in the loop vector (a row with two ones),
so the whole of Table II fits without affine offsets.

The IR carries concrete loop bounds so the same object drives
  * exact dataflow classification (access matrices only),
  * the cycle-accurate-ish cost model (bounds),
  * a functional space-time simulator used to *prove* a schedule computes the
    right thing (tests), and
  * reference evaluation in numpy for oracle checks.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from . import linalg
from .linalg import Mat


@dataclasses.dataclass(frozen=True)
class Sparsity:
    """Block-sparse operand descriptor: a block-COO coordinate list.

    The tensor is partitioned into dense blocks of shape ``block`` (one
    entry per tensor dimension, each dividing the tensor extent); only the
    blocks listed in ``coords`` hold data, everything else is exactly zero.
    Block granularity is what lets the dense GEMM templates run unchanged
    *inside* each block while the kernel grid skips the zero blocks — the
    same compose-with-dataflows argument the Sparse Abstract Machine and
    TeAAL make for compressed operand formats.

    ``coords`` is kept sorted row-major and duplicate-free so downstream
    consumers (the Pallas grid index-map, accumulation-order proofs) can
    rely on a canonical order.
    """

    block: Tuple[int, ...]
    coords: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        if not self.block or any(b < 1 for b in self.block):
            raise ValueError(f"block shape must be positive, got {self.block}")
        canon = tuple(sorted(set(tuple(int(i) for i in c)
                                 for c in self.coords)))
        if any(len(c) != len(self.block) for c in canon):
            raise ValueError("coordinate rank != block rank")
        object.__setattr__(self, "coords", canon)

    @property
    def nnz_blocks(self) -> int:
        return len(self.coords)

    def grid(self, shape: Sequence[int]) -> Tuple[int, ...]:
        """Block-grid shape for a concrete tensor shape (validates that the
        blocks tile the tensor exactly and that every coordinate is in
        range)."""
        if len(shape) != len(self.block):
            raise ValueError(f"tensor rank {len(shape)} != block rank "
                             f"{len(self.block)}")
        for s, b in zip(shape, self.block):
            if s % b:
                raise ValueError(f"block {self.block} does not tile tensor "
                                 f"shape {tuple(shape)}")
        g = tuple(s // b for s, b in zip(shape, self.block))
        for c in self.coords:
            if any(not 0 <= ci < gi for ci, gi in zip(c, g)):
                raise ValueError(f"block coordinate {c} outside grid {g}")
        return g

    def density(self, shape: Sequence[int]) -> float:
        total = 1
        for gi in self.grid(shape):
            total *= gi
        return self.nnz_blocks / total if total else 0.0

    def block_mask(self, shape: Sequence[int]) -> np.ndarray:
        """Boolean nonzero-block mask over the block grid."""
        mask = np.zeros(self.grid(shape), dtype=bool)
        for c in self.coords:
            mask[c] = True
        return mask

    def element_mask(self, shape: Sequence[int]) -> np.ndarray:
        """Boolean mask at element granularity (the masked dense oracle's
        view of this pattern)."""
        mask = self.block_mask(shape)
        for axis, b in enumerate(self.block):
            mask = np.repeat(mask, b, axis=axis)
        return mask

    @staticmethod
    def random(shape: Sequence[int], block: Sequence[int], density: float,
               seed: int = 0) -> "Sparsity":
        """Deterministic random pattern: ``round(density * n_blocks)``
        blocks (at least one when density > 0) drawn without replacement
        from ``default_rng(seed)``."""
        if not 0.0 <= density <= 1.0:
            raise ValueError(f"density must be in [0, 1], got {density}")
        sp = Sparsity(tuple(int(b) for b in block), ())
        grid = sp.grid(shape)
        total = 1
        for g in grid:
            total *= g
        nnz = min(total, max(1, round(density * total))) if density > 0 else 0
        rng = np.random.default_rng(seed)
        flat = rng.choice(total, size=nnz, replace=False)
        coords = tuple(tuple(int(i) for i in np.unravel_index(f, grid))
                       for f in sorted(flat))
        return Sparsity(sp.block, coords)


@dataclasses.dataclass(frozen=True)
class TensorAccess:
    """One tensor operand of the algebra.

    ``access`` has one row per tensor dimension and one column per loop
    iterator: ``index = access @ x``.
    """

    name: str
    access: Mat                    # (tensor_rank, n_loops) exact matrix
    is_output: bool = False

    def rank(self) -> int:
        return len(self.access)

    def index_of(self, x: Sequence[int]) -> Tuple[int, ...]:
        return linalg.as_int_tuple(linalg.matvec(self.access, list(x)))


@dataclasses.dataclass(frozen=True)
class TensorAlgebra:
    """A perfect loop nest computing ``output += prod(inputs)``."""

    name: str
    loops: Tuple[str, ...]               # iterator names, outermost first
    bounds: Tuple[int, ...]              # concrete loop trip counts
    tensors: Tuple[TensorAccess, ...]    # inputs first, output last
    #: per-tensor block-sparse operand form, sorted (name, Sparsity) pairs —
    #: a tuple (not a dict) so the algebra stays hashable and keeps working
    #: as the compile-cache / memoization key
    sparsity: Tuple[Tuple[str, Sparsity], ...] = ()

    def __post_init__(self):
        assert len(self.loops) == len(self.bounds)
        assert sum(t.is_output for t in self.tensors) == 1
        for t in self.tensors:
            for row in t.access:
                assert len(row) == len(self.loops), (self.name, t.name)
        names = {t.name for t in self.tensors}
        for tname, _ in self.sparsity:
            assert tname in names, (self.name, tname)

    # -- convenience ------------------------------------------------------
    @property
    def output(self) -> TensorAccess:
        return next(t for t in self.tensors if t.is_output)

    @property
    def inputs(self) -> Tuple[TensorAccess, ...]:
        return tuple(t for t in self.tensors if not t.is_output)

    def loop_index(self, name: str) -> int:
        return self.loops.index(name)

    def total_macs(self) -> int:
        n = 1
        for b in self.bounds:
            n *= b
        return n

    def with_bounds(self, **bounds: int) -> "TensorAlgebra":
        new = list(self.bounds)
        for k, v in bounds.items():
            new[self.loop_index(k)] = v
        return dataclasses.replace(self, bounds=tuple(new))

    # -- block-sparse operand form ----------------------------------------
    def with_sparsity(self, **per_tensor: Optional[Sparsity]
                      ) -> "TensorAlgebra":
        """Attach (or, with ``None``, remove) a block-sparse pattern to
        input tensors.  Patterns are validated against the current bounds:
        the block must tile the tensor shape exactly and every coordinate
        must lie inside the block grid."""
        cur = dict(self.sparsity)
        by_name = {t.name: t for t in self.tensors}
        for name, sp in per_tensor.items():
            t = by_name.get(name)
            if t is None:
                raise ValueError(f"{self.name} has no tensor {name!r}; "
                                 f"tensors: {sorted(by_name)}")
            if sp is None:
                cur.pop(name, None)
                continue
            if t.is_output:
                raise ValueError(
                    f"sparsity on output tensor {name!r} is unsupported "
                    "(outputs of a sum-of-products are dense in general)")
            sp.grid(self.tensor_shape(t))   # validates block/coords vs shape
            cur[name] = sp
        return dataclasses.replace(self, sparsity=tuple(sorted(cur.items())))

    def sparsity_of(self, name: str) -> Optional[Sparsity]:
        return dict(self.sparsity).get(name)

    @property
    def is_sparse(self) -> bool:
        return bool(self.sparsity)

    def density_of(self, name: str) -> float:
        """Block-level density of a tensor (1.0 when it has no pattern)."""
        sp = self.sparsity_of(name)
        if sp is None:
            return 1.0
        t = next(t for t in self.tensors if t.name == name)
        return sp.density(self.tensor_shape(t))

    def tensor_shape(self, t: TensorAccess) -> Tuple[int, ...]:
        """Bounding-box shape of a tensor given the loop bounds (affine
        accesses like y+p make a dim as large as the sum of the bounds)."""
        dims = []
        for row in t.access:
            hi = 0
            for coef, b in zip(row, self.bounds):
                c = int(coef)
                if c > 0:
                    hi += c * (b - 1)
                elif c < 0:
                    raise ValueError("negative access coefficients unsupported")
            dims.append(hi + 1)
        return tuple(dims)

    # -- reference evaluation ----------------------------------------------
    def reference(self, operands: Dict[str, np.ndarray]) -> np.ndarray:
        """Evaluate the loop nest directly in numpy (oracle; small bounds)."""
        out = np.zeros(self.tensor_shape(self.output),
                       dtype=np.result_type(*[v.dtype for v in operands.values()]))
        for x in itertools.product(*[range(b) for b in self.bounds]):
            prod = None
            for t in self.inputs:
                v = operands[t.name][t.index_of(x)]
                prod = v if prod is None else prod * v
            out[self.output.index_of(x)] += prod
        return out

    def random_operands(self, seed: int = 0) -> Dict[str, np.ndarray]:
        """Random integer operands; sparse tensors are zero outside their
        nonzero blocks, so ``reference`` on these operands *is* the masked
        dense oracle every sparse execution path validates against."""
        rng = np.random.default_rng(seed)
        out = {}
        for t in self.inputs:
            v = rng.integers(-4, 5, size=self.tensor_shape(t)).astype(np.int64)
            sp = self.sparsity_of(t.name)
            if sp is not None:
                v = v * sp.element_mask(self.tensor_shape(t))
            out[t.name] = v
        return out

    def random_sparse_inputs(self, seed: int = 0) -> Dict[str, np.ndarray]:
        """Deterministic operands honouring every attached block-sparse
        pattern (alias of ``random_operands``, which applies the masks
        whenever patterns are present — named per the sparse API surface)."""
        return self.random_operands(seed)


# ---------------------------------------------------------------------------
# Table II — the six evaluated tensor algebras
# ---------------------------------------------------------------------------

def _acc(loops: Sequence[str], rows: Sequence[Dict[str, int]]) -> Mat:
    return linalg.mat(
        [[row.get(l, 0) for l in loops] for row in rows]
    )


def gemm(m: int = 64, n: int = 64, k: int = 64) -> TensorAlgebra:
    """C[m,n] += A[m,k] * B[n,k]   (paper's GEMM layout)."""
    loops = ("m", "n", "k")
    return TensorAlgebra(
        name="gemm", loops=loops, bounds=(m, n, k),
        tensors=(
            TensorAccess("A", _acc(loops, [{"m": 1}, {"k": 1}])),
            TensorAccess("B", _acc(loops, [{"n": 1}, {"k": 1}])),
            TensorAccess("C", _acc(loops, [{"m": 1}, {"n": 1}]), is_output=True),
        ),
    )


def batched_gemv(m: int = 16, k: int = 64, n: int = 64) -> TensorAlgebra:
    """C[m,n] += A[m,k,n] * B[m,k].  Tensor A has no reuse (unicast only)."""
    loops = ("m", "n", "k")
    return TensorAlgebra(
        name="batched_gemv", loops=loops, bounds=(m, n, k),
        tensors=(
            TensorAccess("A", _acc(loops, [{"m": 1}, {"k": 1}, {"n": 1}])),
            TensorAccess("B", _acc(loops, [{"m": 1}, {"k": 1}])),
            TensorAccess("C", _acc(loops, [{"m": 1}, {"n": 1}]), is_output=True),
        ),
    )


def conv2d(k: int = 64, c: int = 64, y: int = 14, x: int = 14,
           p: int = 3, q: int = 3) -> TensorAlgebra:
    """C[k,y,x] += A[c,y+p,x+q] * B[k,c,p,q]."""
    loops = ("k", "c", "y", "x", "p", "q")
    return TensorAlgebra(
        name="conv2d", loops=loops, bounds=(k, c, y, x, p, q),
        tensors=(
            TensorAccess("A", _acc(loops, [{"c": 1}, {"y": 1, "p": 1},
                                           {"x": 1, "q": 1}])),
            TensorAccess("B", _acc(loops, [{"k": 1}, {"c": 1}, {"p": 1},
                                           {"q": 1}])),
            TensorAccess("C", _acc(loops, [{"k": 1}, {"y": 1}, {"x": 1}]),
                         is_output=True),
        ),
    )


def depthwise_conv(k: int = 64, y: int = 14, x: int = 14,
                   p: int = 3, q: int = 3) -> TensorAlgebra:
    """C[k,y,x] += A[k,y+p,x+q] * B[k,p,q].  No large reduction dim."""
    loops = ("k", "y", "x", "p", "q")
    return TensorAlgebra(
        name="depthwise_conv", loops=loops, bounds=(k, y, x, p, q),
        tensors=(
            TensorAccess("A", _acc(loops, [{"k": 1}, {"y": 1, "p": 1},
                                           {"x": 1, "q": 1}])),
            TensorAccess("B", _acc(loops, [{"k": 1}, {"p": 1}, {"q": 1}])),
            TensorAccess("C", _acc(loops, [{"k": 1}, {"y": 1}, {"x": 1}]),
                         is_output=True),
        ),
    )


def mttkrp(i: int = 32, j: int = 32, k: int = 16, l: int = 16) -> TensorAlgebra:
    """D[i,j] += A[i,k,l] * B[k,j] * C[l,j]."""
    loops = ("i", "j", "k", "l")
    return TensorAlgebra(
        name="mttkrp", loops=loops, bounds=(i, j, k, l),
        tensors=(
            TensorAccess("A", _acc(loops, [{"i": 1}, {"k": 1}, {"l": 1}])),
            TensorAccess("B", _acc(loops, [{"k": 1}, {"j": 1}])),
            TensorAccess("C", _acc(loops, [{"l": 1}, {"j": 1}])),
            TensorAccess("D", _acc(loops, [{"i": 1}, {"j": 1}]), is_output=True),
        ),
    )


def ttmc(i: int = 16, j: int = 16, k: int = 16, l: int = 16,
         m: int = 16) -> TensorAlgebra:
    """D[i,j,k] += A[i,l,m] * B[l,j] * C[m,k]."""
    loops = ("i", "j", "k", "l", "m")
    return TensorAlgebra(
        name="ttmc", loops=loops, bounds=(i, j, k, l, m),
        tensors=(
            TensorAccess("A", _acc(loops, [{"i": 1}, {"l": 1}, {"m": 1}])),
            TensorAccess("B", _acc(loops, [{"l": 1}, {"j": 1}])),
            TensorAccess("C", _acc(loops, [{"m": 1}, {"k": 1}])),
            TensorAccess("D", _acc(loops, [{"i": 1}, {"j": 1}, {"k": 1}]),
                         is_output=True),
        ),
    )


PAPER_ALGEBRAS = {
    "gemm": gemm,
    "batched_gemv": batched_gemv,
    "conv2d": conv2d,
    "depthwise_conv": depthwise_conv,
    "mttkrp": mttkrp,
    "ttmc": ttmc,
}


def get_algebra(name: str, **bounds) -> TensorAlgebra:
    return PAPER_ALGEBRAS[name](**bounds)
