"""Tensor-algebra IR: perfect nested loops + per-tensor linear access maps.

This is TensorLib's input language (paper §II, Table II).  A computation is

    out[I_out] += in1[I_1] * in2[I_2] * ...

where every index vector is a *linear* function of the loop iteration vector:
``I = A·x`` with an integer access matrix ``A``.  Affine accesses such as the
convolution's ``y + p`` are linear in the loop vector (a row with two ones),
so the whole of Table II fits without affine offsets.

The IR carries concrete loop bounds so the same object drives
  * exact dataflow classification (access matrices only),
  * the cycle-accurate-ish cost model (bounds),
  * a functional space-time simulator used to *prove* a schedule computes the
    right thing (tests), and
  * reference evaluation in numpy for oracle checks.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import linalg
from .linalg import Mat


@dataclasses.dataclass(frozen=True)
class TensorAccess:
    """One tensor operand of the algebra.

    ``access`` has one row per tensor dimension and one column per loop
    iterator: ``index = access @ x``.
    """

    name: str
    access: Mat                    # (tensor_rank, n_loops) exact matrix
    is_output: bool = False

    def rank(self) -> int:
        return len(self.access)

    def index_of(self, x: Sequence[int]) -> Tuple[int, ...]:
        return linalg.as_int_tuple(linalg.matvec(self.access, list(x)))


@dataclasses.dataclass(frozen=True)
class TensorAlgebra:
    """A perfect loop nest computing ``output += prod(inputs)``."""

    name: str
    loops: Tuple[str, ...]               # iterator names, outermost first
    bounds: Tuple[int, ...]              # concrete loop trip counts
    tensors: Tuple[TensorAccess, ...]    # inputs first, output last

    def __post_init__(self):
        assert len(self.loops) == len(self.bounds)
        assert sum(t.is_output for t in self.tensors) == 1
        for t in self.tensors:
            for row in t.access:
                assert len(row) == len(self.loops), (self.name, t.name)

    # -- convenience ------------------------------------------------------
    @property
    def output(self) -> TensorAccess:
        return next(t for t in self.tensors if t.is_output)

    @property
    def inputs(self) -> Tuple[TensorAccess, ...]:
        return tuple(t for t in self.tensors if not t.is_output)

    def loop_index(self, name: str) -> int:
        return self.loops.index(name)

    def total_macs(self) -> int:
        n = 1
        for b in self.bounds:
            n *= b
        return n

    def with_bounds(self, **bounds: int) -> "TensorAlgebra":
        new = list(self.bounds)
        for k, v in bounds.items():
            new[self.loop_index(k)] = v
        return dataclasses.replace(self, bounds=tuple(new))

    def tensor_shape(self, t: TensorAccess) -> Tuple[int, ...]:
        """Bounding-box shape of a tensor given the loop bounds (affine
        accesses like y+p make a dim as large as the sum of the bounds)."""
        dims = []
        for row in t.access:
            hi = 0
            for coef, b in zip(row, self.bounds):
                c = int(coef)
                if c > 0:
                    hi += c * (b - 1)
                elif c < 0:
                    raise ValueError("negative access coefficients unsupported")
            dims.append(hi + 1)
        return tuple(dims)

    # -- reference evaluation ----------------------------------------------
    def reference(self, operands: Dict[str, np.ndarray]) -> np.ndarray:
        """Evaluate the loop nest directly in numpy (oracle; small bounds)."""
        out = np.zeros(self.tensor_shape(self.output),
                       dtype=np.result_type(*[v.dtype for v in operands.values()]))
        for x in itertools.product(*[range(b) for b in self.bounds]):
            prod = None
            for t in self.inputs:
                v = operands[t.name][t.index_of(x)]
                prod = v if prod is None else prod * v
            out[self.output.index_of(x)] += prod
        return out

    def random_operands(self, seed: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed)
        return {
            t.name: rng.integers(-4, 5, size=self.tensor_shape(t)).astype(np.int64)
            for t in self.inputs
        }


# ---------------------------------------------------------------------------
# Table II — the six evaluated tensor algebras
# ---------------------------------------------------------------------------

def _acc(loops: Sequence[str], rows: Sequence[Dict[str, int]]) -> Mat:
    return linalg.mat(
        [[row.get(l, 0) for l in loops] for row in rows]
    )


def gemm(m: int = 64, n: int = 64, k: int = 64) -> TensorAlgebra:
    """C[m,n] += A[m,k] * B[n,k]   (paper's GEMM layout)."""
    loops = ("m", "n", "k")
    return TensorAlgebra(
        name="gemm", loops=loops, bounds=(m, n, k),
        tensors=(
            TensorAccess("A", _acc(loops, [{"m": 1}, {"k": 1}])),
            TensorAccess("B", _acc(loops, [{"n": 1}, {"k": 1}])),
            TensorAccess("C", _acc(loops, [{"m": 1}, {"n": 1}]), is_output=True),
        ),
    )


def batched_gemv(m: int = 16, k: int = 64, n: int = 64) -> TensorAlgebra:
    """C[m,n] += A[m,k,n] * B[m,k].  Tensor A has no reuse (unicast only)."""
    loops = ("m", "n", "k")
    return TensorAlgebra(
        name="batched_gemv", loops=loops, bounds=(m, n, k),
        tensors=(
            TensorAccess("A", _acc(loops, [{"m": 1}, {"k": 1}, {"n": 1}])),
            TensorAccess("B", _acc(loops, [{"m": 1}, {"k": 1}])),
            TensorAccess("C", _acc(loops, [{"m": 1}, {"n": 1}]), is_output=True),
        ),
    )


def conv2d(k: int = 64, c: int = 64, y: int = 14, x: int = 14,
           p: int = 3, q: int = 3) -> TensorAlgebra:
    """C[k,y,x] += A[c,y+p,x+q] * B[k,c,p,q]."""
    loops = ("k", "c", "y", "x", "p", "q")
    return TensorAlgebra(
        name="conv2d", loops=loops, bounds=(k, c, y, x, p, q),
        tensors=(
            TensorAccess("A", _acc(loops, [{"c": 1}, {"y": 1, "p": 1},
                                           {"x": 1, "q": 1}])),
            TensorAccess("B", _acc(loops, [{"k": 1}, {"c": 1}, {"p": 1},
                                           {"q": 1}])),
            TensorAccess("C", _acc(loops, [{"k": 1}, {"y": 1}, {"x": 1}]),
                         is_output=True),
        ),
    )


def depthwise_conv(k: int = 64, y: int = 14, x: int = 14,
                   p: int = 3, q: int = 3) -> TensorAlgebra:
    """C[k,y,x] += A[k,y+p,x+q] * B[k,p,q].  No large reduction dim."""
    loops = ("k", "y", "x", "p", "q")
    return TensorAlgebra(
        name="depthwise_conv", loops=loops, bounds=(k, y, x, p, q),
        tensors=(
            TensorAccess("A", _acc(loops, [{"k": 1}, {"y": 1, "p": 1},
                                           {"x": 1, "q": 1}])),
            TensorAccess("B", _acc(loops, [{"k": 1}, {"p": 1}, {"q": 1}])),
            TensorAccess("C", _acc(loops, [{"k": 1}, {"y": 1}, {"x": 1}]),
                         is_output=True),
        ),
    )


def mttkrp(i: int = 32, j: int = 32, k: int = 16, l: int = 16) -> TensorAlgebra:
    """D[i,j] += A[i,k,l] * B[k,j] * C[l,j]."""
    loops = ("i", "j", "k", "l")
    return TensorAlgebra(
        name="mttkrp", loops=loops, bounds=(i, j, k, l),
        tensors=(
            TensorAccess("A", _acc(loops, [{"i": 1}, {"k": 1}, {"l": 1}])),
            TensorAccess("B", _acc(loops, [{"k": 1}, {"j": 1}])),
            TensorAccess("C", _acc(loops, [{"l": 1}, {"j": 1}])),
            TensorAccess("D", _acc(loops, [{"i": 1}, {"j": 1}]), is_output=True),
        ),
    )


def ttmc(i: int = 16, j: int = 16, k: int = 16, l: int = 16,
         m: int = 16) -> TensorAlgebra:
    """D[i,j,k] += A[i,l,m] * B[l,j] * C[m,k]."""
    loops = ("i", "j", "k", "l", "m")
    return TensorAlgebra(
        name="ttmc", loops=loops, bounds=(i, j, k, l, m),
        tensors=(
            TensorAccess("A", _acc(loops, [{"i": 1}, {"l": 1}, {"m": 1}])),
            TensorAccess("B", _acc(loops, [{"l": 1}, {"j": 1}])),
            TensorAccess("C", _acc(loops, [{"m": 1}, {"k": 1}])),
            TensorAccess("D", _acc(loops, [{"i": 1}, {"j": 1}, {"k": 1}]),
                         is_output=True),
        ),
    )


PAPER_ALGEBRAS = {
    "gemm": gemm,
    "batched_gemv": batched_gemv,
    "conv2d": conv2d,
    "depthwise_conv": depthwise_conv,
    "mttkrp": mttkrp,
    "ttmc": ttmc,
}


def get_algebra(name: str, **bounds) -> TensorAlgebra:
    return PAPER_ALGEBRAS[name](**bounds)
