"""TensorLib core: Space-Time Transformation dataflow generation.

Public API:
    algebra.get_algebra / PAPER_ALGEBRAS  — Table II tensor algebras
    stt.apply_stt                          — STT matrix -> Dataflow
    stt.simulate                           — space-time functional simulator
    plan.plan_for                          — Dataflow -> kernel + collectives
    costmodel.PaperCycleModel              — paper Fig. 5/6 analytical model
    dse.enumerate_dataflows / sweep        — design-space exploration
    tpu.V5E / RooflineTerms                — target-hardware roofline model
"""
from . import algebra, costmodel, dse, linalg, plan, stt, tiling, tpu
from .algebra import PAPER_ALGEBRAS, Sparsity, TensorAlgebra, get_algebra
from .costmodel import ArrayConfig, CostReport, PaperCycleModel
from .plan import CommPlan, ExecutionPlan, KernelPlan, plan_for
from .stt import Dataflow, DataflowClass, InvalidSTT, apply_stt, simulate, stt_from_name
from .tpu import V5E, RooflineTerms, TpuSpec

__all__ = [
    "algebra", "costmodel", "dse", "linalg", "plan", "stt", "tiling", "tpu",
    "PAPER_ALGEBRAS", "Sparsity", "TensorAlgebra", "get_algebra",
    "ArrayConfig", "CostReport", "PaperCycleModel",
    "CommPlan", "ExecutionPlan", "KernelPlan", "plan_for",
    "Dataflow", "DataflowClass", "InvalidSTT", "apply_stt", "simulate",
    "stt_from_name", "V5E", "RooflineTerms", "TpuSpec",
]
