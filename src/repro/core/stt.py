"""Space-Time Transformation: dataflow generation (paper §II and §IV).

Given a tensor algebra and a full-rank integer matrix ``T`` over a selection
of ``n_space + 1`` loop iterators, every loop instance ``x`` is mapped to a
space-time point ``[p; t] = T · x``.  For each tensor with (selected-loop)
access matrix ``A``, the set of loop instances touching one element differs
by ``null(A)``, so the *reuse subspace* in space-time coordinates is

    R = T · null(A_sel)          (equivalent to the paper's Eq. (3))

Classification (paper Table I) is by ``rank(R)`` and the orientation of its
basis vectors ``(dp, dt)``:

    rank 0                      -> UNICAST
    rank 1, dp = 0, dt != 0     -> STATIONARY
    rank 1, dp != 0, dt != 0    -> SYSTOLIC   (direction dp, delay dt)
    rank 1, dp != 0, dt  = 0    -> MULTICAST (input) / REDUCTION tree (output)
    rank 2, plane ⊥ t-axis      -> BROADCAST              (2-D multicast)
    rank 2, t-axis ∈ plane      -> MULTICAST_STATIONARY
    rank 2, otherwise           -> SYSTOLIC_MULTICAST

All predicates are decided exactly over the rationals (see ``linalg``).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from . import linalg
from .algebra import TensorAlgebra
from .linalg import Mat, Vec


class DataflowClass(enum.Enum):
    UNICAST = "unicast"
    STATIONARY = "stationary"
    SYSTOLIC = "systolic"
    MULTICAST = "multicast"          # input tensors, rank-1, dt = 0
    REDUCTION = "reduction"          # output tensors, rank-1, dt = 0
    BROADCAST = "broadcast"                      # rank-2, plane ⊥ t-axis
    MULTICAST_STATIONARY = "multicast_stationary"  # rank-2, t-axis in plane
    SYSTOLIC_MULTICAST = "systolic_multicast"      # rank-2, intersecting

    @property
    def letter(self) -> str:
        """Single-letter code used in paper-style dataflow names."""
        return {
            DataflowClass.UNICAST: "U",
            DataflowClass.STATIONARY: "T",
            DataflowClass.SYSTOLIC: "S",
            DataflowClass.MULTICAST: "M",
            DataflowClass.REDUCTION: "M",   # paper folds reduction under M
            DataflowClass.BROADCAST: "B",
            DataflowClass.MULTICAST_STATIONARY: "B",
            DataflowClass.SYSTOLIC_MULTICAST: "B",
        }[self]

    @property
    def is_2d(self) -> bool:
        return self in (DataflowClass.BROADCAST,
                        DataflowClass.MULTICAST_STATIONARY,
                        DataflowClass.SYSTOLIC_MULTICAST)


@dataclasses.dataclass(frozen=True)
class TensorDataflow:
    """Classification result for one tensor under one STT."""

    tensor: str
    cls: DataflowClass
    # rank-1 (and the 1-D components of rank-2) carry a reuse direction:
    dp: Tuple[int, ...] = ()     # PE-array direction of movement
    dt: int = 0                  # cycle delay along dp
    # rank-2 cases carry the space-only (multicast/broadcast) direction too:
    dp_multicast: Tuple[int, ...] = ()
    reuse_rank: int = 0

    @property
    def signature(self) -> Tuple:
        return (self.cls.value, self.dp, self.dt, self.dp_multicast)


@dataclasses.dataclass(frozen=True)
class Dataflow:
    """A complete dataflow: STT matrix + per-tensor classification."""

    algebra_name: str
    selected: Tuple[str, ...]            # loop names mapped to (p..., t)
    T: Mat                               # (n_space+1) x (n_space+1), full rank
    tensors: Tuple[TensorDataflow, ...]  # same order as algebra.tensors

    @property
    def n_space(self) -> int:
        return len(self.selected) - 1

    @property
    def name(self) -> str:
        """Paper-style name, e.g. ``MNK-MMT`` (selected loops + letters,
        inputs in formula order then output)."""
        letters = "".join(t.cls.letter for t in self.tensors)
        return f"{''.join(self.selected).upper()}-{letters}"

    def by_tensor(self) -> Dict[str, TensorDataflow]:
        return {t.tensor: t for t in self.tensors}

    @property
    def signature(self) -> Tuple:
        """Hashable identity used to dedupe the design space: what hardware
        gets generated (classes + interconnect directions), not which T
        produced it."""
        return tuple(t.signature for t in self.tensors)


# ---------------------------------------------------------------------------
# Classification core
# ---------------------------------------------------------------------------

def classify_reuse(basis: Sequence[Vec], n_space: int,
                   is_output: bool) -> TensorDataflow:
    """Classify a reuse subspace given an exact basis in space-time coords."""
    rank = len(basis)
    if rank == 0:
        return TensorDataflow("", DataflowClass.UNICAST, reuse_rank=0)

    if rank == 1:
        v = linalg.integerize(basis[0])
        dp = linalg.as_int_tuple(v[:n_space])
        dt = int(v[n_space])
        # canonical orientation: positive delay (data flows forward in time)
        if dt < 0:
            dp = tuple(-d for d in dp)
            dt = -dt
        if all(d == 0 for d in dp):
            return TensorDataflow("", DataflowClass.STATIONARY, dp, dt,
                                  reuse_rank=1)
        if dt != 0:
            return TensorDataflow("", DataflowClass.SYSTOLIC, dp, dt,
                                  reuse_rank=1)
        cls = DataflowClass.REDUCTION if is_output else DataflowClass.MULTICAST
        return TensorDataflow("", cls, dp, dt, reuse_rank=1)

    if rank == 2:
        # space-only directions inside the plane: R ∩ {dt = 0}
        t_normal = tuple([Fraction(0)] * n_space + [Fraction(1)])
        space_only = linalg.intersect_with_hyperplane(basis, t_normal)
        if len(space_only) == 2:
            # plane is {dt = 0}: same element everywhere at the same cycle
            return TensorDataflow("", DataflowClass.BROADCAST,
                                  dp_multicast=linalg.as_int_tuple(
                                      space_only[0][:n_space]),
                                  reuse_rank=2)
        assert len(space_only) == 1, "2-D plane must meet {dt=0} in >=1 dim"
        mc_dir = linalg.as_int_tuple(space_only[0][:n_space])
        t_axis = tuple([Fraction(0)] * n_space + [Fraction(1)])
        if linalg.in_span(t_axis, basis):
            # plane parallel to (containing) the t-axis: broadcast to a PE
            # group, then each element stays put -> multicast + stationary
            return TensorDataflow("", DataflowClass.MULTICAST_STATIONARY,
                                  dp=tuple(0 for _ in range(n_space)), dt=1,
                                  dp_multicast=mc_dir, reuse_rank=2)
        # generic plane: broadcast + systolic traversal.  Pick the systolic
        # component as a basis vector independent of the multicast direction
        # with minimal |dt| (canonical).
        best: Optional[Tuple[Tuple[int, ...], int]] = None
        for c0, c1 in ((1, 0), (0, 1), (1, 1), (1, -1)):
            v = tuple(c0 * a + c1 * b for a, b in zip(basis[0], basis[1]))
            v = linalg.integerize(v)
            dt = int(v[n_space])
            if dt == 0:
                continue
            dp = linalg.as_int_tuple(v[:n_space])
            if dt < 0:
                dp, dt = tuple(-d for d in dp), -dt
            if best is None or dt < best[1]:
                best = (dp, dt)
        assert best is not None
        return TensorDataflow("", DataflowClass.SYSTOLIC_MULTICAST,
                              dp=best[0], dt=best[1],
                              dp_multicast=mc_dir, reuse_rank=2)

    raise ValueError(f"reuse subspace of rank {rank} exceeds the 2-D PE array "
                     "model (paper handles rank <= 2)")


# ---------------------------------------------------------------------------
# STT application
# ---------------------------------------------------------------------------

class InvalidSTT(ValueError):
    pass


@functools.lru_cache(maxsize=None)
def selection_nullspaces(alg: TensorAlgebra, selected: Tuple[str, ...]
                         ) -> Tuple[Tuple[str, bool, Tuple[Vec, ...]], ...]:
    """Per-tensor ``(name, is_output, null(A_sel))`` for one loop selection.

    The nullspace of the selected-loop access matrix does *not* depend on T
    — only its image under T does — so during design-space enumeration the
    (rref-heavy) nullspace computation is shared across every candidate T
    for a selection.  ``TensorAlgebra`` is a frozen dataclass of hashable
    tuples, so memoization on the algebra itself is exact.
    """
    cols = [alg.loop_index(s) for s in selected]
    out = []
    for t in alg.tensors:
        a_sel = linalg.submatrix_cols(t.access, cols)
        out.append((t.name, t.is_output, tuple(linalg.nullspace(a_sel))))
    return tuple(out)


@functools.lru_cache(maxsize=None)
def classify_reuse_cached(basis: Tuple[Vec, ...], n_space: int,
                          is_output: bool) -> TensorDataflow:
    """Memoized ``classify_reuse``: keyed on the transformed reuse basis.

    Many distinct T matrices induce the same space-time reuse basis; the
    rank-2 sub-case analysis (hyperplane intersections, span tests) then
    runs once per distinct basis instead of once per T.
    """
    return classify_reuse(list(basis), n_space, is_output)


def apply_stt(alg: TensorAlgebra, selected: Sequence[str],
              T: Mat) -> Dataflow:
    """Run TensorLib's dataflow-generation step (paper Fig. 2, left half).

    ``selected`` are the loop iterators mapped to space-time, ordered
    ``(p1, ..., pn, t)`` *before* transformation by ``T``;  the remaining
    loops run sequentially outside the PE array and do not affect the PE
    dataflow (paper §IV).
    """
    k = len(selected)
    if linalg.shape(T) != (k, k):
        raise InvalidSTT(f"T must be {k}x{k} for {k} selected loops")
    if linalg.det(T) == 0:
        raise InvalidSTT("T must be full rank (one-to-one space-time mapping)")
    n_space = k - 1

    out: List[TensorDataflow] = []
    for name, is_output, null in selection_nullspaces(alg, tuple(selected)):
        # reuse subspace in space-time coordinates: R = T · null(A_sel)
        basis = tuple(linalg.integerize(linalg.matvec(T, v)) for v in null)
        df = classify_reuse_cached(basis, n_space, is_output)
        out.append(dataclasses.replace(df, tensor=name))
    return Dataflow(alg.name, tuple(selected), T, tuple(out))


# ---------------------------------------------------------------------------
# Space-time execution simulator (validates the one-to-one mapping and that
# a schedule really computes the algebra — used by tests and the cost model)
# ---------------------------------------------------------------------------

def simulate(alg: TensorAlgebra, selected: Sequence[str], T: Mat):
    """Execute the loop nest in space-time order on a virtual PE array.

    Returns (result, n_cycles, pe_extent).  Raises if two operations collide
    on the same (PE, cycle) — which full-rank T must prevent — making this a
    direct check of the paper's one-to-one mapping claim.
    """
    import numpy as np

    cols = [alg.loop_index(s) for s in selected]
    outer = [i for i in range(len(alg.loops)) if i not in cols]
    n_space = len(selected) - 1

    operands = alg.random_operands()
    out = np.zeros(alg.tensor_shape(alg.output), dtype=np.int64)

    pts: Dict[Tuple, Tuple] = {}
    lo = [0] * n_space
    hi = [0] * n_space
    tmin, tmax = 0, 0
    for x in itertools.product(*[range(alg.bounds[c]) for c in cols]):
        st = linalg.as_int_tuple(linalg.matvec(T, list(x)))
        p, t = st[:n_space], st[n_space]
        for d in range(n_space):
            lo[d] = min(lo[d], p[d])
            hi[d] = max(hi[d], p[d])
        tmin, tmax = min(tmin, t), max(tmax, t)
        if (p, t) in pts:
            raise InvalidSTT(f"collision at PE {p} cycle {t}")
        pts[(p, t)] = x

    for x_outer in itertools.product(*[range(alg.bounds[i]) for i in outer]):
        for (p, t), x_sel in pts.items():
            full = [0] * len(alg.loops)
            for i, c in enumerate(cols):
                full[c] = x_sel[i]
            for i, c in enumerate(outer):
                full[c] = x_outer[i]
            prod = None
            for ten in alg.inputs:
                v = operands[ten.name][ten.index_of(full)]
                prod = v if prod is None else prod * v
            out[alg.output.index_of(full)] += prod

    pe_extent = tuple(h - l + 1 for l, h in zip(lo, hi))
    n_cycles = tmax - tmin + 1
    ref = alg.reference(operands)
    if not np.array_equal(out, ref):
        raise AssertionError("space-time execution diverged from reference")
    return out, n_cycles, pe_extent


# ---------------------------------------------------------------------------
# Named STT matrices for common dataflows (paper §VI naming scheme)
# ---------------------------------------------------------------------------

def stt_from_name(kind: str) -> Mat:
    """Classic 3-loop STTs.  With loops ordered (p1, p2, t)=(i, j, k) for
    GEMM these generate the canonical dataflows:

      identity      -> multicast/multicast/stationary   (MMT; SUMMA-like)
      output_stationary -> systolic/systolic/stationary (SST; TPU-style)
      weight_stationary -> A systolic, B stationary, C systolic (STS)
      input_stationary  -> A stationary, B systolic, C systolic (TSS)
    """
    I = linalg.mat
    return {
        "identity": I([[1, 0, 0], [0, 1, 0], [0, 0, 1]]),
        # skewed time makes operand reuse vectors pick up dt != 0 -> systolic.
        # For GEMM with loops (m, n, k): reuse(A)=e_n, reuse(B)=e_m,
        # reuse(C)=e_k, so the dataflow of each tensor is T's column for the
        # missing iterator: (0,0,dt) column -> that tensor is stationary.
        "output_stationary": I([[1, 0, 0], [0, 1, 0], [1, 1, 1]]),   # SST
        "weight_stationary": I([[0, 1, 0], [0, 0, 1], [1, 1, 1]]),   # STS
        "input_stationary": I([[1, 0, 0], [0, 0, 1], [1, 1, 1]]),    # TSS
    }[kind]
