"""Exact rational linear algebra for Space-Time Transformation analysis.

The dataflow classification predicates of TensorLib (``dt == 0``, ``dp == 0``,
subspace rank) must be decided *exactly* — floating point would misclassify
dataflows whose reuse vectors are small integers.  Everything here therefore
works over ``fractions.Fraction`` and returns canonical *integer* primitive
vectors where a direction is the answer.

Matrices are represented as tuples of tuples (immutable, hashable) so that
dataflow signatures can be used as dict keys during design-space enumeration.
"""
from __future__ import annotations

import math
from fractions import Fraction
from typing import Iterable, List, Sequence, Tuple

Vec = Tuple[Fraction, ...]
Mat = Tuple[Vec, ...]


# ---------------------------------------------------------------------------
# Construction helpers
# ---------------------------------------------------------------------------

def mat(rows: Iterable[Iterable]) -> Mat:
    """Build an exact matrix from any nested iterable of ints/Fractions."""
    return tuple(tuple(Fraction(v) for v in row) for row in rows)


def identity(n: int) -> Mat:
    return tuple(
        tuple(Fraction(1) if i == j else Fraction(0) for j in range(n))
        for i in range(n)
    )


def zeros(m: int, n: int) -> Mat:
    return tuple(tuple(Fraction(0) for _ in range(n)) for _ in range(m))


def shape(a: Mat) -> Tuple[int, int]:
    return (len(a), len(a[0]) if a else 0)


# ---------------------------------------------------------------------------
# Arithmetic
# ---------------------------------------------------------------------------

def matmul(a: Mat, b: Mat) -> Mat:
    (am, an), (bm, bn) = shape(a), shape(b)
    if an != bm:
        raise ValueError(f"matmul shape mismatch: {am}x{an} @ {bm}x{bn}")
    return tuple(
        tuple(sum((a[i][k] * b[k][j] for k in range(an)), Fraction(0))
              for j in range(bn))
        for i in range(am)
    )


def matvec(a: Mat, x: Sequence) -> Vec:
    (am, an) = shape(a)
    if an != len(x):
        raise ValueError(f"matvec shape mismatch: {am}x{an} @ {len(x)}")
    xv = [Fraction(v) for v in x]
    return tuple(sum((a[i][k] * xv[k] for k in range(an)), Fraction(0))
                 for i in range(am))


def transpose(a: Mat) -> Mat:
    m, n = shape(a)
    return tuple(tuple(a[i][j] for i in range(m)) for j in range(n))


def submatrix_cols(a: Mat, cols: Sequence[int]) -> Mat:
    """Select a subset of columns (used to restrict access matrices to the
    loop iterators chosen for space-time mapping)."""
    return tuple(tuple(row[c] for c in cols) for row in a)


# ---------------------------------------------------------------------------
# Gaussian elimination (exact)
# ---------------------------------------------------------------------------

def rref(a: Mat) -> Tuple[Mat, List[int]]:
    """Reduced row-echelon form.  Returns (R, pivot_columns)."""
    m, n = shape(a)
    rows = [list(r) for r in a]
    pivots: List[int] = []
    r = 0
    for c in range(n):
        if r >= m:
            break
        # find pivot
        piv = next((i for i in range(r, m) if rows[i][c] != 0), None)
        if piv is None:
            continue
        rows[r], rows[piv] = rows[piv], rows[r]
        inv = Fraction(1) / rows[r][c]
        rows[r] = [v * inv for v in rows[r]]
        for i in range(m):
            if i != r and rows[i][c] != 0:
                f = rows[i][c]
                rows[i] = [vi - f * vr for vi, vr in zip(rows[i], rows[r])]
        pivots.append(c)
        r += 1
    return tuple(tuple(row) for row in rows), pivots


def rank(a: Mat) -> int:
    return len(rref(a)[1])


def nullspace(a: Mat) -> List[Vec]:
    """Exact rational basis of the right nullspace of ``a``.

    Basis vectors are scaled to primitive integer vectors with a canonical
    sign so that reuse-direction comparisons are deterministic.
    """
    m, n = shape(a)
    if n == 0:
        return []
    r, pivots = rref(a)
    free = [c for c in range(n) if c not in pivots]
    basis: List[Vec] = []
    for fc in free:
        v = [Fraction(0)] * n
        v[fc] = Fraction(1)
        for i, pc in enumerate(pivots):
            v[pc] = -r[i][fc]
        basis.append(integerize(tuple(v)))
    return basis


def inverse(a: Mat) -> Mat:
    m, n = shape(a)
    if m != n:
        raise ValueError("inverse of non-square matrix")
    aug = tuple(tuple(list(a[i]) + list(identity(n)[i])) for i in range(n))
    r, pivots = rref(aug)
    if pivots != list(range(n)):
        raise ValueError("matrix is singular")
    return tuple(tuple(r[i][n:]) for i in range(n))


def det(a: Mat) -> Fraction:
    m, n = shape(a)
    if m != n:
        raise ValueError("determinant of non-square matrix")
    rows = [list(r) for r in a]
    d = Fraction(1)
    for c in range(n):
        piv = next((i for i in range(c, n) if rows[i][c] != 0), None)
        if piv is None:
            return Fraction(0)
        if piv != c:
            rows[c], rows[piv] = rows[piv], rows[c]
            d = -d
        d *= rows[c][c]
        inv = Fraction(1) / rows[c][c]
        for i in range(c + 1, n):
            if rows[i][c] != 0:
                f = rows[i][c] * inv
                rows[i] = [vi - f * vc for vi, vc in zip(rows[i], rows[c])]
    return d


def is_full_rank(a: Mat) -> bool:
    m, n = shape(a)
    return rank(a) == min(m, n)


# ---------------------------------------------------------------------------
# Vector utilities
# ---------------------------------------------------------------------------

def integerize(v: Vec) -> Vec:
    """Scale a rational vector to the primitive integer vector with canonical
    sign (first nonzero entry positive).  The zero vector maps to itself."""
    if all(x == 0 for x in v):
        return tuple(Fraction(0) for _ in v)
    lcm = 1
    for x in v:
        if x != 0:
            lcm = lcm * x.denominator // math.gcd(lcm, x.denominator)
    ints = [int(x * lcm) for x in v]
    g = 0
    for x in ints:
        g = math.gcd(g, abs(x))
    ints = [x // g for x in ints]
    first = next(x for x in ints if x != 0)
    if first < 0:
        ints = [-x for x in ints]
    return tuple(Fraction(x) for x in ints)


def in_span(v: Vec, basis: Sequence[Vec]) -> bool:
    """Exact membership test: is ``v`` in span(basis)?"""
    if all(x == 0 for x in v):
        return True
    if not basis:
        return False
    a = transpose(mat(list(basis)))
    aug = tuple(tuple(list(row) + [ve]) for row, ve in zip(a, v))
    return rank(a) == rank(aug)


def intersect_with_hyperplane(basis: Sequence[Vec], normal: Vec) -> List[Vec]:
    """Basis of span(basis) ∩ {x : normal·x = 0}.

    Used to find the space-only (dt = 0) directions inside a 2-D reuse plane,
    which decides the paper's three rank-2 sub-cases.
    """
    if not basis:
        return []
    # coefficients c s.t. sum_i c_i (normal · b_i) = 0
    dots = mat([[sum((n * b for n, b in zip(normal, bv)), Fraction(0))
                 for bv in basis]])
    coeff_basis = nullspace(dots)
    out: List[Vec] = []
    n = len(basis[0])
    for c in coeff_basis:
        v = [Fraction(0)] * n
        for ci, bv in zip(c, basis):
            for k in range(n):
                v[k] += ci * bv[k]
        out.append(integerize(tuple(v)))
    return out


def as_int_tuple(v: Vec) -> Tuple[int, ...]:
    """Convert an (already integral) exact vector to plain ints."""
    out = []
    for x in v:
        if x.denominator != 1:
            raise ValueError(f"vector {v} is not integral")
        out.append(int(x))
    return tuple(out)
