"""Analytical performance / area / power models (paper Fig. 5, Fig. 6).

Two models live here:

1. ``PaperCycleModel`` — reproduces the paper's evaluation setup: a 16x16 PE
   array at 320 MHz with 32 GB/s on-chip bandwidth between the scratchpad and
   the array (§VI-A).  We cannot synthesize RTL (deviation D2 in DESIGN.md),
   so cycles are derived from the space-time geometry the STT induces:

     * per-tile cycle count = time extent of the tile box under T (this is
       exact for box domains and automatically charges systolic dataflows
       their fill/drain skew — the paper's "pipeline overhead"),
     * bandwidth stalls  = max(1, demand / available) with per-tensor traffic
       from the access-matrix extents (unicast tensors are automatically
       charged full-volume traffic because their access map is injective),
     * PE under-utilization from small loop bounds, with packing of multiple
       copies when a bound is below the array dimension (the paper's
       "15 of 16 rows used when p = 3" effect).

2. Area/energy proxies for the design-space exploration (Fig. 6), using
   per-dataflow-module area units and per-element-movement energy, calibrated
   so the paper's qualitative findings hold (MMT/MMS cost the most energy,
   reduction trees are cheap, stationary modules cost area + control energy).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from . import linalg, tiling
from .algebra import TensorAlgebra
from .stt import Dataflow, DataflowClass
from .tiling import ArrayConfig  # re-export: historic home of ArrayConfig


@dataclasses.dataclass
class CostReport:
    dataflow_name: str
    cycles: float
    macs: int
    peak_macs: int                 # n_pes * cycles
    normalized_perf: float         # macs / peak  (paper Fig. 5 y-axis)
    utilization: float             # spatial utilization of the PE array
    bw_stall_factor: float
    fill_overhead_frac: float
    traffic_bytes: Dict[str, float]
    #: compressed-format index traffic per sparse tensor (block-COO
    #: coordinates moved alongside the payload); empty for dense algebras
    metadata_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: fraction of the loop nest's MACs that touch nonzero blocks
    #: (product of input-tensor block densities; 1.0 = dense)
    work_density: float = 1.0
    #: MACs the lowered kernel actually executes, from the LoweredForm's
    #: batched-matmul dims (batch * m * n * k, density-scaled on the BSR
    #: path).  Equal to ``macs`` for every registry algebra now that batch
    #: loops fold onto the Pallas grid instead of zero-padding the
    #: contraction; a ratio above 1.0 flags an execution path doing more
    #: work than the model prices (e.g. the masked-dense sparse fallback).
    executed_macs: int = 0
    area_units: float = 0.0
    power_mw: float = 0.0
    #: multi-chip terms, filled by ``mesh_evaluate`` from the solved
    #: :class:`~repro.core.plan.PartitionSolution`; zero / empty when the
    #: report was priced single-chip
    mesh_shape: Optional[Tuple[int, int]] = None
    mesh_strategy: str = ""
    per_device_macs: int = 0
    mesh_comm_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    mesh_cycles: float = 0.0
    #: median measured wall clock expressed at the model's frequency, when
    #: the measured autotuner (repro.tune) has timed this design; None =
    #: never measured.  Sits beside ``cycles`` so modeled vs measured is
    #: one report, not two code paths.
    measured_cycles: Optional[float] = None
    #: True when ``cycles`` (and everything derived from it: peak,
    #: normalized_perf, runtime_ms) was scaled by a fitted
    #: measured/model calibration (repro.tune.calibrate)
    calibrated: bool = False

    @property
    def executed_mac_ratio(self) -> float:
        """executed / priced MACs — 1.0 means the hardware does exactly
        the work the model charges for."""
        return self.executed_macs / self.macs if self.macs else 0.0

    @property
    def runtime_ms(self) -> float:
        return self.cycles / (320e6) * 1e3


# ---------------------------------------------------------------------------
# Geometry helpers — shared with the compiler, see core/tiling.py
# ---------------------------------------------------------------------------

_row_extent = tiling.row_extent
_is_unit_row = tiling.is_unit_row


@functools.lru_cache(maxsize=256)
def _lowered_form(alg: TensorAlgebra):
    """``alg``'s LoweredForm, or None when no lowering is registered.
    Memoized: the form is dataflow-independent, so one lookup serves
    every ``evaluate`` call of a DSE sweep (the hashable algebra is
    already the key all the other memoizations use)."""
    # lazy import: `repro.compile` depends on this module at load time, so
    # the reverse edge (mandated: executed MACs come *from the form* the
    # compiler runs, not from a parallel re-derivation) resolves at call
    # time only
    from ..compile.lowering import lower_form
    try:
        return lower_form(alg)
    except NotImplementedError:
        return None


def _lowered_executed_macs(alg: TensorAlgebra) -> Optional[int]:
    form = _lowered_form(alg)
    return None if form is None else form.executed_macs


# ---------------------------------------------------------------------------
# Cycle model
# ---------------------------------------------------------------------------

class PaperCycleModel:
    #: bytes per block-COO coordinate component (int32 indices)
    INDEX_BYTES = 4

    def __init__(self, cfg: ArrayConfig = ArrayConfig(),
                 density: Optional[float] = None,
                 calibration=None):
        """``density`` is a uniform input-operand density override used to
        rank dataflows for a target sparsity level *without* committing to
        a concrete pattern (``dse.search(..., density=...)``).  Tensors
        carrying an explicit :class:`~repro.core.algebra.Sparsity` always
        use their own block density instead.

        ``calibration`` is a fitted measured/model scale table (duck-typed
        on ``scale_for(template, algebra) -> float``; canonically a
        :class:`repro.tune.calibrate.Calibration`).  When given, every
        predicted cycle count is multiplied by the scale for the design's
        kernel template — the first-principles model times a machine
        correction — and reports carry ``calibrated=True``.  Scales are
        clamped positive by the fit, so calibrated cycles are positive
        whenever analytical cycles are, and same-template rankings are
        preserved."""
        if density is not None and not 0.0 < density <= 1.0:
            raise ValueError(f"density override must be in (0, 1], "
                             f"got {density}")
        if calibration is not None and not callable(
                getattr(calibration, "scale_for", None)):
            raise TypeError("calibration must expose "
                            "scale_for(template, algebra)")
        self.cfg = cfg
        self.density = density
        self.calibration = calibration

    def _calibration_scale(self, alg: TensorAlgebra, df: Dataflow) -> float:
        if self.calibration is None:
            return 1.0
        # the template is the plan layer's total function of the
        # classification — lazy import, same reverse edge as _lowered_form
        from . import plan as plan_mod
        template = plan_mod.kernel_plan_for(df).template
        return float(self.calibration.scale_for(template, alg.name))

    def _density_of(self, alg: TensorAlgebra, name: str,
                    is_output: bool) -> float:
        if is_output:
            return 1.0     # sum-of-products outputs are dense in general
        if alg.sparsity_of(name) is not None:
            return alg.density_of(name)
        return float(self.density) if self.density is not None else 1.0

    def _executed_macs(self, alg: TensorAlgebra, priced_macs: int) -> int:
        """MACs the lowered execution path performs, from the LoweredForm.

        The grid-folded lowerings make this equal the algebra's MACs for
        every registry algebra (the refactor's invariant, asserted by the
        registry-sweep test); algebras with no registered lowering have no
        execution path, so they are priced as themselves.
        """
        executed = _lowered_executed_macs(alg)
        return priced_macs if executed is None else executed

    # -- tiling -------------------------------------------------------------
    def _choose_tile(self, alg: TensorAlgebra, df: Dataflow
                     ) -> Tuple[List[int], Tuple[int, int], float]:
        """Delegates to the shared chooser (core/tiling.py) so the compiler
        and the cost model price/execute with identical tiles."""
        return tiling.choose_tile(alg, df, self.cfg.pe_dims)

    # -- traffic ------------------------------------------------------------
    def _tile_traffic(self, alg: TensorAlgebra, df: Dataflow,
                      tile: Sequence[int]
                      ) -> Tuple[Dict[str, float], Dict[str, float]]:
        """Bytes moved between scratchpad and array per tile, per tensor:
        ``(payload, metadata)``.

        Distinct elements touched by the tile box = product of index-extents
        (exact for box domains).  Multicast/broadcast reuse means an element
        is fetched once; unicast tensors have injective access so the same
        formula automatically yields full-volume traffic.

        Compressed-format terms: a block-sparse tensor only moves its
        nonzero blocks — payload scales by its block density — plus the
        block-COO coordinate list for the blocks the tile touches
        (``rank`` int32 indices per nonzero block).  The uniform
        ``density`` override scales payload only (no pattern, no concrete
        metadata layout to price).
        """
        cols = [alg.loop_index(s) for s in df.selected]
        by = df.by_tensor()
        out: Dict[str, float] = {}
        meta: Dict[str, float] = {}
        for t in alg.tensors:
            a_sel = linalg.submatrix_cols(t.access, cols)
            distinct = 1
            for row in a_sel:
                distinct *= _row_extent(row, tile)
            cls = by[t.name].cls
            factor = 1.0
            if t.is_output and cls not in (DataflowClass.STATIONARY,
                                           DataflowClass.MULTICAST_STATIONARY):
                # non-stationary outputs stream partial results every tile;
                # stationary outputs are written back once per reduction
                # (amortised below by only charging the final tile) — keep 1.
                factor = 1.0
            d = self._density_of(alg, t.name, t.is_output)
            out[t.name] = distinct * self.cfg.elem_bytes * factor * d
            sp = None if t.is_output else alg.sparsity_of(t.name)
            if sp is not None:
                block_elems = 1
                for b in sp.block:
                    block_elems *= b
                nnz_touched = d * distinct / block_elems
                meta[t.name] = nnz_touched * self.INDEX_BYTES * len(sp.block)
        return out, meta

    # -- main entry ----------------------------------------------------------
    def evaluate(self, alg: TensorAlgebra, df: Dataflow) -> CostReport:
        cols = [alg.loop_index(s) for s in df.selected]
        outer = [i for i in range(len(alg.loops)) if i not in cols]
        sel_bounds = [alg.bounds[c] for c in cols]

        tile, copies, util = self._choose_tile(alg, df)
        n_copies = copies[0] * copies[1]

        # time extent of one tile under T (includes systolic skew = fill)
        t_row = df.T[df.n_space]
        tile_cycles = _row_extent(t_row, tile)
        # the "pure compute" floor: MACs in the tile / spatially active PEs
        space_ext = math.prod(_row_extent(r, tile) for r in df.T[:df.n_space])
        compute_cycles = max(1, math.ceil(math.prod(tile) / max(1, space_ext)))
        fill = max(0, tile_cycles - compute_cycles)

        n_tiles_sel = 1
        for b, tb in zip(sel_bounds, tile):
            n_tiles_sel *= math.ceil(b / tb)
        n_outer = 1
        for i in outer:
            n_outer *= alg.bounds[i]
        # Fraction of stages whose blocks are all nonzero: a sparse-aware
        # array skips stages that hit a zero block of any sparse input
        # (independence approximation when several inputs are sparse).
        # This prices the *algebra's* compressed-format dataflow — what
        # the generated hardware would do.  The TPU realization only
        # skips blocks on the BSR path (`CompiledKernel.sparse_mode ==
        # "bsr"`); the masked-dense fallback executes dense and moves the
        # full operand — `executed_mac_ratio` > 1 reports exactly that
        # gap.
        work = 1.0
        for t in alg.inputs:
            work *= self._density_of(alg, t.name, False)
        # packed copies absorb outer/tile iterations
        n_stages = max(1, math.ceil(n_tiles_sel * n_outer / n_copies * work))

        traffic, meta = self._tile_traffic(alg, df, tile)
        tile_bytes = (sum(traffic.values()) + sum(meta.values())) * n_copies
        demand = tile_bytes / max(1, tile_cycles)
        stall = max(1.0, demand / self.cfg.bytes_per_cycle)

        cycles = n_stages * tile_cycles * stall
        # calibration applies before peak/normalized are derived, so every
        # downstream quantity tracks the corrected cycle count
        cycles *= self._calibration_scale(alg, df)
        macs = max(1, round(alg.total_macs() * work))
        peak = int(cycles * self.cfg.n_pes)
        report = CostReport(
            calibrated=self.calibration is not None,
            executed_macs=self._executed_macs(alg, macs),
            dataflow_name=df.name,
            cycles=cycles,
            macs=macs,
            peak_macs=peak,
            normalized_perf=macs / peak if peak else 0.0,
            utilization=util,
            bw_stall_factor=stall,
            fill_overhead_frac=fill / tile_cycles if tile_cycles else 0.0,
            traffic_bytes={k: v * n_stages * n_copies
                           for k, v in traffic.items()},
            metadata_bytes={k: v * n_stages * n_copies
                            for k, v in meta.items()},
            work_density=work,
        )
        report.area_units = self.area_units(alg, df)
        report.power_mw = self.power_mw(alg, df, report)
        return report

    # ------------------------------------------------------------------
    # Area / power proxies (Fig. 6) — unit-calibrated, see module docstring
    # ------------------------------------------------------------------
    #: per-PE area units for each dataflow module (Fig. 3 modules a..f)
    AREA_UNITS = {
        DataflowClass.SYSTOLIC: 2.0,              # reg + neighbour wire
        DataflowClass.STATIONARY: 3.6,            # double-buffer + control
        DataflowClass.MULTICAST: 1.0,             # wire tap
        DataflowClass.REDUCTION: 1.6,             # adder-tree share
        DataflowClass.UNICAST: 2.6,               # private memory port
        DataflowClass.BROADCAST: 1.4,
        DataflowClass.MULTICAST_STATIONARY: 4.4,  # tap + double buffer
        DataflowClass.SYSTOLIC_MULTICAST: 3.0,    # tap + reg
    }
    #: energy (pJ-equivalent units) per element delivered to a PE
    ENERGY_UNITS = {
        DataflowClass.SYSTOLIC: 1.0,              # one register hop
        DataflowClass.STATIONARY: 1.3,            # buffer write + control
        DataflowClass.MULTICAST: 1.9,             # long wire, high fanout
        DataflowClass.REDUCTION: 1.1,             # adder tree is cheap
        DataflowClass.UNICAST: 2.4,               # SRAM port per element
        DataflowClass.BROADCAST: 2.2,
        DataflowClass.MULTICAST_STATIONARY: 2.1,
        DataflowClass.SYSTOLIC_MULTICAST: 1.6,
    }
    MAC_AREA = 10.0
    MAC_ENERGY = 1.0
    #: calibration so the GEMM sweep lands in the paper's 35–63 mW range
    POWER_SCALE_MW = 0.08

    def area_units(self, alg: TensorAlgebra, df: Dataflow) -> float:
        per_pe = self.MAC_AREA
        for t in df.tensors:
            per_pe += self.AREA_UNITS[t.cls]
        return per_pe * self.cfg.n_pes

    def power_mw(self, alg: TensorAlgebra, df: Dataflow,
                 report: CostReport) -> float:
        """Average power = energy / cycle, scaled to mW at 320 MHz."""
        by = df.by_tensor()
        energy = report.macs * self.MAC_ENERGY
        for t in alg.tensors:
            # every MAC delivers/produces one element of each tensor to a PE
            energy += report.macs * self.ENERGY_UNITS[by[t.name].cls] * 0.35
        # scratchpad traffic energy
        for name, b in report.traffic_bytes.items():
            energy += (b / self.cfg.elem_bytes) * 0.8
        per_cycle = energy / max(1.0, report.cycles)
        return per_cycle * self.POWER_SCALE_MW


# ---------------------------------------------------------------------------
# Graph-level totals — fused vs unfused HBM accounting (repro.graph)
# ---------------------------------------------------------------------------

#: HBM <-> scratchpad bandwidth per 320 MHz cycle (≈32 GB/s, the paper's
#: off-array link §VI-A): the denominator for the traffic every
#: *materialized* graph edge pays and every fused edge saves
HBM_BYTES_PER_CYCLE = 100.0


@dataclasses.dataclass
class GraphCostReport:
    """Whole-graph cycle/byte totals for a planned :class:`AlgebraGraph`.

    ``hbm_bytes`` charges each materialized edge one write plus one read
    per unfused consumer (graph inputs are reads, the graph output a
    write, an unfused epilogue a full round trip);
    ``hbm_bytes_unfused`` re-prices the same plan with *every* fusion
    disabled — the honest baseline ``dse.search_graph`` ranks against.
    ``cycles`` = per-node compute cycles + HBM traffic cycles (+ mesh
    reshard traffic over the inter-chip link when planned on a mesh).
    """

    node_cycles: Dict[str, float]
    compute_cycles: float
    edge_bytes: Dict[str, float]            # per-edge HBM bytes charged
    hbm_bytes: float
    hbm_bytes_unfused: float
    fused_edges: Tuple[str, ...]            # "producer->consumer:edge"
    materialized_edges: Tuple[Tuple[str, str], ...]   # (edge desc, why)
    reshard_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    mesh_shape: Optional[Tuple[int, int]] = None
    #: edges a merged group exports from an intermediate stage for an
    #: out-of-group consumer ("group:edge"), and the HBM traffic those
    #: taps pay (the write plus every out-of-group read, already part
    #: of ``hbm_bytes`` — this attributes it)
    tapped_edges: Tuple[str, ...] = ()
    tap_hbm_bytes: float = 0.0

    @property
    def saved_hbm_bytes(self) -> float:
        return self.hbm_bytes_unfused - self.hbm_bytes

    @property
    def hbm_ratio(self) -> float:
        """unfused / fused HBM traffic (>1 = fusion saves bytes)."""
        return self.hbm_bytes_unfused / max(1.0, self.hbm_bytes)

    @property
    def hbm_cycles(self) -> float:
        return self.hbm_bytes / HBM_BYTES_PER_CYCLE

    @property
    def reshard_cycles(self) -> float:
        return sum(self.reshard_bytes.values()) / INTERCHIP_BYTES_PER_CYCLE

    @property
    def cycles(self) -> float:
        return self.compute_cycles + self.hbm_cycles + self.reshard_cycles

    @property
    def cycles_unfused(self) -> float:
        return (self.compute_cycles
                + self.hbm_bytes_unfused / HBM_BYTES_PER_CYCLE
                + self.reshard_cycles)

    @property
    def runtime_ms(self) -> float:
        return self.cycles / 320e6 * 1e3


# ---------------------------------------------------------------------------
# Multi-chip pricing — collective cost terms from the PartitionSolution
# ---------------------------------------------------------------------------

#: inter-chip link bandwidth per 320 MHz cycle (≈41 GB/s per direction —
#: ICI-class), the denominator for collective stall terms
INTERCHIP_BYTES_PER_CYCLE = 128.0


def mesh_evaluate(alg: TensorAlgebra, df: Dataflow,
                  shape: Tuple[int, int],
                  cfg: ArrayConfig = ArrayConfig(),
                  axes: Tuple[str, str] = ("x", "y"),
                  density: Optional[float] = None,
                  shard_batch: bool = True,
                  report: Optional[CostReport] = None) -> CostReport:
    """Single-chip evaluation plus multi-chip terms priced from the solved
    :class:`~repro.core.plan.PartitionSolution`.

    Per-device compute shrinks by the solver's ``macs_split`` (which is
    where the batch-shard speedup shows up); collective terms charge the
    bytes each device *receives* — per-hop shard bytes for rings and
    gathers, nnz-scaled payloads (plus block-COO metadata) for compressed
    sides, reduction hops for psum / staggered outputs.  ``mesh_cycles``
    = per-device compute cycles + collective cycles, the quantity
    ``dse.search(mesh=...)`` ranks by.  Pass ``report`` to reuse an
    already-computed single-chip evaluation (the DSE does: one model
    pass per candidate, not two).
    """
    from . import plan as plan_mod
    if report is None:
        report = PaperCycleModel(cfg, density=density).evaluate(alg, df)
    form = _lowered_form(alg)
    if form is None:
        return report
    comm = plan_mod.comm_plan_for(
        df, axes, densities={name: alg.density_of(name)
                             for name, _ in alg.sparsity})
    sol = plan_mod.solve_partition(comm, form, axes=axes, shape=shape,
                                   shard_batch=shard_batch)
    comm_bytes = sol.comm_bytes(form, cfg.elem_bytes)
    per_dev = sol.per_device_macs(form)
    compute_cycles = report.cycles * per_dev / max(1, form.executed_macs)
    comm_cycles = sum(comm_bytes.values()) / INTERCHIP_BYTES_PER_CYCLE
    return dataclasses.replace(
        report,
        mesh_shape=tuple(shape),
        mesh_strategy=sol.strategy,
        per_device_macs=sol.per_device_macs(form),
        mesh_comm_bytes=comm_bytes,
        mesh_cycles=compute_cycles + comm_cycles)
