"""Design-space exploration: enumerate STT matrices -> distinct dataflows.

The paper sweeps 148 GEMM dataflows and 33 depthwise-conv dataflows in a
16x16 array (Fig. 6).  Their enumeration universe is not spelled out; ours is
stated precisely:

  * loop selections: every ordered choice of 3 iterators out of the nest
    (order matters: the first two map to space, the last to time — but
    permutations of the two space rows produce mirrored hardware, so we
    canonicalize by sorting the space pair),
  * T entries in {-1, 0, 1}, det(T) != 0,
  * dedupe by ``Dataflow.signature`` (per-tensor class + interconnect
    directions) — two T's generating identical hardware count once.

Every enumerated point is costed with ``PaperCycleModel`` to produce the
area/power scatter (benchmarks/fig6_dse.py).

Fast path (ISSUE 1 tentpole item 4): the naive loop re-derived the
selected-loop nullspaces (one rref per tensor) and re-ran the full rank-2
classification for *every* candidate T.  Three facts make most of that
redundant:

  1. ``null(A_sel)`` is independent of T — computed once per selection
     (``stt.selection_nullspaces``), then only the cheap ``T @ v``
     transforms run per candidate.
  2. The full-rank filter over the T universe is selection-independent —
     the determinant sieve runs once per (entries, k) and is memoized.
  3. Candidates whose *transformed bases* repeat are duplicates by
     construction, so they are short-circuited before classification even
     starts; classification itself is memoized on the basis
     (``stt.classify_reuse_cached``).

``enumerate_dataflows_reference`` preserves the original per-T pipeline for
regression tests and A/B timing (benchmarks/fig6_dse.py --baseline).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from . import linalg, stt as stt_mod
from .algebra import TensorAlgebra
from .costmodel import ArrayConfig, CostReport, PaperCycleModel
from .stt import Dataflow, DataflowClass, InvalidSTT, apply_stt


@functools.lru_cache(maxsize=None)
def _full_rank_T(entries: Tuple[int, ...], k: int) -> Tuple[linalg.Mat, ...]:
    """All full-rank k x k matrices over ``entries`` (determinant sieve runs
    once per universe, not once per loop selection)."""
    return tuple(T for T, _ in _full_rank_T_pairs(entries, k))


@functools.lru_cache(maxsize=None)
def _full_rank_T_pairs(entries: Tuple[int, ...], k: int
                       ) -> Tuple[Tuple[linalg.Mat, Tuple[Tuple[int, ...],
                                                          ...]], ...]:
    """(exact Fraction matrix, plain-int rows) for every full-rank candidate.

    The int form feeds the enumeration hot loop: transforming integral
    nullspace vectors and hashing the result is ~10x faster in machine ints
    than in ``Fraction``.
    """
    out = []
    for flat in itertools.product(entries, repeat=k * k):
        rows = tuple(tuple(int(v) for v in flat[i * k:(i + 1) * k])
                     for i in range(k))
        T = linalg.mat(rows)
        if linalg.det(T) != 0:
            out.append((T, rows))
    return tuple(out)


def _canon_int(v: Tuple[int, ...]) -> Tuple[int, ...]:
    """Integer-only ``linalg.integerize``: primitive vector, first nonzero
    positive.  Exactly matches integerize() on integral input."""
    import math
    g = 0
    for x in v:
        g = math.gcd(g, abs(x))
    if g == 0:
        return v
    if g != 1:
        v = tuple(x // g for x in v)
    first = next(x for x in v if x)
    return tuple(-x for x in v) if first < 0 else v


@functools.lru_cache(maxsize=None)
def _classify_int(basis: Tuple[Tuple[int, ...], ...], n_space: int,
                  is_output: bool) -> stt_mod.TensorDataflow:
    """Classification memo keyed on plain-int bases (hot-loop friendly)."""
    from fractions import Fraction
    frac = tuple(tuple(Fraction(x) for x in b) for b in basis)
    return stt_mod.classify_reuse_cached(frac, n_space, is_output)


def enumerate_T(entries: Sequence[int] = (-1, 0, 1), k: int = 3
                ) -> Iterable[linalg.Mat]:
    """All full-rank k x k matrices with entries drawn from ``entries``."""
    yield from _full_rank_T(tuple(entries), k)


def loop_selections(alg: TensorAlgebra) -> List[Tuple[str, ...]]:
    """Ordered 3-loop selections with the space pair canonicalized."""
    sels = set()
    for combo in itertools.permutations(alg.loops, 3):
        space = tuple(sorted(combo[:2]))
        sels.add((space[0], space[1], combo[2]))
    return sorted(sels)


def is_realizable(df: Dataflow) -> bool:
    """Filter dataflows the paper's hardware templates cannot build:

    * systolic delay must be a small constant (|dt| <= 2 registers) and the
      hop must reach a neighbouring PE (|dp_i| <= 1),
    * an *output* tensor cannot be pure-multicast over time rank-2 shapes
      with no accumulation order (handled by REDUCTION tree for rank-1).
    """
    for t in df.tensors:
        if t.cls in (DataflowClass.SYSTOLIC, DataflowClass.SYSTOLIC_MULTICAST):
            if any(abs(d) > 1 for d in t.dp) or abs(t.dt) > 2:
                return False
        if t.cls in (DataflowClass.MULTICAST, DataflowClass.REDUCTION,
                     DataflowClass.BROADCAST):
            if any(abs(d) > 1 for d in (t.dp or ())):
                return False
    return True


def enumerate_dataflows(alg: TensorAlgebra,
                        selections: Optional[Sequence[Tuple[str, ...]]] = None,
                        entries: Sequence[int] = (-1, 0, 1),
                        realizable_only: bool = True,
                        ) -> Dict[Tuple, Dataflow]:
    """Map signature -> one representative Dataflow per distinct hardware.

    Fast path: per-selection nullspaces, memoized classification, and
    duplicate-basis short-circuiting (see module docstring).  Produces the
    same representative per signature as the reference implementation
    because candidates are visited in the same order.
    """
    out: Dict[Tuple, Dataflow] = {}
    sels = list(selections) if selections is not None else loop_selections(alg)
    for sel in sels:
        sel = tuple(sel)
        ns = stt_mod.selection_nullspaces(alg, sel)
        if any(len(null) > 2 for _, _, null in ns):
            # some tensor has a rank-3 reuse subspace under this selection
            # for *every* full-rank T — the whole selection is unbuildable
            # on a 2-D PE array (paper handles rank <= 2); skip it upfront.
            continue
        n_space = len(sel) - 1
        # integral nullspace vectors (nullspace() already integerizes)
        null_int = [tuple(linalg.as_int_tuple(v) for v in null)
                    for _, _, null in ns]
        seen_bases = set()
        for T, T_rows in _full_rank_T_pairs(tuple(entries), len(sel)):
            bases = tuple(
                tuple(_canon_int(tuple(sum(r * x for r, x in zip(row, v))
                                       for row in T_rows))
                      for v in null)
                for null in null_int)
            if bases in seen_bases:     # duplicate hardware: skip before
                continue                # classification ever runs
            seen_bases.add(bases)
            tensors = tuple(
                dataclasses.replace(
                    _classify_int(basis, n_space, is_output), tensor=name)
                for (name, is_output, _), basis in zip(ns, bases))
            df = Dataflow(alg.name, sel, T, tensors)
            if realizable_only and not is_realizable(df):
                continue
            key = (df.selected, df.signature)
            if key not in out:
                out[key] = df
    return out


def enumerate_dataflows_reference(
        alg: TensorAlgebra,
        selections: Optional[Sequence[Tuple[str, ...]]] = None,
        entries: Sequence[int] = (-1, 0, 1),
        realizable_only: bool = True,
        ) -> Dict[Tuple, Dataflow]:
    """The original (slow) enumeration: one full apply_stt per candidate T.

    Kept as the regression oracle for ``enumerate_dataflows`` and as the
    baseline for the DSE speedup measurement in benchmarks/fig6_dse.py.
    """
    out: Dict[Tuple, Dataflow] = {}
    sels = list(selections) if selections is not None else loop_selections(alg)
    for sel in sels:
        for flat in itertools.product(entries, repeat=len(sel) ** 2):
            k = len(sel)
            T = linalg.mat([flat[i * k:(i + 1) * k] for i in range(k)])
            if linalg.det(T) == 0:
                continue
            try:
                df = apply_stt(alg, sel, T)
            except (InvalidSTT, ValueError):
                continue
            if realizable_only and not is_realizable(df):
                continue
            key = (df.selected, df.signature)
            if key not in out:
                out[key] = df
    return out


def sweep_with_dataflows(alg: TensorAlgebra,
                         cfg: ArrayConfig = ArrayConfig(),
                         selections: Optional[Sequence[Tuple[str, ...]]]
                         = None,
                         density: Optional[float] = None,
                         calibration=None,
                         ) -> List[Tuple[CostReport, Dataflow]]:
    """Full DSE sweep, keeping the (report, dataflow) association.

    ``Dataflow.name`` is *not* unique across a sweep (hundreds of distinct
    T's share a letter combo), so consumers that need to act on a costed
    point — e.g. lower the pareto winner — must use this pairing rather
    than a name lookup.  ``density`` is the uniform input-density override
    (tensors with an explicit Sparsity pattern keep their own).
    ``calibration`` scales every prediction by the fitted measured/model
    ratio for its template (see ``PaperCycleModel``)."""
    model = PaperCycleModel(cfg, density=density, calibration=calibration)
    return [(model.evaluate(alg, df), df)
            for df in enumerate_dataflows(alg, selections).values()]


def sweep(alg: TensorAlgebra,
          cfg: ArrayConfig = ArrayConfig(),
          selections: Optional[Sequence[Tuple[str, ...]]] = None,
          density: Optional[float] = None,
          calibration=None,
          ) -> List[CostReport]:
    """Full DSE sweep: enumerate + cost every distinct dataflow."""
    return [r for r, _ in sweep_with_dataflows(alg, cfg, selections, density,
                                               calibration)]


def _mesh_shape(mesh) -> Tuple[int, int]:
    """Normalize a mesh argument: a (rows, cols) tuple or a
    ``jax.sharding.Mesh``."""
    if hasattr(mesh, "devices"):
        return tuple(mesh.devices.shape)
    s0, s1 = mesh
    return (int(s0), int(s1))


def search(alg: TensorAlgebra, top_k: int = 5,
           cfg: ArrayConfig = ArrayConfig(),
           selections: Optional[Sequence[Tuple[str, ...]]] = None,
           objective=None,
           density: Optional[float] = None,
           mesh=None,
           calibration=None,
           ) -> List[Tuple[CostReport, Dataflow]]:
    """Ranked design-space search: the DSE as an API the front door eats.

    Sweeps the design space and returns the ``top_k`` best ``(report,
    dataflow)`` pairs — pareto-optimal points first, then the rest, each
    group ordered by ``objective`` (default: cycles, then area, then
    power).  ``repro.generate(alg, search=...)`` consumes the result
    directly: candidates are lowered in rank order and the first one that
    validates becomes the accelerator.

    Sparse ranking: an algebra carrying :class:`~repro.core.algebra.
    Sparsity` patterns is priced with its per-tensor block densities and
    compressed-format traffic terms automatically; ``density`` applies a
    uniform input-density override instead when no pattern is attached.

    Multi-chip ranking: with ``mesh=`` (a ``jax.sharding.Mesh`` or a
    (rows, cols) shape) every candidate is priced by
    :func:`~repro.core.costmodel.mesh_evaluate` — per-device compute from
    the solved partition's spatial split plus collective stall terms —
    and ranked by ``mesh_cycles``: a dataflow that replicates less and
    ships smaller payloads wins even when its single-chip cycles tie.

    Calibrated ranking: ``calibration`` (a fitted measured/model scale
    table, ``repro.tune.calibrate``) re-prices every candidate with its
    template's machine-measured correction before ranking — the measured
    autotuner's feedback path into the analytical search.
    """
    pairs = sweep_with_dataflows(alg, cfg, selections, density, calibration)
    if mesh is not None:
        from .costmodel import mesh_evaluate
        shape = _mesh_shape(mesh)
        pairs = [(mesh_evaluate(alg, df, shape, cfg, density=density,
                                report=rep), df)
                 for rep, df in pairs]
        key = objective or (lambda r: (r.mesh_cycles, r.cycles,
                                       r.area_units, r.power_mw))
        ranked = sorted(pairs, key=lambda p: key(p[0]))
        return ranked[:top_k] if top_k else ranked
    key = objective or (lambda r: (r.cycles, r.area_units, r.power_mw))
    front_ids = {id(r) for r in pareto_front([r for r, _ in pairs])}
    ranked = sorted(pairs,
                    key=lambda p: (id(p[0]) not in front_ids, key(p[0])))
    return ranked[:top_k] if top_k else ranked


def _front2d_keep(group: List[Tuple[float, float, int]]) -> List[int]:
    """Indices of (area, power) points in ``group`` not strictly dominated
    within the group (<= on both and < on at least one)."""
    group = sorted(group)
    keep = []
    best_smaller_area = float("inf")   # min power over strictly smaller areas
    i = 0
    while i < len(group):
        # run of equal areas, sorted by power ascending
        j = i
        run_min_power = group[i][1]
        while j < len(group) and group[j][0] == group[i][0]:
            a, p, idx = group[j]
            # dominated by a strictly-smaller-area point with power <= p, or
            # by an equal-area point with strictly smaller power
            if p >= best_smaller_area or p > run_min_power:
                pass                    # dominated
            else:
                keep.append(idx)
            j += 1
        best_smaller_area = min(best_smaller_area, run_min_power)
        i = j
    return keep


class _Staircase:
    """Minimal (area, power) staircase: areas ascending, powers strictly
    descending.  Supports 'is any kept point <= (a, p) on both coords?'
    queries and insertions in O(log n) amortized."""

    def __init__(self):
        self.areas: List[float] = []
        self.powers: List[float] = []

    def dominates(self, area: float, power: float) -> bool:
        import bisect
        i = bisect.bisect_right(self.areas, area)
        return i > 0 and self.powers[i - 1] <= power

    def insert(self, area: float, power: float) -> None:
        import bisect
        if self.dominates(area, power):
            return
        i = bisect.bisect_left(self.areas, area)
        # drop kept points weakly dominated by the new one
        j = i
        while j < len(self.areas) and self.powers[j] >= power:
            j += 1
        self.areas[i:j] = [area]
        self.powers[i:j] = [power]


def pareto_front(reports: Sequence[CostReport]) -> List[CostReport]:
    """Pareto-optimal points over (cycles, area, power) — all minimized.

    Sort-based sweep instead of the old all-pairs O(n^2) scan: points are
    processed in (cycles, area, power) order, so a point can only be
    dominated by already-processed ones.  Strictly-smaller-cycle groups are
    summarized by a 2-D (area, power) staircase (weak dominance there
    implies strict dominance overall); equal-cycle groups are resolved with
    a 2-D front pass that honours the strictness requirement.
    """
    order = sorted(range(len(reports)),
                   key=lambda i: (reports[i].cycles, reports[i].area_units,
                                  reports[i].power_mw))
    stair = _Staircase()
    front_idx: List[int] = []
    i = 0
    while i < len(order):
        # group of equal cycles
        j = i
        c = reports[order[i]].cycles
        while j < len(order) and reports[order[j]].cycles == c:
            j += 1
        group = order[i:j]
        # vs earlier (strictly smaller cycles): weak 2-D dominance suffices
        alive = [gi for gi in group
                 if not stair.dominates(reports[gi].area_units,
                                        reports[gi].power_mw)]
        # vs same-cycle points: needs strictness in area or power
        survivors = _front2d_keep(
            [(reports[gi].area_units, reports[gi].power_mw, gi)
             for gi in alive])
        front_idx.extend(survivors)
        for gi in group:
            stair.insert(reports[gi].area_units, reports[gi].power_mw)
        i = j
    front_idx.sort()
    return [reports[i] for i in front_idx]


def pareto_front_reference(reports: Sequence[CostReport]
                           ) -> List[CostReport]:
    """Original all-pairs O(n^2) pareto scan — regression oracle."""
    front = []
    for r in reports:
        dominated = any(
            (o.cycles <= r.cycles and o.area_units <= r.area_units
             and o.power_mw <= r.power_mw)
            and (o.cycles < r.cycles or o.area_units < r.area_units
                 or o.power_mw < r.power_mw)
            for o in reports)
        if not dominated:
            front.append(r)
    return front


def search_graph(graph, search: int = 5,
                 cfg: ArrayConfig = ArrayConfig(),
                 mesh=None, dtype: str = "float32"):
    """Graph-level design-space search: per-node dataflow selection with
    inter-node agreement (``repro.graph.planner.plan_graph``).

    Extends :func:`search` from one algebra to an
    :class:`~repro.graph.ir.AlgebraGraph`: each node's candidates are
    ranked by their own compute cycles *plus* the HBM traffic the node's
    input edges would pay under that candidate — an edge that fuses with
    its already-planned producer (tile/partition agreement) costs
    nothing, so fused and unfused schedules compete honestly.  Returns
    the :class:`~repro.graph.planner.GraphPlan`; its ``cost_report()``
    carries the graph-level cycle/byte totals (``hbm_bytes`` vs
    ``hbm_bytes_unfused``) and ``mesh=`` adds the partition-agreement
    constraint with reshard pricing for disagreeing edges.
    """
    from ..graph.planner import plan_graph
    return plan_graph(graph, search=search, cfg=cfg, mesh=mesh,
                      dtype=dtype)
