"""Design-space exploration: enumerate STT matrices -> distinct dataflows.

The paper sweeps 148 GEMM dataflows and 33 depthwise-conv dataflows in a
16x16 array (Fig. 6).  Their enumeration universe is not spelled out; ours is
stated precisely:

  * loop selections: every ordered choice of 3 iterators out of the nest
    (order matters: the first two map to space, the last to time — but
    permutations of the two space rows produce mirrored hardware, so we
    canonicalize by sorting the space pair),
  * T entries in {-1, 0, 1}, det(T) != 0,
  * dedupe by ``Dataflow.signature`` (per-tensor class + interconnect
    directions) — two T's generating identical hardware count once.

Every enumerated point is costed with ``PaperCycleModel`` to produce the
area/power scatter (benchmarks/fig6_dse.py).
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from . import linalg
from .algebra import TensorAlgebra
from .costmodel import ArrayConfig, CostReport, PaperCycleModel
from .stt import Dataflow, DataflowClass, InvalidSTT, apply_stt


def enumerate_T(entries: Sequence[int] = (-1, 0, 1), k: int = 3
                ) -> Iterable[linalg.Mat]:
    """All full-rank k x k matrices with entries drawn from ``entries``."""
    for flat in itertools.product(entries, repeat=k * k):
        T = linalg.mat([flat[i * k:(i + 1) * k] for i in range(k)])
        if linalg.det(T) != 0:
            yield T


def loop_selections(alg: TensorAlgebra) -> List[Tuple[str, ...]]:
    """Ordered 3-loop selections with the space pair canonicalized."""
    sels = set()
    for combo in itertools.permutations(alg.loops, 3):
        space = tuple(sorted(combo[:2]))
        sels.add((space[0], space[1], combo[2]))
    return sorted(sels)


def is_realizable(df: Dataflow) -> bool:
    """Filter dataflows the paper's hardware templates cannot build:

    * systolic delay must be a small constant (|dt| <= 2 registers) and the
      hop must reach a neighbouring PE (|dp_i| <= 1),
    * an *output* tensor cannot be pure-multicast over time rank-2 shapes
      with no accumulation order (handled by REDUCTION tree for rank-1).
    """
    for t in df.tensors:
        if t.cls in (DataflowClass.SYSTOLIC, DataflowClass.SYSTOLIC_MULTICAST):
            if any(abs(d) > 1 for d in t.dp) or abs(t.dt) > 2:
                return False
        if t.cls in (DataflowClass.MULTICAST, DataflowClass.REDUCTION,
                     DataflowClass.BROADCAST):
            if any(abs(d) > 1 for d in (t.dp or ())):
                return False
    return True


def enumerate_dataflows(alg: TensorAlgebra,
                        selections: Optional[Sequence[Tuple[str, ...]]] = None,
                        entries: Sequence[int] = (-1, 0, 1),
                        realizable_only: bool = True,
                        ) -> Dict[Tuple, Dataflow]:
    """Map signature -> one representative Dataflow per distinct hardware."""
    out: Dict[Tuple, Dataflow] = {}
    sels = list(selections) if selections is not None else loop_selections(alg)
    for sel in sels:
        for T in enumerate_T(entries):
            try:
                df = apply_stt(alg, sel, T)
            except InvalidSTT:
                continue
            if realizable_only and not is_realizable(df):
                continue
            key = (df.selected, df.signature)
            if key not in out:
                out[key] = df
    return out


def sweep(alg: TensorAlgebra,
          cfg: ArrayConfig = ArrayConfig(),
          selections: Optional[Sequence[Tuple[str, ...]]] = None,
          ) -> List[CostReport]:
    """Full DSE sweep: enumerate + cost every distinct dataflow."""
    model = PaperCycleModel(cfg)
    reports = []
    for df in enumerate_dataflows(alg, selections).values():
        reports.append(model.evaluate(alg, df))
    return reports


def pareto_front(reports: Sequence[CostReport]
                 ) -> List[CostReport]:
    """Pareto-optimal points over (cycles, area, power) — all minimized."""
    front = []
    for r in reports:
        dominated = any(
            (o.cycles <= r.cycles and o.area_units <= r.area_units
             and o.power_mw <= r.power_mw)
            and (o.cycles < r.cycles or o.area_units < r.area_units
                 or o.power_mw < r.power_mw)
            for o in reports)
        if not dominated:
            front.append(r)
    return front
