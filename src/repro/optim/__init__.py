"""Optimizers (AdamW + 8-bit state)."""
from . import adamw
from .adamw import AdamWConfig, OptState
