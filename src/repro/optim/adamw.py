"""AdamW with global-norm clipping, cosine schedule, and an 8-bit
(block-quantized) optimizer-state option.

No optax in this container — implemented directly on pytrees.  The 8-bit
state keeps m/v as int8 with per-block (128-element) fp32 scales, cutting
optimizer HBM from 8 to ~2.06 bytes/param — this is what lets grok-1-314b
fit v5e-512 (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_bits: int = 32            # 32 or 8


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(
        jnp.pi * t))
    return cfg.lr * warm * cos


# ---------------------------------------------------------------------------
# 8-bit state codec (per-block absmax quantization)
# ---------------------------------------------------------------------------

_BLOCK = 128


def _q8_encode(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = -n % _BLOCK
    flat = jnp.pad(flat, (0, npad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _q8_decode(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = math.prod(shape)
    return flat[:n].reshape(shape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q8:
    """int8 moment + per-block scale; ``shape`` is static aux data so jit /
    sharding trees only see the two array leaves."""
    q: Any
    scale: Any
    shape: Tuple[int, ...]

    def tree_flatten(self):
        return (self.q, self.scale), tuple(self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


def _enc(x: jax.Array, bits: int):
    if bits == 32:
        return x
    q, s = _q8_encode(x)
    return Q8(q, s, tuple(x.shape))


def _dec(x, bits: int) -> jax.Array:
    if bits == 32:
        return x
    return _q8_decode(x.q, x.scale, x.shape)


# ---------------------------------------------------------------------------
# API
# ---------------------------------------------------------------------------

class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params: Any, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree.map(lambda p: _enc(jnp.zeros_like(p, jnp.float32),
                                        cfg.state_bits), params)
    z2 = jax.tree.map(lambda p: _enc(jnp.zeros_like(p, jnp.float32),
                                     cfg.state_bits), params)
    return OptState(jnp.zeros((), jnp.int32), zeros, z2)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params: Any, grads: Any, state: OptState,
                  cfg: AdamWConfig) -> Tuple[Any, OptState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    is_q8 = lambda x: isinstance(x, Q8)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mf = _dec(m, cfg.state_bits)
        vf = _dec(v, cfg.state_bits)
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        pnew = (p - lr * delta).astype(p.dtype)
        return pnew, _enc(mf, cfg.state_bits), _enc(vf, cfg.state_bits)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m, is_leaf=is_q8)
    flat_v = jax.tree.leaves(state.v, is_leaf=is_q8)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
