"""CommPlan-interpreter selftests (run in a fresh interpreter).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.dist.comm_selftest

Checks, on 8 fake CPU devices:
  * ``repro.generate(alg, mesh=square_submesh(2))`` is numerically correct
    (vs ``alg.reference`` *and* vs the single-chip CompiledKernel) for all
    six registry algebras under the default output-stationary dataflow —
    the multi-chip execution is driven by the generated CommPlan, not a
    hand-picked schedule function;
  * the classic schedules are recovered as special cases and match the
    hand-written engines kept as oracles: SUMMA = gemm x MMT (2x4 mesh),
    Cannon = gemm x SST (2x2), ring-reduce = gemm x a K-spatial STT;
  * a weight-stationary (hybrid single-ring) dataflow also executes
    correctly end-to-end.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

import repro
from repro.core import algebra, linalg, stt
from repro.dist import engine

#: small even bounds: the python loop-nest oracle stays fast and integer
#: operands keep the fp32 paths exact
SMALL_BOUNDS = {
    "gemm": dict(m=16, n=16, k=16),
    "batched_gemv": dict(m=4, k=8, n=8),
    "conv2d": dict(k=8, c=4, y=6, x=6, p=3, q=3),
    "depthwise_conv": dict(k=8, y=6, x=6, p=3, q=3),
    "mttkrp": dict(i=8, j=8, k=4, l=4),
    "ttmc": dict(i=4, j=4, k=4, l=4, m=4),
}

#: a K-spatial GEMM STT: space = (k, n), time = m -> C is a reduction
#: (psum) output, B stationary, A multicast — the ring-reduce family
K_SPATIAL_T = linalg.mat([[0, 0, 1], [0, 1, 0], [1, 0, 0]])


def check_all_algebras() -> None:
    sq = engine.square_submesh(2)
    for name in sorted(algebra.PAPER_ALGEBRAS):
        alg = algebra.get_algebra(name, **SMALL_BOUNDS[name])
        acc = repro.generate(alg)                     # output-stationary
        sharded = acc.sharded(sq)
        operands = alg.random_operands(seed=3)
        want = alg.reference(operands)
        single = np.asarray(acc(operands)).round().astype(np.int64)
        multi = np.asarray(sharded(operands)).round().astype(np.int64)
        np.testing.assert_array_equal(single, want)
        np.testing.assert_array_equal(multi, want)
        kinds = {t.tensor: t.kind for t in acc.plan.comm.tensors}
        sol = sharded.partition
        # no silent replication: the solver must shard every input side,
        # and fold batch grid dims onto a mesh axis instead of
        # replicating them
        assert not sol.replicated_inputs(), (
            f"{name}: inputs {sol.replicated_inputs()} fell back to "
            f"replication (partition {sol.describe()})")
        if acc.kernel.form.batch:
            assert sol.batch_axis is not None, (
                f"{name}: batch dim replicated (partition "
                f"{sol.describe()})")
        print(f"{name:15s} comm={kinds} strategy={sol.strategy} "
              f"batch_axis={sol.batch_axis}: "
              f"sharded == single == reference")


def check_classic_oracles() -> None:
    g = algebra.gemm(32, 32, 32)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    operands = {"A": a, "B": b}         # C = A @ B^T (paper GEMM layout)

    mesh24 = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("x", "y"))
    sq = engine.square_submesh(2)

    # SUMMA is gemm x MMT: parity with the hand-written oracle on 2x4
    acc = repro.generate(g, "identity", mesh=mesh24, validate=False)
    assert acc._program().strategy == "summa", acc._program()
    want = np.asarray(engine.summa_matmul(a, jnp.transpose(b), mesh24))
    np.testing.assert_allclose(np.asarray(acc(operands)), want,
                               rtol=1e-4, atol=1e-4)
    print("summa-as-oracle: generate(gemm, MMT) == summa_matmul (2x4)")

    # Cannon is gemm x SST: parity on the square 2x2 submesh
    acc = repro.generate(g, "output_stationary", mesh=sq, validate=False)
    assert acc._program().strategy == "cannon", acc._program()
    want = np.asarray(engine.cannon_matmul(a, jnp.transpose(b), sq))
    np.testing.assert_allclose(np.asarray(acc(operands)), want,
                               rtol=1e-4, atol=1e-4)
    print("cannon-as-oracle: generate(gemm, SST) == cannon_matmul (2x2)")

    # ring-reduce is gemm x a K-spatial STT (psum output)
    df = stt.apply_stt(g, ("m", "n", "k"), K_SPATIAL_T)
    kinds = {t.tensor: t.kind for t in repro.generate(
        g, df, validate=False).plan.comm.tensors}
    assert kinds["C"] == "psum", kinds
    acc = repro.generate(g, df, mesh=mesh24, validate=False)
    assert acc._program().strategy.startswith("k_spatial"), acc._program()
    want = np.asarray(engine.ring_reduce_matmul(a, jnp.transpose(b), mesh24))
    np.testing.assert_allclose(np.asarray(acc(operands)), want,
                               rtol=1e-4, atol=1e-4)
    print("ring-reduce-as-oracle: generate(gemm, K-spatial) == "
          "ring_reduce_matmul (2x4)")

    # hybrid: weight-stationary (STS) — B resident, A systolic, C on an
    # output ring; no hand-written engine ever existed for this one
    acc = repro.generate(g, "weight_stationary", mesh=sq, validate=False)
    err = acc.validate(seed=5)
    print(f"hybrid STS executes from its CommPlan (max err {err:.1e})")


def main() -> None:
    assert len(jax.devices()) >= 8, "comm selftest needs 8 fake devices"
    check_all_algebras()
    check_classic_oracles()
    print("ALL COMM-ENGINE SELFTESTS PASSED")


if __name__ == "__main__":
    main()
