"""Paged-cache mesh placement selftest (run in a fresh interpreter).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.dist.serve_selftest

Checks, on 8 fake devices:
  * ``solve_page_placement`` routes the decode-attention algebra
    (batched_gemv) through the partition solver and yields a page-axis
    PartitionSpec on the batch-carrying mesh axis;
  * ``place_pools`` shards every page pool over that axis (page axis
    padded to the axis size, scratch page preserved);
  * continuous decode over the SHARDED pools stays bit-identical to the
    unsharded slot engine, insert/evict churn included.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.models import init_params, split
from repro.serve import SlotEngine, place_pools, solve_page_placement


def _drive(eng, prompts, steps=6):
    """Insert two requests, decode, evict one mid-flight, decode on —
    returns the packed per-step results."""
    out = []
    eng.insert(prompts[0], max_new_tokens=steps + 1)
    eng.insert(prompts[1], max_new_tokens=steps + 1)
    for t in range(steps):
        out.append(np.asarray(eng.step().data))
        if t == steps // 2:
            eng.evict(1)                   # churn: no drain, no recompile
    return out


def main() -> None:
    assert len(jax.devices()) >= 8, "selftest needs 8 fake devices"
    cfg = get_config("granite-8b").reduced()
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (s,)).astype(np.int32)
               for s in (9, 14)]

    def build():
        return SlotEngine(params, cfg, capacity=4, max_context=32,
                          page_size=8)

    want = _drive(build(), prompts)

    eng = build()
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("x", "y"))
    sol, spec = solve_page_placement(cfg, eng.cache.layout,
                                     axes=("x", "y"), shape=(2, 4))
    assert spec[0] in ("x", "y") and spec[1] is None and spec[2] is None, (
        spec)
    print(f"page placement: strategy={sol.strategy} spec={spec}")

    place_pools(eng.cache, mesh, spec)
    axis = dict(zip(mesh.axis_names, mesh.devices.shape))[spec[0]]
    for path, pool in eng.cache.pools.items():
        assert pool.shape[0] % axis == 0, (path, pool.shape)
        assert not pool.sharding.is_fully_replicated, path
    print(f"pools sharded over '{spec[0]}' "
          f"({len(eng.cache.pools)} pools, page axis padded to x{axis})")

    got = _drive(eng, prompts)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    print(f"sharded continuous decode bit-matches unsharded "
          f"({len(got)} steps)")

    # the no-recompile contract under sharding: jit legitimately re-keys
    # while pool shardings settle on the first steps, but once steady,
    # insert/evict churn must not add entries — and results must repeat.
    for slot in eng.live_slots():
        eng.evict(slot)
    steady = eng.decode_compiles
    got2 = _drive(eng, prompts)
    for g, w in zip(got2, want):
        np.testing.assert_array_equal(g, w)
    assert eng.decode_compiles == steady, (
        (steady, eng.decode_compiles))
    print(f"insert/evict churn on the sharded engine: compiles stable "
          f"at {steady}")
    print("serve placement selftest OK")


if __name__ == "__main__":
    main()
