"""Hand-written classic GEMM schedules — kept as test oracles.

Production mesh execution goes through the generic CommPlan interpreter
(``comm_engine.compile_comm_plan``, what ``repro.generate(...).sharded``
runs); these three hand-written schedules survive because they are
independently-derived realizations of the classic algorithms the
interpreter must recover as special cases:

    summa_matmul        = what gemm x MMT must compute
    cannon_matmul       = what gemm x SST must compute
    ring_reduce_matmul  = what gemm x a K-spatial STT must compute

``repro.dist.comm_selftest`` asserts that parity on fake devices.  Mesh
axes are ("x", "y") — the chip-level analogue of the paper's 2-D PE
array.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .. import jax_compat


def square_submesh(n: int = 2) -> Mesh:
    """An (n, n) mesh over the first n*n devices (Cannon needs square)."""
    devs = np.asarray(jax.devices()[:n * n]).reshape(n, n)
    return Mesh(devs, ("x", "y"))


def summa_matmul(a: jax.Array, b: jax.Array, mesh: Mesh) -> jax.Array:
    """SUMMA (MMT-class: inputs all_gather, output sharded/stationary).

    Both operands are fully sharded over the mesh; each (i, j) chip
    all_gathers A's row panel along y and B's column panel along x —
    the mesh realization of the multicast wires — then computes its
    resident C block with zero further communication.
    """
    def body(a_blk, b_blk):
        a_row = jax.lax.all_gather(a_blk, "y", axis=1, tiled=True)
        b_col = jax.lax.all_gather(b_blk, "x", axis=0, tiled=True)
        return jnp.dot(a_row, b_col, preferred_element_type=jnp.float32
                       ).astype(a_blk.dtype)

    return jax_compat.shard_map(
        body, mesh=mesh, in_specs=(P("x", "y"), P("x", "y")),
        out_specs=P("x", "y"))(a, b)


def ring_reduce_matmul(a: jax.Array, b: jax.Array, mesh: Mesh) -> jax.Array:
    """Reduction-class schedule (K spatial: output psum, operands sharded).

    The contraction dimension is sharded over the whole mesh; every chip
    computes a full-size partial product and the reduction tree becomes a
    single psum over both axes.
    """
    def body(a_blk, b_blk):
        partial = jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32)
        return jax.lax.psum(partial, ("x", "y")).astype(a_blk.dtype)

    return jax_compat.shard_map(
        body, mesh=mesh, in_specs=(P(None, ("x", "y")), P(("x", "y"), None)),
        out_specs=P(None, None))(a, b)


def _skew_blocks(m: jax.Array, s: int, axis: int, by_axis: int) -> jax.Array:
    """Cannon's initial alignment: roll block row/col ``i`` by ``i`` blocks
    (done on the global array; the steady-state rotation is the systolic
    ppermute ring inside the shard_map)."""
    blocks = np.split(np.asarray(m), s, axis=by_axis)
    rolled = [np.roll(blk, -i * (m.shape[axis] // s), axis=axis)
              for i, blk in enumerate(blocks)]
    return jnp.asarray(np.concatenate(rolled, axis=by_axis))


def cannon_matmul(a: jax.Array, b: jax.Array, mesh: Mesh) -> jax.Array:
    """Cannon (SST-class: inputs on ppermute rings, output stationary).

    Blocks of A circulate left along x-rows and blocks of B circulate up
    along y-columns — the chip-mesh realization of the systolic
    nearest-neighbour wires — while each chip's C block stays resident.
    """
    s = mesh.devices.shape[0]
    assert mesh.devices.shape == (s, s), "Cannon needs a square mesh"
    a = _skew_blocks(a, s, axis=1, by_axis=0)   # row i left by i blocks
    b = _skew_blocks(b, s, axis=0, by_axis=1)   # col j up by j blocks
    left = [(j, (j - 1) % s) for j in range(s)]
    up = [(i, (i - 1) % s) for i in range(s)]

    def body(a_blk, b_blk):
        def step(t, carry):
            a_c, b_c, acc = carry
            acc = acc + jnp.dot(a_c, b_c,
                                preferred_element_type=jnp.float32)
            a_c = jax.lax.ppermute(a_c, "y", left)
            b_c = jax.lax.ppermute(b_c, "x", up)
            return a_c, b_c, acc

        acc = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)
        _, _, acc = jax.lax.fori_loop(0, s, step, (a_blk, b_blk, acc))
        return acc.astype(a_blk.dtype)

    return jax_compat.shard_map(
        body, mesh=mesh, in_specs=(P("x", "y"), P("x", "y")),
        out_specs=P("x", "y"), check_vma=False)(a, b)
