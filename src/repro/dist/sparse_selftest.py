"""Sparse-accelerator mesh-parity selftest (run in a fresh interpreter).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.dist.sparse_selftest

On 8 fake CPU devices: a block-sparse GEMM accelerator bound to a 2x2
mesh must match both the masked dense oracle (``alg.reference`` on
masked operands) and the single-chip BSR kernel, across several
densities.  The mesh path runs the CommPlan-prescribed collectives on
the *masked dense* operand form (`Accelerator.sharded`'s documented
dense-replication fallback), so parity here proves the fallback is
exact, not merely approximate.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

import repro
from repro.core.algebra import Sparsity, gemm
from repro.dist import engine


def check_sparse_mesh_parity() -> None:
    mesh = engine.square_submesh(2)
    alg = gemm(16, 16, 16)
    for density in (0.25, 0.5, 1.0):
        sp = Sparsity.random((16, 16), (4, 4), density, seed=7)
        acc = repro.generate(alg.with_sparsity(A=sp), interpret=True)
        assert acc.kernel.sparse_mode == "bsr", acc.kernel.sparse_mode
        sharded = acc.sharded(mesh)
        operands = acc.algebra.random_sparse_inputs(seed=11)
        want = acc.algebra.reference(operands)
        single = np.asarray(acc(operands)).round().astype(np.int64)
        multi = np.asarray(sharded(operands)).round().astype(np.int64)
        np.testing.assert_array_equal(single, want)
        np.testing.assert_array_equal(multi, want)
        comm = acc.plan.comm.by_tensor()["A"]
        assert abs(comm.density - density) < 1e-9, comm
        print(f"sparse-mesh-parity density={density:.2f} "
              f"comm={comm.kind} OK")


def main() -> None:
    import jax

    n = len(jax.devices())
    assert n >= 8, f"need 8 fake devices, got {n} (set XLA_FLAGS before jax)"
    check_sparse_mesh_parity()
    print("ALL SPARSE MESH SELFTESTS PASSED")


if __name__ == "__main__":
    main()
