"""Sparse-accelerator mesh-parity selftest (run in a fresh interpreter).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.dist.sparse_selftest

On 8 fake CPU devices: a block-sparse GEMM accelerator bound to a 2x2
mesh must match both the masked dense oracle (``alg.reference`` on
masked operands) and the single-chip BSR kernel, across several
densities.  Since the unified-partition refactor the mesh path ships the
operand **compressed** (per-device BSR payload + block-COO coordinates
through the CommPlan collectives — the solver reports ``compressed``);
the masked-dense baseline (``sparse='dense'``) is exercised alongside to
prove both paths are exact and that the compressed footprint is the
smaller one.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

import repro
from repro.core.algebra import Sparsity, gemm
from repro.dist import engine


def check_sparse_mesh_parity() -> None:
    mesh = engine.square_submesh(2)
    alg = gemm(16, 16, 16)
    for density in (0.25, 0.5, 1.0):
        sp = Sparsity.random((16, 16), (4, 4), density, seed=7)
        acc = repro.generate(alg.with_sparsity(A=sp), interpret=True)
        assert acc.kernel.sparse_mode == "bsr", acc.kernel.sparse_mode
        sharded = acc.sharded(mesh)                   # compressed (default)
        baseline = acc.sharded(mesh, sparse="dense")  # masked-dense
        sol = sharded.partition
        assert sol.lhs.compressed, sol.describe()
        operands = acc.algebra.random_sparse_inputs(seed=11)
        want = acc.algebra.reference(operands)
        single = np.asarray(acc(operands)).round().astype(np.int64)
        np.testing.assert_array_equal(single, want)
        for a in (sharded, baseline):
            multi = np.asarray(a(operands)).round().astype(np.int64)
            np.testing.assert_array_equal(multi, want)
        form = acc.kernel.form
        comp_b = sol.per_device_bytes(form)["lhs"]
        dense_b = baseline.partition.per_device_bytes(form)["lhs"]
        if density < 1.0:
            assert comp_b < dense_b, (comp_b, dense_b)
        comm = acc.plan.comm.by_tensor()["A"]
        assert abs(comm.density - density) < 1e-9, comm
        print(f"sparse-mesh-parity density={density:.2f} "
              f"comm={comm.kind} compressed={comp_b:.0f}B/dev "
              f"dense={dense_b:.0f}B/dev OK")


def main() -> None:
    import jax

    n = len(jax.devices())
    assert n >= 8, f"need 8 fake devices, got {n} (set XLA_FLAGS before jax)"
    check_sparse_mesh_parity()
    print("ALL SPARSE MESH SELFTESTS PASSED")


if __name__ == "__main__":
    main()
