"""Generic CommPlan interpreter: any generated CommPlan -> shard_map.

The previous ``dist/engine.py`` shipped three hand-written, GEMM-only
schedules (SUMMA / Cannon / ring-reduce) the user had to pick by name.
This module replaces them with a *compiler*: ``compile_comm_plan`` takes
the CommPlan that ``plan.comm_plan_for`` generated from the dataflow
classification plus the algebra's :class:`~repro.compile.LoweredForm`, and
emits a shard_map program over a 2-D device mesh — the chip-level
realization of the paper's claim that one transformation matrix yields the
complete accelerator, module selection *and connection*.

Per-tensor collective kinds map onto shard_map structure:

    shard          fully partitioned in/out specs, no collective
    stream         fully partitioned (unicast: no reuse to exploit)
    all_gather     stored k-split, ``jax.lax.all_gather`` inside the body
    ppermute_ring  stored k-split + skewed, rotated by ``jax.lax.ppermute``
                   inside a ``fori_loop`` (the systolic wires, chip-scale)
    psum           output partial over the reduction axes, one ``psum``

Tensor kinds are folded onto the two GEMM operands through
``LoweredForm.lhs_tensors`` / ``rhs_tensors`` (a side moves the way its most
mobile tensor does: ring > all_gather > stream > shard), and the output
tensor's kind selects the execution strategy:

    output shard / stream  -> block-stationary output (SUMMA / Cannon /
                              hybrid single-ring, by input kinds)
    output psum            -> contraction spatial over the psum axes
    output ppermute_ring   -> contraction spatial over the ring axis,
                              reduced by an accumulate-rotate ppermute ring
    output all_gather      -> 2-D reduction tree: psum over both axes

The classic named schedules fall out as special cases (and are kept as
test oracles in ``engine.py``): SUMMA is gemm x the MMT dataflow, Cannon
is gemm x SST, ring-reduce is gemm x a K-spatial STT.

Grid-folded batch dims (``LoweredForm.batch``, e.g. batched_gemv's batch
loop or depthwise_conv's channel loop) ride along as a leading array dim:
the batch is **replicated** across the mesh (spec ``None``) and every
per-chip body executes the batched contraction over its m/n/k shard —
the collectives prescribed by the plan move per-slice operand panels
exactly as they would for the 2-D form.  (Sharding the batch dim itself
over a mesh axis is a possible future refinement; replication keeps every
strategy's spec algebra unchanged and the results exact.)

These run on fake CPU devices (``XLA_FLAGS=--xla_force_host_platform_
device_count=N``) in tests and on real slices unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, FrozenSet, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .. import jax_compat
from ..core.plan import CommPlan, TensorCommPlan

try:  # LoweredForm only needed for isinstance-free typing
    from ..compile.lowering import LoweredForm
except Exception:  # pragma: no cover - circular-import guard
    LoweredForm = "LoweredForm"  # type: ignore

#: side-kind precedence: a GEMM operand fed by several algebra tensors
#: (mttkrp's Khatri-Rao rhs) moves the way its most mobile tensor does.
_KIND_ORDER = ("ppermute_ring", "all_gather", "stream", "shard")


def _side_kind(by_tensor: Dict[str, TensorCommPlan],
               tensors: FrozenSet[str]) -> str:
    kinds = {by_tensor[t].kind for t in tensors if t in by_tensor}
    for k in _KIND_ORDER:
        if k in kinds:
            return k
    return "shard"


def _pad_dim(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Pad ``axis`` (negative axes address from the last dim, so the same
    call works on 2-D operands and batched rank-3 ones) up to ``mult``."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _skew(m: jax.Array, s: int, roll_axis: int, block_axis: int) -> jax.Array:
    """Cannon's initial alignment: roll block row/col ``i`` of ``m`` by
    ``i`` k-blocks along ``roll_axis`` (pure jnp, stays on device;
    negative axes keep it batch-agnostic)."""
    kb = m.shape[roll_axis] // s
    blocks = jnp.split(m, s, axis=block_axis)
    rolled = [jnp.roll(blk, -i * kb, axis=roll_axis)
              for i, blk in enumerate(blocks)]
    return jnp.concatenate(rolled, axis=block_axis)


def _contract(l: jax.Array, r: jax.Array) -> jax.Array:
    """out[..., m, n] = l[..., m, k] @ r[..., k, n] in fp32, broadcasting
    a leading batch dim carried by either operand — the per-chip body of
    every strategy, rank-aware so grid-folded forms fold through the same
    collectives as plain GEMMs."""
    return jnp.einsum("...mk,...kn->...mn", l, r,
                      preferred_element_type=jnp.float32)


def _acc_init(l: jax.Array, r: jax.Array) -> jax.Array:
    """fp32 accumulator matching ``_contract(l, r)``'s shape."""
    bshape = jnp.broadcast_shapes(l.shape[:-2], r.shape[:-2])
    return jnp.zeros((*bshape, l.shape[-2], r.shape[-1]), jnp.float32)


def _spec(batched: bool, *dims) -> P:
    """A PartitionSpec with a replicated leading batch dim when the
    operand carries one."""
    return P(None, *dims) if batched else P(*dims)


def _ring_perm(size: int) -> list:
    """Rotate data one hop backwards: position r receives block r+1, so
    after t steps position r holds its (r + t)-th block."""
    return [(j, (j - 1) % size) for j in range(size)]


@dataclasses.dataclass(frozen=True)
class MeshProgram:
    """A compiled CommPlan: the shard_map specs + ring structure chosen
    for one (CommPlan, LoweredForm, mesh) triple.  ``fn`` maps *global*
    (lhs2d, rhs2d) -> global out2d; specs/strategy are introspection for
    tests and docs."""

    strategy: str                       # summa | cannon | ring | k_spatial...
    in_specs: Tuple[P, P]
    out_spec: P
    ring_axes: Tuple[str, ...]
    pads: Tuple[int, int, int]          # padded (m, n, k)
    fn: Callable[[jax.Array, jax.Array], jax.Array] = \
        dataclasses.field(repr=False, default=None)

    def __call__(self, lhs: jax.Array, rhs: jax.Array) -> jax.Array:
        return self.fn(lhs, rhs)


def compile_comm_plan(comm: CommPlan, form: "LoweredForm", mesh: Mesh,
                      dtype=jnp.float32) -> MeshProgram:
    """Compile a generated CommPlan into an executable mesh program.

    The returned program computes ``out[b?, m, n] = lhs @ rhs`` (the
    algebra's LoweredForm view; grid-folded batch dims replicate across
    the mesh) with every inter-chip transfer prescribed by the plan's
    per-tensor collective kinds.  Works on any 2-D mesh; dataflows whose
    plan needs two rings (Cannon-class) require a square mesh and degrade
    to all_gather multicast on a rectangular one (same reuse, realized by
    the multicast wires instead of the systolic ones).
    """
    if len(mesh.axis_names) != 2:
        raise ValueError(f"comm_engine needs a 2-D mesh, got axes "
                         f"{mesh.axis_names}")
    ax_x, ax_y = mesh.axis_names
    sx, sy = mesh.devices.shape

    by = comm.by_tensor()
    out_tp = comm.tensors[-1]
    lhs_kind = _side_kind(by, form.lhs_tensors)
    rhs_kind = _side_kind(by, form.rhs_tensors)
    out_kind = out_tp.kind
    dt = jnp.dtype(dtype)

    if out_kind in ("shard", "stream"):
        return _out_stationary(form, mesh, lhs_kind, rhs_kind, dt)
    if out_kind == "psum":
        axes = tuple(a for a in out_tp.mesh_axes if a in mesh.axis_names) \
            or (ax_x,)
        return _k_spatial(form, mesh, lhs_kind, rhs_kind, axes, dt,
                          ring=False)
    if out_kind == "ppermute_ring":
        axes = (out_tp.mesh_axis if out_tp.mesh_axis in mesh.axis_names
                else ax_y,)
        return _k_spatial(form, mesh, lhs_kind, rhs_kind, axes, dt,
                          ring=True)
    if out_kind == "all_gather":
        # broadcast-class output: rank-2 reuse plane ⊥ t — the paper's 2-D
        # reduction tree; on the mesh a psum over both axes
        return _k_spatial(form, mesh, lhs_kind, rhs_kind, (ax_x, ax_y), dt,
                          ring=False)
    raise ValueError(f"unknown output collective kind {out_kind!r}")


# ---------------------------------------------------------------------------
# Strategy 1: output blocks stationary (shard / stream output)
# ---------------------------------------------------------------------------

def _out_stationary(form, mesh: Mesh, lhs_kind: str, rhs_kind: str,
                    dtype) -> MeshProgram:
    """Output (m, n) blocks resident on their chip; the contraction is
    delivered by gathers (multicast wires), rings (systolic wires), or
    local full-k residency (stationary / unicast operands).

    m is sharded over the first mesh axis and n over the second; the
    structural motion axis for the lhs is therefore the second axis (its
    reuse spans the n-direction) and vice versa — the same orientation the
    hand-written SUMMA/Cannon engines used.  Grid-folded batch dims are
    replicated (leading ``None`` spec); every body contraction is
    rank-aware via ``_contract``.
    """
    ax_x, ax_y = mesh.axis_names
    sx, sy = mesh.devices.shape
    square = sx == sy
    lb = bool(form.batch) and form.lhs_batched
    rb = bool(form.batch) and form.rhs_batched
    ob = bool(form.batch)

    if lhs_kind == "ppermute_ring" and rhs_kind == "ppermute_ring" \
            and not square:
        # Cannon needs equal ring lengths; on a rectangular mesh realize
        # the same reuse with the multicast wires instead.
        lhs_kind = rhs_kind = "all_gather"

    lhs_moves = lhs_kind in ("all_gather", "ppermute_ring")
    rhs_moves = rhs_kind in ("all_gather", "ppermute_ring")
    ring_axes = tuple(ax for ax, kind in ((ax_y, lhs_kind), (ax_x, rhs_kind))
                      if kind == "ppermute_ring")

    # k-split granularity: the ring length when a ring exists (Cannon needs
    # both splits equal), else each moving side splits over its own axis.
    double_ring = lhs_kind == "ppermute_ring" and rhs_kind == "ppermute_ring"
    S = sy if lhs_kind == "ppermute_ring" else \
        (sx if rhs_kind == "ppermute_ring" else 1)

    in_specs = (_spec(lb, ax_x, ax_y if lhs_moves else None),
                _spec(rb, ax_x if rhs_moves else None, ax_y))
    out_spec = _spec(ob, ax_x, ax_y)
    kmult = math.lcm(sy if lhs_moves else 1, sx if rhs_moves else 1, max(S, 1))

    strategy = ("cannon" if double_ring else
                "summa" if lhs_kind == "all_gather"
                and rhs_kind == "all_gather" else
                "ring_hybrid" if ring_axes else
                "multicast_hybrid" if lhs_moves or rhs_moves else "local")

    def body(l, r):
        if lhs_kind == "all_gather":
            l = jax.lax.all_gather(l, ax_y, axis=l.ndim - 1, tiled=True)
        if rhs_kind == "all_gather":
            r = jax.lax.all_gather(r, ax_x, axis=r.ndim - 2, tiled=True)
        if not ring_axes:
            return _contract(l, r).astype(dtype)

        if double_ring:
            left = _ring_perm(sy)
            up = _ring_perm(sx)

            def step(t, carry):
                l_c, r_c, acc = carry
                acc = acc + _contract(l_c, r_c)
                l_c = jax.lax.ppermute(l_c, ax_y, left)
                r_c = jax.lax.ppermute(r_c, ax_x, up)
                return l_c, r_c, acc

            _, _, acc = jax.lax.fori_loop(0, S, step, (l, r, _acc_init(l, r)))
            return acc.astype(dtype)

        # single ring: one side circulates its k-blocks; the other side
        # holds full k (gathered or resident) and slices the block that is
        # currently aligned with the ring position.
        ring_on_lhs = lhs_kind == "ppermute_ring"
        ax_ring = ax_y if ring_on_lhs else ax_x
        perm = _ring_perm(S)
        pos = jax.lax.axis_index(ax_ring)
        mov0 = l if ring_on_lhs else r
        kb = mov0.shape[-1] if ring_on_lhs else mov0.shape[-2]

        def step(t, carry):
            mov, acc = carry
            idx = ((pos + t) % S) * kb
            if ring_on_lhs:
                r_blk = jax.lax.dynamic_slice_in_dim(r, idx, kb,
                                                     axis=r.ndim - 2)
                acc = acc + _contract(mov, r_blk)
            else:
                l_blk = jax.lax.dynamic_slice_in_dim(l, idx, kb,
                                                     axis=l.ndim - 1)
                acc = acc + _contract(l_blk, mov)
            mov = jax.lax.ppermute(mov, ax_ring, perm)
            return mov, acc

        _, acc = jax.lax.fori_loop(0, S, step, (mov0, _acc_init(l, r)))
        return acc.astype(dtype)

    def run(lhs, rhs):
        m, n = lhs.shape[-2], rhs.shape[-1]
        lhs = _pad_dim(_pad_dim(lhs, -2, sx), -1, kmult)
        rhs = _pad_dim(_pad_dim(rhs, -1, sy), -2, kmult)
        if double_ring:
            lhs = _skew(lhs, sx, roll_axis=-1, block_axis=-2)
            rhs = _skew(rhs, sy, roll_axis=-2, block_axis=-1)
        out = jax_compat.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
            check_vma=False)(lhs, rhs)
        return out[..., :m, :n]

    return MeshProgram(strategy, in_specs, out_spec, ring_axes,
                       (sx, sy, kmult), jax.jit(run))


# ---------------------------------------------------------------------------
# Strategy 2: contraction spatial over mesh axes (psum / output-ring /
# broadcast-reduction outputs)
# ---------------------------------------------------------------------------

def _k_spatial(form, mesh: Mesh, lhs_kind: str, rhs_kind: str,
               k_axes: Tuple[str, ...], dtype, *, ring: bool) -> MeshProgram:
    """The contraction dimension is sharded over ``k_axes``; each chip
    computes a partial product and the reduction tree runs over those axes
    — as one ``psum`` (reduction-class outputs) or as an accumulate-rotate
    ppermute ring (systolic-class outputs).

    Inputs never need off-chip k-blocks here (k is spatial), so input
    rings/multicasts along non-k axes collapse to replication — the
    time-staggering they describe is a wire-level schedule, not a
    different data placement.  Grid-folded batch dims are replicated
    (leading ``None`` spec), the partial products are batched.
    """
    ax_x, ax_y = mesh.axis_names
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    other = next((a for a in mesh.axis_names if a not in k_axes), None)
    lb = bool(form.batch) and form.lhs_batched
    rb = bool(form.batch) and form.rhs_batched
    ob = bool(form.batch)

    # the fully-partitioned ("shard"/"stream") input also splits its non-k
    # dim over the remaining axis; lhs wins if both claim it
    shard_m = other is not None and lhs_kind in ("shard", "stream")
    shard_n = other is not None and not shard_m

    k_spec = k_axes[0] if len(k_axes) == 1 else tuple(k_axes)
    in_specs = (_spec(lb, other if shard_m else None, k_spec),
                _spec(rb, k_spec, other if shard_n else None))
    out_spec = _spec(ob, other if shard_m else None,
                     other if shard_n else None)
    kmult = math.prod(sizes[a] for a in k_axes)
    ring_axes = k_axes if ring else ()
    S = sizes[k_axes[0]] if ring else 0

    def body(l, r):
        part = _contract(l, r)
        if ring:
            perm = _ring_perm(S)

            def step(t, acc):
                return jax.lax.ppermute(acc, k_axes[0], perm) + part

            # S steps of (rotate, add own partial) leave the full sum on
            # every ring member — the systolic output chain, chip-scale
            total = jax.lax.fori_loop(0, S, step,
                                      jnp.zeros_like(part))
        else:
            total = jax.lax.psum(part, k_axes if len(k_axes) > 1
                                 else k_axes[0])
        return total.astype(dtype)

    def run(lhs, rhs):
        m, n = lhs.shape[-2], rhs.shape[-1]
        lhs = _pad_dim(lhs, -1, kmult)
        rhs = _pad_dim(rhs, -2, kmult)
        if shard_m:
            lhs = _pad_dim(lhs, -2, sizes[other])
        if shard_n:
            rhs = _pad_dim(rhs, -1, sizes[other])
        out = jax_compat.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
            check_vma=False)(lhs, rhs)
        return out[..., :m, :n]

    return MeshProgram("k_spatial_ring" if ring else "k_spatial",
                       in_specs, out_spec, ring_axes,
                       (1, 1, kmult), jax.jit(run))


# ---------------------------------------------------------------------------
# Introspection: kind -> spec table for one plan (used by docs and tests)
# ---------------------------------------------------------------------------

def describe(comm: CommPlan, form: "LoweredForm", mesh: Mesh
             ) -> Dict[str, str]:
    """Human-readable per-tensor realization of a CommPlan on a mesh."""
    prog = compile_comm_plan(comm, form, mesh)
    lines = {"strategy": prog.strategy,
             "lhs_spec": str(prog.in_specs[0]),
             "rhs_spec": str(prog.in_specs[1]),
             "out_spec": str(prog.out_spec)}
    for t in comm.tensors:
        ax = ",".join(t.mesh_axes) if t.mesh_axes else "-"
        lines[t.tensor] = f"{t.kind}[{ax}]"
    return lines
