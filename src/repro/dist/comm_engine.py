"""Generic CommPlan interpreter: any generated CommPlan -> shard_map.

``compile_comm_plan`` takes the CommPlan that ``plan.comm_plan_for``
generated from the dataflow classification plus the algebra's
:class:`~repro.compile.LoweredForm`, and emits a shard_map program over a
2-D device mesh — the chip-level realization of the paper's claim that one
transformation matrix yields the complete accelerator, module selection
*and connection*.

Since the unified-partition refactor this module contains **no per-strategy
shard/replicate decisions**: every placement, motion and degradation comes
from ``plan.solve_partition`` — the :class:`~repro.core.plan.
PartitionSolution` maps every LoweredForm dim (batch, m, n, k, and sparse
block coordinates) onto mesh axes once, and this module only materializes
it:

    * stored layouts      -> shard_map ``PartitionSpec``s (one per side),
    * ``all_gather`` motion -> ``jax.lax.all_gather(..., tiled=True)``,
    * ``ppermute_ring`` motion -> rotation schedules in ``fori_loop``s,
    * batch grid dims     -> sharded over their mesh axis (replication only
      as the solver's degenerate solution),
    * compressed sides    -> per-device BSR payload + block-COO coordinate
      lists shipped through the same gathers/rings (never densified),
    * input-systolic dt   -> the staggered accumulate-rotate schedule
      (``k_spatial_stagger``): device r adds its partial for output chunk
      ``(r - t) mod S`` at step t, so the mobile tensor stores 1/S per
      device instead of a full replica.

The classic named schedules fall out as special cases (and are kept as
test oracles in ``engine.py``): SUMMA is gemm x the MMT dataflow, Cannon
is gemm x SST, ring-reduce is gemm x a K-spatial STT.

These run on fake CPU devices (``XLA_FLAGS=--xla_force_host_platform_
device_count=N``) in tests and on real slices unchanged; degenerate
meshes (1x1, 1xN, Nx1) and non-divisible shard shapes are handled by the
same padding every strategy applies.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .. import jax_compat
from ..core import plan as plan_mod
from ..core.plan import CommPlan, PartitionSolution, TensorPartition

try:  # LoweredForm only needed for isinstance-free typing
    from ..compile.lowering import LoweredForm
except Exception:  # pragma: no cover - circular-import guard
    LoweredForm = "LoweredForm"  # type: ignore


def _pad_dim(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Pad ``axis`` (negative axes address from the last dim, so the same
    call works on 2-D operands and batched rank-3 ones) up to ``mult``."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _skew(m: jax.Array, s: int, roll_axis: int, block_axis: int) -> jax.Array:
    """Cannon's initial alignment: roll block row/col ``i`` of ``m`` by
    ``i`` k-blocks along ``roll_axis``."""
    kb = m.shape[roll_axis] // s
    blocks = jnp.split(m, s, axis=block_axis)
    rolled = [jnp.roll(blk, -i * kb, axis=roll_axis)
              for i, blk in enumerate(blocks)]
    return jnp.concatenate(rolled, axis=block_axis)


def _contract(l: jax.Array, r: jax.Array) -> jax.Array:
    """out[..., m, n] = l[..., m, k] @ r[..., k, n] in fp32, broadcasting
    a leading batch dim carried by either operand."""
    return jnp.einsum("...mk,...kn->...mn", l, r,
                      preferred_element_type=jnp.float32)


def _acc_init(l: jax.Array, r: jax.Array) -> jax.Array:
    """fp32 accumulator matching ``_contract(l, r)``'s shape."""
    bshape = jnp.broadcast_shapes(l.shape[:-2], r.shape[:-2])
    return jnp.zeros((*bshape, l.shape[-2], r.shape[-1]), jnp.float32)


def _ring_perm(size: int) -> list:
    """Rotate data one hop backwards: position r receives block r+1."""
    return [(j, (j - 1) % size) for j in range(size)]


def _fwd_perm(size: int) -> list:
    """Rotate data one hop forwards: position r sends to r+1 (the
    staggered accumulator schedule's direction)."""
    return [(j, (j + 1) % size) for j in range(size)]


def _spec_of(tp: TensorPartition) -> P:
    """The stored layout of one side, as a shard_map PartitionSpec."""
    return P(*tp.placement)


@dataclasses.dataclass(frozen=True)
class MeshProgram:
    """A compiled CommPlan: the shard_map specs + ring structure chosen
    for one (CommPlan, LoweredForm, mesh) triple.  ``fn`` maps *global*
    (lhs2d, rhs2d) -> global out; ``solution`` is the partition the
    program materializes (introspection for tests, docs and the cost
    model)."""

    strategy: str                       # summa | cannon | ring | k_spatial...
    in_specs: Tuple[P, P]
    out_spec: P
    ring_axes: Tuple[str, ...]
    pads: Tuple[int, int, int]          # padding multiples for (m, n, k)
    solution: PartitionSolution = None
    fn: Callable[[jax.Array, jax.Array], jax.Array] = (
        dataclasses.field(repr=False, default=None))

    def __call__(self, lhs: jax.Array, rhs: jax.Array) -> jax.Array:
        return self.fn(lhs, rhs)

    def footprint(self, form: "LoweredForm", elem_bytes: int = 4
                  ) -> Dict[str, float]:
        """Per-device stored bytes per side (the solver's accounting)."""
        return self.solution.per_device_bytes(form, elem_bytes)


def compile_comm_plan(comm: CommPlan, form: "LoweredForm", mesh: Mesh,
                      dtype=jnp.float32, *, shard_batch: bool = True,
                      sparse: str = "auto") -> MeshProgram:
    """Compile a generated CommPlan into an executable mesh program.

    The returned program computes ``out[b?, m, n] = lhs @ rhs`` (the
    algebra's LoweredForm view) with every inter-chip transfer prescribed
    by the :class:`~repro.core.plan.PartitionSolution` the plan solves to:
    batch grid dims shard a mesh axis, structured block-sparse operands
    ship compressed, and systolic plans run their rotation schedules.

    ``shard_batch=False`` requests the replicating-batch baseline and
    ``sparse="dense"`` the masked-dense shipping baseline (both kept for
    footprint A/B comparisons); ``sparse="auto"``/``"bsr"`` ship the
    structured operand compressed whenever the form has one.
    """
    if len(mesh.axis_names) != 2:
        raise ValueError(f"comm_engine needs a 2-D mesh, got axes "
                         f"{mesh.axis_names}")
    if sparse not in ("auto", "bsr", "dense"):
        raise ValueError(f"sparse must be 'auto', 'bsr' or 'dense', "
                         f"got {sparse!r}")
    compressed = None if sparse == "auto" else (sparse == "bsr")
    sol = plan_mod.solve_partition(
        comm, form, axes=tuple(mesh.axis_names),
        shape=tuple(mesh.devices.shape), shard_batch=shard_batch,
        compressed=compressed)
    if sparse == "bsr" and not (sol.lhs.compressed or sol.rhs.compressed):
        raise ValueError(
            "sparse='bsr' requested but the solved partition ships no "
            "compressed side (no structured 2-D sparse operand); use "
            "sparse='auto' or 'dense'")
    dt = jnp.dtype(dtype)
    if sol.strategy in ("summa", "cannon", "ring_hybrid",
                        "multicast_hybrid", "local"):
        return _build_out_stationary(sol, form, mesh, dt)
    return _build_k_spatial(sol, form, mesh, dt)


# ---------------------------------------------------------------------------
# Compressed-operand shipping: per-device BSR payload + coordinate lists
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Compressed:
    """Trace-time partition of a structured sparse side.

    The dense prepared operand is decomposed into its pattern's blocks and
    each device's nonzero blocks are collected as (payload, stat-coord,
    k-coord) triples — the stationary-dim coordinate is local to the
    device's shard, the contraction-dim coordinate is in ``k_frame``
    ("global": the frame of a full-k dense side at contract time, i.e.
    gathered/resident; "local": the frame of a k-spatial shard).  Payload
    rows are padded per device to the max nnz (``n_max``); padded entries
    are zeroed so they contribute nothing downstream.
    """

    side: str                       # lhs | rhs
    block: Tuple[int, int]
    d0_pad: int                     # padded operand dims
    d1_pad: int
    n_max: int
    flat_ids: np.ndarray            # (s0, s1, n_max) block ids, padded w/ 0
    stat_c: np.ndarray              # (s0, s1, n_max) local stationary coords
    k_c: np.ndarray                 # (s0, s1, n_max) contraction coords
    valid: np.ndarray               # (s0, s1, n_max) bool
    counts: np.ndarray              # (s0, s1) nnz per device

    @property
    def grid_pad(self) -> Tuple[int, int]:
        return (self.d0_pad // self.block[0], self.d1_pad // self.block[1])


def _splits(ax, sizes: Dict[str, int]) -> int:
    return plan_mod._axis_factor(ax, sizes)


def _compress_partition(form: "LoweredForm", sol: PartitionSolution,
                        k_frame: str, k_extra: int = 1) -> _Compressed:
    """Partition the pattern's block-COO list per device (numpy, static).

    ``k_extra`` is the dense side's contraction-dim split factor: the
    padded k extent must be divisible by it too, so the gathered /
    resident dense side and the payload's k-coordinate frame agree."""
    osp = form.sparse
    tp = sol.lhs if osp.side == "lhs" else sol.rhs
    axes, (s0, s1) = sol.axes, sol.shape
    sizes = sol.sizes
    b0, b1 = osp.block
    if osp.side == "lhs":
        stat_dim, k_dim = "m", "k"
        d0_ext, d1_ext = form.m, form.k
        stat_pos = 0                       # rows are the stationary dim
    else:
        stat_dim, k_dim = "n", "k"
        d0_ext, d1_ext = form.k, form.n
        stat_pos = 1                       # cols are the stationary dim
    stat_ax = tp.axis_of.get(stat_dim)
    k_ax = tp.axis_of.get(k_dim)
    f_stat = _splits(stat_ax, sizes)
    f_k = _splits(k_ax, sizes)

    # pad operand dims so every shard is a whole number of blocks (and the
    # contraction dim also divides the dense side's split)
    def padded(ext, blk, splits, extra=1):
        step = math.lcm(blk * splits, extra)
        return step * math.ceil(ext / step)

    if stat_pos == 0:
        d0_pad = padded(d0_ext, b0, f_stat)
        d1_pad = padded(d1_ext, b1, f_k, k_extra)
        g_stat, g_k = d0_pad // b0, d1_pad // b1
    else:
        d0_pad = padded(d0_ext, b0, f_k, k_extra)
        d1_pad = padded(d1_ext, b1, f_stat)
        g_k, g_stat = d0_pad // b0, d1_pad // b1
    g0, g1 = d0_pad // b0, d1_pad // b1
    stat_per, k_per = g_stat // f_stat, g_k // f_k

    def shard_of(ax, i, j):
        if ax is None:
            return 0
        if isinstance(ax, tuple):
            coords = {axes[0]: i, axes[1]: j}
            idx = 0
            for a in ax:
                idx = idx * sizes[a] + coords[a]
            return idx
        return i if ax == axes[0] else j

    per_dev = [[[] for _ in range(s1)] for _ in range(s0)]
    for (r, c) in osp.coords:
        stat_id, k_id = (r, c) if stat_pos == 0 else (c, r)
        si, ki = stat_id // stat_per, k_id // k_per
        for i in range(s0):
            for j in range(s1):
                if shard_of(stat_ax, i, j) != si and stat_ax is not None:
                    continue
                if shard_of(k_ax, i, j) != ki and k_ax is not None:
                    continue
                stat_local = (stat_id - (si if stat_ax is not None else 0)
                    * stat_per)
                k_out = (k_id if k_frame == "global" else
                    k_id - (ki if k_ax is not None else 0) * k_per)
                per_dev[i][j].append((r * g1 + c, stat_local, k_out))

    counts = np.array([[len(per_dev[i][j]) for j in range(s1)]
                       for i in range(s0)], np.int32)
    n_max = max(1, int(counts.max()))
    flat_ids = np.zeros((s0, s1, n_max), np.int32)
    stat_c = np.zeros((s0, s1, n_max), np.int32)
    k_c = np.zeros((s0, s1, n_max), np.int32)
    valid = np.zeros((s0, s1, n_max), bool)
    for i in range(s0):
        for j in range(s1):
            for t, (fid, sc, kc) in enumerate(per_dev[i][j]):
                flat_ids[i, j, t] = fid
                stat_c[i, j, t] = sc
                k_c[i, j, t] = kc
                valid[i, j, t] = True
    return _Compressed(osp.side, (b0, b1), d0_pad, d1_pad, n_max,
                       flat_ids, stat_c, k_c, valid, counts)


def _pack_payload(dense2d: jax.Array, comp: _Compressed) -> jax.Array:
    """Blocks of the padded dense operand, gathered per device and zeroed
    on padded entries: (s0, s1, n_max, b0, b1)."""
    b0, b1 = comp.block
    g0, g1 = comp.grid_pad
    x = _pad_dim(_pad_dim(dense2d, -2, comp.d0_pad), -1, comp.d1_pad)
    x = x[:comp.d0_pad, :comp.d1_pad]
    blocks = x.reshape(g0, b0, g1, b1).transpose(0, 2, 1, 3)
    flat = blocks.reshape(g0 * g1, b0, b1)
    pay = flat[comp.flat_ids]                     # (s0, s1, N, b0, b1)
    mask = jnp.asarray(comp.valid)[..., None, None]
    return jnp.where(mask, pay, jnp.zeros((), pay.dtype))


def _bsr_contract(pay: jax.Array, stat_c: jax.Array, k_c: jax.Array,
                  dense: jax.Array, side: str, stat_blocks: int,
                  b_stat: int, b_k: int) -> jax.Array:
    """One compressed contraction: nonzero blocks against a dense side.

    ``side == 'lhs'``: pay (N, bm, bk) x dense (K, n) -> (stat_blocks*bm, n)
    ``side == 'rhs'``: dense (m, K) x pay (N, bk, bn) -> (m, stat_blocks*bn)

    ``dense``'s contraction extent K must be in the same frame as ``k_c``
    (full-k at contract time for gathered/resident sides, the local shard
    for k-spatial).  Padded payload entries are zero, so their (0, 0)
    coordinates contribute nothing.
    """
    if side == "lhs":
        n = dense.shape[-1]
        rb = dense.reshape(-1, b_k, n)[k_c]               # (N, bk, n)
        parts = jnp.einsum("nab,nbc->nac", pay, rb,
                           preferred_element_type=jnp.float32)
        out = jax.ops.segment_sum(parts, stat_c, num_segments=stat_blocks)
        return out.reshape(stat_blocks * b_stat, n)
    m = dense.shape[-2]
    lb = jnp.take(dense.reshape(m, -1, b_k), k_c, axis=1)  # (m, N, bk)
    parts = jnp.einsum("mnb,nbc->nmc", lb, pay,
                       preferred_element_type=jnp.float32)
    out = jax.ops.segment_sum(parts, stat_c, num_segments=stat_blocks)
    return out.transpose(1, 0, 2).reshape(m, stat_blocks * b_stat)


# ---------------------------------------------------------------------------
# Strategy family 1: output blocks stationary (shard / stream output)
# ---------------------------------------------------------------------------

def _build_out_stationary(sol: PartitionSolution, form, mesh: Mesh,
                          dtype) -> MeshProgram:
    """Output (b?, m, n) blocks resident on their chip; the contraction is
    delivered by the motions the solver assigned: gathers (multicast
    wires), rings (systolic wires), or local full-k residency."""
    ax0, ax1 = sol.axes
    sizes = sol.sizes
    s0, s1 = sol.shape
    lhs_tp, rhs_tp, out_tp = sol.lhs, sol.rhs, sol.out
    double_ring = sol.strategy == "cannon"
    lhs_ring = lhs_tp.motion == "ppermute_ring"
    rhs_ring = rhs_tp.motion == "ppermute_ring"
    S = s1 if lhs_ring else (s0 if rhs_ring else 1)

    comp = None
    if lhs_tp.compressed or rhs_tp.compressed:
        dn_tp = rhs_tp if lhs_tp.compressed else lhs_tp
        comp = _compress_partition(
            form, sol, k_frame="global",
            k_extra=plan_mod._axis_factor(dn_tp.axis_of.get("k"), sizes))

    in_specs = (_spec_of(lhs_tp), _spec_of(rhs_tp))
    out_spec = _spec_of(out_tp)
    kmult = math.lcm(
        s1 if lhs_tp.axis_of.get("k") else 1,
        s0 if rhs_tp.axis_of.get("k") else 1, max(S, 1))
    f_b = plan_mod._axis_factor(sol.batch_axis, sizes)
    f_m = plan_mod._axis_factor(sol.grid.get("m"), sizes)
    f_n = plan_mod._axis_factor(sol.grid.get("n"), sizes)

    if comp is None:
        fn = _dense_out_stationary_fn(
            sol, form, mesh, dtype, in_specs, out_spec, kmult,
            f_b, f_m, f_n, S, double_ring)
    else:
        fn = _compressed_out_stationary_fn(
            sol, form, mesh, dtype, comp, out_spec, kmult, f_m, f_n, S)
    return MeshProgram(sol.strategy, in_specs, out_spec, sol.ring_axes,
                       (f_m, f_n, kmult), sol, fn)


def _dense_out_stationary_fn(sol, form, mesh, dtype, in_specs, out_spec,
                             kmult, f_b, f_m, f_n, S, double_ring):
    ax0, ax1 = sol.axes
    s0, s1 = sol.shape
    lhs_tp, rhs_tp = sol.lhs, sol.rhs
    lhs_ring = lhs_tp.motion == "ppermute_ring"
    rhs_ring = rhs_tp.motion == "ppermute_ring"
    lhs_gather = lhs_tp.motion == "all_gather"
    rhs_gather = rhs_tp.motion == "all_gather"

    def body(l, r):
        if lhs_gather:
            l = jax.lax.all_gather(l, ax1, axis=l.ndim - 1, tiled=True)
        if rhs_gather:
            r = jax.lax.all_gather(r, ax0, axis=r.ndim - 2, tiled=True)
        if not (lhs_ring or rhs_ring):
            return _contract(l, r).astype(dtype)

        if double_ring:
            left = _ring_perm(s1)
            up = _ring_perm(s0)

            def step(t, carry):
                l_c, r_c, acc = carry
                acc = acc + _contract(l_c, r_c)
                l_c = jax.lax.ppermute(l_c, ax1, left)
                r_c = jax.lax.ppermute(r_c, ax0, up)
                return l_c, r_c, acc

            _, _, acc = jax.lax.fori_loop(0, S, step, (l, r, _acc_init(l, r)))
            return acc.astype(dtype)

        # single ring: one side circulates its k-blocks; the other side
        # holds full k (gathered or resident) and slices the block that is
        # currently aligned with the ring position.
        ax_ring = ax1 if lhs_ring else ax0
        perm = _ring_perm(S)
        pos = jax.lax.axis_index(ax_ring)
        mov0 = l if lhs_ring else r
        kb = mov0.shape[-1] if lhs_ring else mov0.shape[-2]

        def step(t, carry):
            mov, acc = carry
            idx = ((pos + t) % S) * kb
            if lhs_ring:
                r_blk = jax.lax.dynamic_slice_in_dim(r, idx, kb,
                                                     axis=r.ndim - 2)
                acc = acc + _contract(mov, r_blk)
            else:
                l_blk = jax.lax.dynamic_slice_in_dim(l, idx, kb,
                                                     axis=l.ndim - 1)
                acc = acc + _contract(l_blk, mov)
            mov = jax.lax.ppermute(mov, ax_ring, perm)
            return mov, acc

        _, acc = jax.lax.fori_loop(0, S, step, (mov0, _acc_init(l, r)))
        return acc.astype(dtype)

    batched = bool(form.batch)

    def run(lhs, rhs):
        b, m, n = form.batch_size, lhs.shape[-2], rhs.shape[-1]
        lhs = _pad_dim(_pad_dim(lhs, -2, f_m), -1, kmult)
        rhs = _pad_dim(_pad_dim(rhs, -1, f_n), -2, kmult)
        if batched:
            if form.lhs_batched:
                lhs = _pad_dim(lhs, -3, f_b)
            if form.rhs_batched:
                rhs = _pad_dim(rhs, -3, f_b)
        if double_ring:
            lhs = _skew(lhs, s0, roll_axis=-1, block_axis=-2)
            rhs = _skew(rhs, s1, roll_axis=-2, block_axis=-1)
        out = jax_compat.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
            check_vma=False)(lhs, rhs)
        out = out[..., :m, :n]
        return out[:b] if batched else out

    return jax.jit(run)


def _compressed_out_stationary_fn(sol, form, mesh, dtype, comp, out_spec,
                                  kmult, f_m, f_n, S):
    """The sparse side ships as (payload, stat-coords, k-coords) through
    the motion the solver assigned (gather or single ring — the solver
    never emits a compressed double ring); the dense side moves exactly as
    in the dense program and is full-k at contract time, so the global
    k-coordinates the payload carries need no realignment."""
    ax0, ax1 = sol.axes
    s0, s1 = sol.shape
    sp_side = comp.side
    sp_tp = sol.lhs if sp_side == "lhs" else sol.rhs
    dn_tp = sol.rhs if sp_side == "lhs" else sol.lhs
    dn_gather = dn_tp.motion == "all_gather"
    sp_gather = sp_tp.motion == "all_gather"
    sp_ring = sp_tp.motion == "ppermute_ring"
    b0, b1 = comp.block
    b_stat, b_k = (b0, b1) if sp_side == "lhs" else (b1, b0)
    stat_ax = sp_tp.axis_of.get("m" if sp_side == "lhs" else "n")
    f_stat = plan_mod._axis_factor(stat_ax, sol.sizes)
    stat_blocks = ((comp.d0_pad if sp_side == "lhs" else comp.d1_pad)
        // (b_stat * f_stat))
    # the sparse side's motion axis (k split) and the dense side's
    dn_ax = ax0 if sp_side == "lhs" else ax1
    sp_ax = ax1 if sp_side == "lhs" else ax0
    triple_specs = (P(ax0, ax1, None, None, None),
                    P(ax0, ax1, None), P(ax0, ax1, None))

    def body(pay, sc, kc, dense):
        pay, sc, kc = pay[0, 0], sc[0, 0], kc[0, 0]
        if dn_gather:
            axis = dense.ndim - 2 if sp_side == "lhs" else dense.ndim - 1
            dense = jax.lax.all_gather(dense, dn_ax, axis=axis, tiled=True)
        if sp_gather:
            pay = jax.lax.all_gather(pay, sp_ax, axis=0, tiled=True)
            sc = jax.lax.all_gather(sc, sp_ax, axis=0, tiled=True)
            kc = jax.lax.all_gather(kc, sp_ax, axis=0, tiled=True)
        if not sp_ring:
            return _bsr_contract(pay, sc, kc, dense, sp_side,
                                 stat_blocks, b_stat, b_k).astype(dtype)

        perm = _ring_perm(S)
        if sp_side == "lhs":
            acc0 = jnp.zeros((stat_blocks * b_stat, dense.shape[-1]),
                             jnp.float32)
        else:
            acc0 = jnp.zeros((dense.shape[-2], stat_blocks * b_stat),
                             jnp.float32)

        def step(t, carry):
            pay_c, sc_c, kc_c, acc = carry
            acc = acc + _bsr_contract(pay_c, sc_c, kc_c, dense, sp_side,
                                      stat_blocks, b_stat, b_k)
            pay_c = jax.lax.ppermute(pay_c, sp_ax, perm)
            sc_c = jax.lax.ppermute(sc_c, sp_ax, perm)
            kc_c = jax.lax.ppermute(kc_c, sp_ax, perm)
            return pay_c, sc_c, kc_c, acc

        _, _, _, acc = jax.lax.fori_loop(0, S, step, (pay, sc, kc, acc0))
        return acc.astype(dtype)

    dense_spec = _spec_of(dn_tp)
    sc = jnp.asarray(comp.stat_c)
    kc = jnp.asarray(comp.k_c)

    def run(lhs, rhs):
        m, n = lhs.shape[-2], rhs.shape[-1]
        sp2d, dn2d = (lhs, rhs) if sp_side == "lhs" else (rhs, lhs)
        pay = _pack_payload(sp2d, comp)
        if sp_side == "lhs":
            dn2d = _pad_dim(_pad_dim(dn2d, -1, f_n), -2, comp.d1_pad)
            dn2d = dn2d[:comp.d1_pad]
            args = (pay, sc, kc, dn2d)
        else:
            dn2d = _pad_dim(_pad_dim(dn2d, -2, f_m), -1, comp.d0_pad)
            dn2d = dn2d[:, :comp.d0_pad]
            args = (pay, sc, kc, dn2d)
        out = jax_compat.shard_map(
            body, mesh=mesh, in_specs=(*triple_specs, dense_spec),
            out_specs=out_spec, check_vma=False)(*args)
        return out[..., :m, :n]

    return jax.jit(run)


# ---------------------------------------------------------------------------
# Strategy family 2: contraction spatial over mesh axes (psum / staggered
# output ring / broadcast-reduction outputs)
# ---------------------------------------------------------------------------

def _build_k_spatial(sol: PartitionSolution, form, mesh: Mesh,
                     dtype) -> MeshProgram:
    """The contraction dim is sharded over ``sol.k_axes``; each chip
    computes a partial product and the reduction runs over those axes —
    one ``psum`` (reduction-class outputs) or the staggered
    accumulate-rotate ppermute schedule (systolic-class outputs, the
    executed dt: the output is the mobile tensor and stores 1/S per
    device)."""
    sizes = sol.sizes
    k_axes = sol.k_axes
    lhs_tp, rhs_tp, out_tp = sol.lhs, sol.rhs, sol.out
    kmult = math.prod(sizes[a] for a in k_axes)
    f_b = plan_mod._axis_factor(sol.batch_axis, sizes)
    f_m = plan_mod._axis_factor(sol.grid.get("m"), sizes)
    f_n = plan_mod._axis_factor(sol.grid.get("n"), sizes)
    S = sizes[k_axes[0]] if sol.stagger else 0

    comp = None
    if lhs_tp.compressed or rhs_tp.compressed:
        comp = _compress_partition(form, sol, k_frame="local",
                                   k_extra=kmult)

    in_specs = (_spec_of(lhs_tp), _spec_of(rhs_tp))
    out_spec = _spec_of(out_tp)
    ring_ax = k_axes[0] if sol.stagger else None

    def reduce_partial(part):
        """Partial (b?, m_pad, n_loc) fp32 -> reduced output block: one
        psum over the k axes, or — for systolic-class outputs — the
        staggered accumulate-rotate schedule (the executed dt): at step t
        device r adds its k-shard's partial for output chunk
        ``(r - t) mod S`` to the chunk passing by and forwards it, so
        after S rotations chunk r has visited every k-shard and lands on
        device r — the mobile tensor stores 1/S per device instead of a
        full replica."""
        if not sol.stagger:
            return jax.lax.psum(part, k_axes if len(k_axes) > 1
                                else k_axes[0])
        pos = jax.lax.axis_index(ring_ax)
        chunk = part.shape[-2] // S
        perm = _fwd_perm(S)

        def step(t, acc):
            c = (pos - t) % S
            pc = jax.lax.dynamic_slice_in_dim(part, c * chunk, chunk,
                                              axis=part.ndim - 2)
            return jax.lax.ppermute(acc + pc, ring_ax, perm)

        acc0 = jnp.zeros((*part.shape[:-2], chunk, part.shape[-1]),
                         jnp.float32)
        return jax.lax.fori_loop(0, S, step, acc0)

    m_mult = S if sol.stagger else f_m
    if comp is not None:
        fn = _compressed_k_spatial_fn(sol, form, mesh, dtype, comp,
                                      out_spec, f_m, f_n, m_mult,
                                      reduce_partial)
    else:
        fn = _dense_k_spatial_fn(sol, form, mesh, dtype, in_specs,
                                 out_spec, kmult, f_b, f_n, m_mult,
                                 reduce_partial)
    return MeshProgram(sol.strategy, in_specs, out_spec,
                       sol.ring_axes, (f_m, f_n, kmult), sol, fn)


def _dense_k_spatial_fn(sol, form, mesh, dtype, in_specs, out_spec, kmult,
                        f_b, f_n, m_mult, reduce_partial):
    batched = bool(form.batch)

    def body(l, r):
        return reduce_partial(_contract(l, r)).astype(dtype)

    def run(lhs, rhs):
        b, m, n = form.batch_size, lhs.shape[-2], rhs.shape[-1]
        lhs = _pad_dim(_pad_dim(lhs, -1, kmult), -2, m_mult)
        rhs = _pad_dim(_pad_dim(rhs, -2, kmult), -1, f_n)
        if batched:
            if form.lhs_batched:
                lhs = _pad_dim(lhs, -3, f_b)
            if form.rhs_batched:
                rhs = _pad_dim(rhs, -3, f_b)
        out = jax_compat.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
            check_vma=False)(lhs, rhs)
        out = out[..., :m, :n]
        return out[:b] if batched else out

    return jax.jit(run)


def _compressed_k_spatial_fn(sol, form, mesh, dtype, comp, out_spec,
                             f_m, f_n, m_mult, reduce_partial):
    """Compressed operand under a k-spatial plan: every device holds only
    the nonzero blocks of its own (stat-shard, k-shard) tile — local-frame
    k coordinates against the dense side's k-shard — and the reduction
    (psum tree or staggered output ring) runs on the partial products."""
    sp_side = comp.side
    sp_tp = sol.lhs if sp_side == "lhs" else sol.rhs
    b0, b1 = comp.block
    b_stat, b_k = (b0, b1) if sp_side == "lhs" else (b1, b0)
    stat_ax = sp_tp.axis_of.get("m" if sp_side == "lhs" else "n")
    f_stat = plan_mod._axis_factor(stat_ax, sol.sizes)
    stat_blocks = ((comp.d0_pad if sp_side == "lhs" else comp.d1_pad)
        // (b_stat * f_stat))
    dn_tp = sol.rhs if sp_side == "lhs" else sol.lhs
    dense_spec = _spec_of(dn_tp)
    triple_specs = (P(*sol.axes, None, None, None),
                    P(*sol.axes, None), P(*sol.axes, None))
    sc = jnp.asarray(comp.stat_c)
    kc = jnp.asarray(comp.k_c)

    def body(pay, sc_b, kc_b, dense):
        pay, sc_b, kc_b = pay[0, 0], sc_b[0, 0], kc_b[0, 0]
        part = _bsr_contract(pay, sc_b, kc_b, dense, sp_side,
                             stat_blocks, b_stat, b_k)
        if sol.stagger and part.shape[-2] % m_mult:
            part = _pad_dim(part, -2, m_mult)
        return reduce_partial(part).astype(dtype)

    def run(lhs, rhs):
        m, n = lhs.shape[-2], rhs.shape[-1]
        sp2d, dn2d = (lhs, rhs) if sp_side == "lhs" else (rhs, lhs)
        pay = _pack_payload(sp2d, comp)
        if sp_side == "lhs":
            dn2d = _pad_dim(_pad_dim(dn2d, -1, f_n), -2, comp.d1_pad)
            dn2d = dn2d[:comp.d1_pad]
        else:
            dn2d = _pad_dim(_pad_dim(dn2d, -2, max(f_m, m_mult)),
                            -1, comp.d0_pad)
            dn2d = dn2d[:, :comp.d0_pad]
        out = jax_compat.shard_map(
            body, mesh=mesh, in_specs=(*triple_specs, dense_spec),
            out_specs=out_spec, check_vma=False)(pay, sc, kc, dn2d)
        return out[..., :m, :n]

    return jax.jit(run)


# ---------------------------------------------------------------------------
# Introspection: kind -> spec table for one plan (used by docs and tests)
# ---------------------------------------------------------------------------

def describe(comm: CommPlan, form: "LoweredForm", mesh: Mesh
             ) -> Dict[str, str]:
    """Human-readable per-tensor realization of a CommPlan on a mesh."""
    prog = compile_comm_plan(comm, form, mesh)
    lines = {"strategy": prog.strategy,
             "lhs_spec": str(prog.in_specs[0]),
             "rhs_spec": str(prog.in_specs[1]),
             "out_spec": str(prog.out_spec)}
    lines.update(prog.solution.describe())
    for t in comm.tensors:
        ax = ",".join(t.mesh_axes) if t.mesh_axes else "-"
        lines[t.tensor] = f"{t.kind}[{ax}]"
    return lines
