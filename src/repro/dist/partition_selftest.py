"""Unified-partition selftests (run in a fresh interpreter).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.dist.partition_selftest

On 8 fake CPU devices, the acceptance battery for the partition solver:

  * **Degenerate + skewed meshes**: every registry algebra under every
    named STT executes correctly on 1x1, 1x8, 8x1, 2x4 and 2x2 meshes
    with deliberately non-divisible loop bounds — every CommPlan kind
    goes through every mesh shape.
  * **No silent replication**: for every case above, the solver's
    reported partition shards at least one dim of every input side, and
    batched forms shard their batch dim (the degenerate replicating
    solution never fires for the registry).
  * **Batch sharding**: batched_gemv / depthwise_conv per-device operand
    bytes shrink ~1/|batch axis| vs the ``shard_batch=False`` replicating
    baseline, with parity intact.
  * **Compressed collectives**: block-sparse operands ship as BSR
    payloads + coordinate lists (solution reports ``compressed``) with
    parity against the masked dense oracle, and their per-device stored
    bytes scale with density vs the ``sparse='dense'`` baseline.
  * **Executed dt staggering**: input-systolic plans run the
    ``k_spatial_stagger`` ppermute schedule; the mobile (output) tensor
    stores 1/S per device instead of a full replica.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from jax.sharding import Mesh

import repro
from repro.core import algebra
from repro.core.algebra import Sparsity

#: deliberately non-divisible bounds: every mesh shape below forces
#: padding on at least one dim
SKEWED_BOUNDS = {
    "gemm": dict(m=6, n=10, k=7),
    "batched_gemv": dict(m=5, k=6, n=9),
    "conv2d": dict(k=8, c=4, y=6, x=6, p=3, q=3),
    "depthwise_conv": dict(k=6, y=5, x=5, p=2, q=2),
    "mttkrp": dict(i=8, j=8, k=4, l=4),
    "ttmc": dict(i=4, j=4, k=4, l=4, m=4),
}
NAMED_DATAFLOWS = ("identity", "output_stationary", "weight_stationary",
                   "input_stationary")
MESH_SHAPES = ((1, 1), (1, 8), (8, 1), (2, 4), (2, 2))
BATCHED = ("batched_gemv", "depthwise_conv")


def mesh_of(rows: int, cols: int) -> Mesh:
    devs = np.asarray(jax.devices()[:rows * cols]).reshape(rows, cols)
    return Mesh(devs, ("x", "y"))


def check_degenerate_meshes() -> None:
    """Every algebra x named dataflow x mesh shape: parity + solver
    asserts (no replicated inputs; batch sharded whenever an axis is
    free)."""
    for name in sorted(algebra.PAPER_ALGEBRAS):
        alg = algebra.get_algebra(name, **SKEWED_BOUNDS[name])
        operands = alg.random_operands(seed=3)
        want = alg.reference(operands)
        strategies = set()
        for dfname in NAMED_DATAFLOWS:
            acc = repro.generate(alg, dfname, validate=False)
            for shape in MESH_SHAPES:
                sh = acc.sharded(mesh_of(*shape))
                sol = sh.partition
                got = np.asarray(sh(operands)).round().astype(np.int64)
                np.testing.assert_array_equal(got, want, err_msg=(
                    f"{name} x {dfname} on {shape} ({sol.strategy})"))
                assert not sol.replicated_inputs(), (
                    f"{name} x {dfname} on {shape}: inputs "
                    f"{sol.replicated_inputs()} silently replicated")
                if name in BATCHED:
                    assert sol.batch_axis is not None, (
                        f"{name} x {dfname} on {shape}: batch replicated "
                        f"(solution {sol.describe()})")
                strategies.add(sol.strategy)
        print(f"degenerate-mesh {name:15s} "
              f"{len(NAMED_DATAFLOWS) * len(MESH_SHAPES)} cases "
              f"strategies={sorted(strategies)}")


def check_batch_shard_footprint() -> None:
    """Batch-sharded operands store ~1/|axis| of the replicating
    baseline per device, at full parity."""
    mesh = mesh_of(2, 4)
    for name in BATCHED:
        bounds = dict(SKEWED_BOUNDS[name])
        bounds["m" if name == "batched_gemv" else "k"] = 8   # divisible b
        alg = algebra.get_algebra(name, **bounds)
        acc = repro.generate(alg, validate=False)
        sharded = acc.sharded(mesh)
        baseline = acc.sharded(mesh, shard_batch=False)
        operands = alg.random_operands(seed=5)
        want = alg.reference(operands)
        for a in (sharded, baseline):
            got = np.asarray(a(operands)).round().astype(np.int64)
            np.testing.assert_array_equal(got, want)
        form = acc.kernel.form
        f_b = sharded.partition.sizes[sharded.partition.batch_axis]
        new = sharded.partition.per_device_bytes(form)
        old = baseline.partition.per_device_bytes(form)
        assert baseline.partition.batch_axis is None
        for side in ("lhs", "rhs", "out"):
            ratio = new[side] / old[side]
            assert abs(ratio - 1.0 / f_b) < 1e-9, (name, side, ratio)
        print(f"batch-shard {name:15s} batch_axis="
              f"{sharded.partition.batch_axis} per-device bytes = "
              f"1/{f_b} of replicating baseline")


def check_compressed_collectives() -> None:
    """BSR operands ship compressed through the collectives: parity at
    several densities, stored bytes scale with density vs the masked
    dense baseline, and no device ever holds the dense operand."""
    for shape in ((2, 2), (2, 4)):
        mesh = mesh_of(*shape)
        for density in (0.25, 0.5, 1.0):
            sp = Sparsity.random((16, 16), (4, 4), density, seed=7)
            alg = algebra.gemm(16, 16, 16).with_sparsity(A=sp)
            acc = repro.generate(alg, interpret=True)
            assert acc.kernel.sparse_mode == "bsr"
            sharded = acc.sharded(mesh)                   # compressed
            baseline = acc.sharded(mesh, sparse="dense")  # masked dense
            sol = sharded.partition
            assert sol.lhs.compressed, sol.describe()
            assert not baseline.partition.lhs.compressed
            operands = alg.random_sparse_inputs(seed=11)
            want = alg.reference(operands)
            for a in (sharded, baseline):
                got = np.asarray(a(operands)).round().astype(np.int64)
                np.testing.assert_array_equal(got, want)
            form = acc.kernel.form
            comp = sol.per_device_bytes(form)["lhs"]
            dense = baseline.partition.per_device_bytes(form)["lhs"]
            # payload ~ density x dense shard + coordinate metadata
            assert comp <= dense * density + 64, (density, comp, dense)
            print(f"compressed {shape} density={density:.2f} "
                  f"{sol.strategy:12s} lhs {comp:.0f}B/dev vs dense "
                  f"{dense:.0f}B/dev")
    # sparse rhs + conv2d block-sparse-im2col + mttkrp mode-1 unfolding
    mesh = mesh_of(2, 2)
    cases = [
        ("gemm-B", algebra.gemm(16, 16, 16).with_sparsity(
            B=Sparsity.random((16, 16), (4, 4), 0.5, seed=9)), "rhs"),
        ("conv2d-B", algebra.conv2d(k=8, c=4, y=6, x=6, p=3, q=3)
         .with_sparsity(B=Sparsity.random((8, 4, 3, 3), (2, 2, 3, 3),
                                          0.5, seed=5)), "lhs"),
        ("mttkrp-A", algebra.mttkrp(8, 8, 4, 4).with_sparsity(
            A=Sparsity.random((8, 4, 4), (2, 2, 4), 0.5, seed=5)), "lhs"),
    ]
    for label, alg, side in cases:
        acc = repro.generate(alg, interpret=True)
        sharded = acc.sharded(mesh)
        sol = sharded.partition
        tp = sol.lhs if side == "lhs" else sol.rhs
        assert tp.compressed, (label, sol.describe())
        operands = alg.random_sparse_inputs(seed=11)
        got = np.asarray(sharded(operands)).round().astype(np.int64)
        np.testing.assert_array_equal(got, alg.reference(operands))
        print(f"compressed {label:10s} side={side} "
              f"{sol.strategy:17s} OK")


def check_stagger_schedule() -> None:
    """Input-systolic plans execute the staggered ppermute schedule and
    the mobile (rotating output) tensor stores 1/S per device."""
    alg = algebra.gemm(16, 16, 16)
    operands = alg.random_operands(seed=3)
    want = alg.reference(operands)
    for shape, S in (((2, 4), 4), ((2, 2), 2), ((1, 8), 8)):
        acc = repro.generate(alg, "weight_stationary", validate=False)
        sh = acc.sharded(mesh_of(*shape))
        sol = sh.partition
        assert sol.strategy == "k_spatial_stagger", sol.strategy
        assert sol.out.motion == "ppermute_ring"
        assert sol.out.axis_of["m"] == sol.ring_axes[0]
        got = np.asarray(sh(operands)).round().astype(np.int64)
        np.testing.assert_array_equal(got, want)
        form = acc.kernel.form
        out_bytes = sol.per_device_bytes(form)["out"]
        full = form.m * form.n * 4
        # the m dim is chunked 1/S by the rotation schedule (n may shard
        # the other axis on top): at most 1/S of the replica the old
        # k_spatial_ring stored per device
        assert out_bytes * S <= full, (out_bytes, full, S)
        print(f"stagger {shape} S={S}: out stores "
              f"{out_bytes:.0f}B/dev vs {full}B replicated (<= 1/{S})")


def check_batched_sparse_slices() -> None:
    """Sparse batched forms skip all-zero batch slices and still match
    the masked dense oracle on the mesh."""
    sp = Sparsity((2, 2), ((0, 0), (0, 1), (2, 0)))
    alg = (algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
        .with_sparsity(B=sp))
    acc = repro.generate(alg, interpret=True)
    form = acc.kernel.form
    assert form.batch_keep == (0, 1, 4, 5), form.batch_keep
    rep = acc.cost_report()
    assert rep.executed_mac_ratio < 1.0 / rep.work_density, (
        "slice skipping did not reduce executed MACs")
    sh = acc.sharded(mesh_of(2, 2))
    operands = alg.random_sparse_inputs(seed=1)
    got = np.asarray(sh(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, alg.reference(operands))
    print(f"batched-sparse batched_gemv keeps {form.batch}"
          f"/{form.batch_full} slices, ratio "
          f"{rep.executed_mac_ratio:.2f} < {1.0 / rep.work_density:.2f}")


def main() -> None:
    assert len(jax.devices()) >= 8, "partition selftest needs 8 fake devices"
    check_degenerate_meshes()
    check_batch_shard_footprint()
    check_compressed_collectives()
    check_stagger_schedule()
    check_batched_sparse_slices()
    print("ALL PARTITION SELFTESTS PASSED")


if __name__ == "__main__":
    main()
