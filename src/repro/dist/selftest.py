"""Distributed STT-GEMM engine selftests (run in a fresh interpreter).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.dist.selftest

Checks, on 8 fake devices:
  * CommPlan -> schedule classification for the classic GEMM STTs,
  * SUMMA (all_gather schedule) vs the jnp oracle on a 2x4 mesh,
  * ring-reduce (psum schedule) vs the oracle on a 2x4 mesh,
  * Cannon (ppermute-ring schedule) vs the oracle on a 2x2 submesh,
  * schedule selection driven end-to-end from apply_stt + comm_plan_for.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import algebra, plan, stt
from repro.dist import engine, schedules


def _gemm_schedule(kind: str):
    g = algebra.gemm(32, 32, 32)
    df = stt.apply_stt(g, ("m", "n", "k"), stt.stt_from_name(kind))
    return df, schedules.schedule_from_comm_plan(plan.comm_plan_for(df))


def main() -> None:
    assert len(jax.devices()) >= 8, "selftest needs 8 fake devices"
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    want = np.asarray(a) @ np.asarray(b)

    # 1. classification of the classic STTs
    _, summa = _gemm_schedule("identity")
    assert summa.name == "summa", summa
    df_sst, cannon = _gemm_schedule("output_stationary")
    assert cannon.name == "cannon", cannon
    _, hybrid = _gemm_schedule("weight_stationary")
    assert hybrid.name == "hybrid", hybrid
    print(f"schedule classification: {summa} / {cannon} / {hybrid}")

    # 2. SUMMA on the full 2x4 mesh
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("x", "y"))
    got = engine.summa_matmul(a, b, mesh)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    print("summa_matmul (2x4 mesh) matches oracle")

    # 3. ring-reduce (K spatial -> psum output) on the 2x4 mesh
    got = engine.ring_reduce_matmul(a, b, mesh)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    print("ring_reduce_matmul (2x4 mesh) matches oracle")

    # 4. Cannon on a square 2x2 submesh (systolic ppermute rings)
    sq = engine.square_submesh(2)
    got = engine.cannon_matmul(a, b, sq)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    print("cannon_matmul (2x2 mesh) matches oracle")

    # 5. end-to-end: the SST dataflow's own comm plan drives Cannon
    assert df_sst.name == "MNK-SST"
    kinds = {t.tensor: t.kind for t in plan.comm_plan_for(df_sst).tensors}
    assert kinds == {"A": "ppermute_ring", "B": "ppermute_ring",
                     "C": "shard"}
    print("ALL DIST SELFTESTS PASSED")


if __name__ == "__main__":
    main()
