"""Mesh-level realization of generated CommPlans (DESIGN.md level 2).

The compile pipeline (``repro.compile``) executes the intra-chip
KernelPlan; this package executes the *inter-chip* half of a generated
accelerator: each ``TensorCommPlan.kind`` maps to a shard_map collective
(all_gather = multicast wires, psum = reduction tree, ppermute ring =
systolic nearest-neighbour links, shard = stationary residency).

Modules:
    comm_engine — the generic CommPlan interpreter: any generated plan ->
                  shard_map program (``compile_comm_plan``); what
                  ``repro.generate(...).sharded(mesh)`` executes
    schedules — CommPlan -> named collective schedule (SUMMA / Cannon / ...)
    engine    — hand-written shard_map GEMM schedules, kept as the test
                oracles the interpreter is checked against
    selftest  — executes every schedule on fake devices vs the jnp oracle
    comm_selftest — interpreter parity: every registry algebra sharded vs
                single-chip, plus SUMMA/Cannon/ring-reduce-as-oracle
                (both run as ``python -m repro.dist.<name>`` with
                ``--xla_force_host_platform_device_count=8``)
    serve_selftest — continuous-batching page pools sharded through the
                partition solver stay bit-identical to unsharded decode
"""
from . import comm_engine, engine, schedules
from .comm_engine import compile_comm_plan
from .schedules import schedule_from_comm_plan

__all__ = ["comm_engine", "compile_comm_plan", "engine", "schedules",
           "schedule_from_comm_plan"]
