"""Mesh-level realization of generated CommPlans (DESIGN.md level 2).

The compile pipeline (``repro.compile``) executes the intra-chip
KernelPlan; this package executes the *inter-chip* half of a generated
accelerator: each ``TensorCommPlan.kind`` maps to a shard_map collective
(all_gather = multicast wires, psum = reduction tree, ppermute ring =
systolic nearest-neighbour links, shard = stationary residency).

Modules:
    schedules — CommPlan -> named collective schedule (SUMMA / Cannon / ...)
    engine    — shard_map GEMM realizations of the classic schedules
    selftest  — executes every schedule on fake devices vs the jnp oracle
                (run as ``python -m repro.dist.selftest`` with
                ``--xla_force_host_platform_device_count=8``)
"""
from . import engine, schedules
from .schedules import schedule_from_comm_plan

__all__ = ["engine", "schedules", "schedule_from_comm_plan"]
