"""CommPlan -> named mesh-level collective schedule.

``plan.comm_plan_for`` emits one collective kind per tensor; the *set* of
kinds identifies the classic distributed-GEMM algorithm the dataflow maps
to on a chip mesh (the paper's PE-array wires, chip-scale):

    all_gather inputs + sharded output      -> SUMMA
    ppermute-ring inputs + sharded output   -> Cannon
    sharded operand + psum output           -> ring reduce-scatter family
    streamed (unicast) operand              -> fully-partitioned streaming
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from ..core.plan import CommPlan


@dataclasses.dataclass(frozen=True)
class CollectiveSchedule:
    """A named schedule plus the per-tensor collective ops realizing it."""

    name: str
    comm: CommPlan

    @property
    def per_tensor(self) -> Tuple[Tuple[str, str], ...]:
        return tuple((t.tensor, t.kind) for t in self.comm.tensors)

    def __str__(self) -> str:
        ops = " ".join(f"{t}:{k}" for t, k in self.per_tensor)
        return f"{self.name}[{ops}]"


def schedule_from_comm_plan(comm: CommPlan) -> CollectiveSchedule:
    """Classify a generated CommPlan as a named distributed algorithm."""
    kinds = [t.kind for t in comm.tensors]
    out_kind = kinds[-1]
    in_kinds = kinds[:-1]

    if out_kind == "psum":
        name = "ring-reduce"              # partial sums combined on the mesh
    elif all(k == "all_gather" for k in in_kinds):
        name = "summa"                    # multicast panels, local rank-k
    elif all(k == "ppermute_ring" for k in in_kinds):
        name = "cannon"                   # skewed blocks circulate on rings
    elif "stream" in in_kinds:
        name = "streaming"                # an operand has no reuse to exploit
    elif "ppermute_ring" in in_kinds or "all_gather" in in_kinds:
        name = "hybrid"                   # mixed stationary/moving operands
    else:
        name = "local"                    # fully sharded, no motion
    return CollectiveSchedule(name, comm)
