"""Feed-forward layers: SwiGLU MLP and top-k MoE with capacity dispatch.

The MoE uses gather/scatter dispatch (indices (E, C) per token group)
instead of GShard's dense one-hot dispatch einsum — the (tokens, E, C)
one-hot tensor is the memory hog that caps MoE scale; the index form is
O(E*C) and shards cleanly.  Expert weights carry an 'expert' leading axis
and are TP-sharded on d_ff ('mlp' logical axis) — EP via all_to_all is a
config option exercised on small meshes (tests) where n_experts divides the
axis; at 256 chips with 8 experts, TP-inside-experts is the production
layout (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import Leaf, shard, shard_pinned, stacked_dense_init


# ---------------------------------------------------------------------------
# Dense SwiGLU
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, n_layers: int) -> Dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wg": stacked_dense_init(ks[0], n_layers, d, f, ("embed", "mlp")),
        "wu": stacked_dense_init(ks[1], n_layers, d, f, ("embed", "mlp")),
        "wd": stacked_dense_init(ks[2], n_layers, f, d, ("mlp", "embed")),
    }


def apply_mlp(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    compute = jnp.dtype(cfg.dtype)
    if cfg.explicit_collectives and cfg.sequence_parallel:
        # fully-manual dataflow: gather + dots + reduce-scatter in ONE
        # shard_map (keeps the backward manual as well)
        from .explicit_tp import mlp_manual
        res = mlp_manual(x, p["wg"], p["wu"], p["wd"], compute)
        if res is not None:
            return res.astype(x.dtype)
    # SP -> TP boundary: gather the (bf16) sequence shards here, NOT inside
    # the fp32 norm internals (keeps the all-gather at half width)
    xc = x.astype(compute)
    if cfg.explicit_collectives:
        from .explicit_tp import gather_seq
        xg = gather_seq(xc)
        xc = xg if xg is not None else shard_pinned(
            xc, ("pod", "data"), None, None)
    else:
        xc = shard_pinned(xc, ("pod", "data"), None, None)
    g = xc @ p["wg"].astype(compute)
    u = xc @ p["wu"].astype(compute)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute) * u
    h = shard(h, ("pod", "data"), None, "model")
    wd = p["wd"].astype(compute)
    if cfg.explicit_collectives and cfg.sequence_parallel:
        from .explicit_tp import project_scatter
        res = project_scatter(h, wd)
        if res is not None:
            return res.astype(x.dtype)
    out = jnp.dot(h, wd, preferred_element_type=jnp.float32)
    if cfg.sequence_parallel:
        # TP -> SP boundary: constrain the raw dot output (before any
        # convert) so the partitioner emits a reduce-scatter, not
        # all-reduce + slice
        out = shard(out, ("pod", "data"), "model", None)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (top-k, capacity-based, gather/scatter dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, n_layers: int) -> Dict:
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = (1.0 / d) ** 0.5

    def expert_w(k, din, dout, axes):
        w = jax.random.normal(k, (n_layers, e, din, dout), jnp.float32)
        return Leaf(w * (1.0 / din) ** 0.5, ("layers", "expert", *axes))

    return {
        "router": stacked_dense_init(ks[0], n_layers, d, e,
                                     ("embed", None), scale=scale),
        "wg": expert_w(ks[1], d, f, ("embed", "mlp")),
        "wu": expert_w(ks[2], d, f, ("embed", "mlp")),
        "wd": expert_w(ks[3], f, d, ("mlp", "embed")),
    }


def _dispatch_indices(top_idx: jax.Array, n_experts: int, capacity: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """top_idx: (T, K) expert choice per token/slot.

    Returns (token_slot (E, C) int32 index into T*K flat choices — entries
    >= T*K mean empty —, keep_mask (T, K) bool for choices that won the
    capacity race).  Priority: token order, then slot (GShard-style).
    """
    t, k = top_idx.shape
    flat = top_idx.reshape(-1)                                 # (T*K,)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                       # slot in expert
    my_pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = my_pos < capacity
    # scatter flat-choice id into (E, C); dropped entries scatter nowhere
    buf = jnp.full((n_experts, capacity), t * k, jnp.int32)
    e_idx = jnp.where(keep, flat, n_experts)       # out-of-range -> dropped
    c_idx = jnp.where(keep, my_pos, capacity)
    buf = buf.at[e_idx, c_idx].set(jnp.arange(t * k, dtype=jnp.int32),
                                   mode="drop")
    return buf, keep.reshape(t, k)


def apply_moe(p: Dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  Router in fp32.

    Tokens are grouped by batch row (G = B groups of S tokens) so dispatch
    stays local to the data shard; capacity is per group.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    compute = jnp.dtype(cfg.dtype)
    if cfg.explicit_collectives and cfg.sequence_parallel:
        from .explicit_tp import moe_manual
        res = moe_manual(x, p, cfg, compute)
        if res is not None:
            return res[0].astype(x.dtype), res[1]
    x = shard(x, ("pod", "data"), None, None)        # SP -> TP gather
    capacity = int(s * k / e * cfg.capacity_factor + 1)

    logits = (x.astype(jnp.float32) @
              p["router"].astype(jnp.float32))                 # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, top_idx = jax.lax.top_k(probs, k)                   # (B, S, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style) + router z-loss
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0 / (b * s * k))
    aux = e * jnp.sum(me * ce) + 1e-3 * jnp.mean(
        jax.nn.logsumexp(logits, -1) ** 2)

    def per_group(xg, idxg, gateg):
        """xg: (S, D); idxg: (S, K); gateg: (S, K)."""
        slots, keep = _dispatch_indices(idxg, e, capacity)     # (E, C)
        token_of = slots // k                                  # (E, C)
        valid = slots < s * k
        safe_token = jnp.minimum(token_of, s - 1)
        xin = jnp.where(valid[..., None],
                        jnp.take(xg, safe_token, axis=0),
                        0.0).astype(compute)                   # (E, C, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin,
                                   p["wg"].astype(compute)).astype(jnp.float32)
                        ).astype(compute)
        h = h * jnp.einsum("ecd,edf->ecf", xin, p["wu"].astype(compute))
        out_e = jnp.einsum("ecf,efd->ecd", h,
                           p["wd"].astype(compute))            # (E, C, D)
        # combine: scatter expert outputs back to tokens, weighted by gates
        gate_flat = (gateg * keep).reshape(-1)                 # (S*K,)
        w = jnp.where(valid, jnp.take(gate_flat, jnp.minimum(slots, s * k - 1)),
                      0.0)                                     # (E, C)
        contrib = (out_e.astype(jnp.float32) * w[..., None]
                   ).reshape(e * capacity, d)
        scatter_idx = jnp.where(valid, safe_token, s).reshape(-1)
        outg = jnp.zeros((s, d), jnp.float32).at[scatter_idx].add(
            contrib, mode="drop")
        return outg

    out = jax.vmap(per_group)(x, top_idx, gates)
    out = out.astype(x.dtype)
    if cfg.sequence_parallel:
        out = shard(out, ("pod", "data"), "model", None)   # TP -> SP
    return out, aux
