"""Shared model machinery: parameters with logical sharding axes, norms,
RoPE, losses.

Parameters are built as ``Leaf(value, logical_axes)`` pytrees; ``split``
separates them into (params, PartitionSpec) trees.  Logical axes map to mesh
axes through ``AxisRules`` (MaxText-style), with a divisibility fallback so
one rule set serves all ten architectures (e.g. whisper's 12 heads can't
shard over a 16-way model axis and silently fall back to replicated).

This is the mesh-level half of the paper's technique applied to the LM
stack: a tensor whose reuse class is *stationary* along an axis gets sharded
there (memory bank assignment, deviation D4), *multicast* tensors are
replicated/all-gathered, *reduction* outputs psum — see dist/schedules.py
for the explicit GEMM schedules and train/loss.py for their use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import jax_compat


# ---------------------------------------------------------------------------
# Parameter leaves with logical axes
# ---------------------------------------------------------------------------

@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Logical:
    """Static marker carrying logical axis names for one param."""
    axes: Tuple[Optional[str], ...]


class Leaf(tuple):
    """(value, Logical) pair that tree_map treats as a leaf via is_leaf."""
    def __new__(cls, value, axes):
        return super().__new__(cls, (value, Logical(tuple(axes))))


def is_leaf(x) -> bool:
    return isinstance(x, Leaf)


def split(tree) -> Tuple[Any, Any]:
    """Leaf pytree -> (params pytree, logical-axes pytree)."""
    params = jax.tree.map(lambda l: l[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda l: l[1], tree, is_leaf=is_leaf)
    return params, axes


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis -> mesh axis (or tuple of mesh axes)."""
    rules: Dict[str, Union[str, Tuple[str, ...], None]]

    def spec_for(self, axes: Logical, shape: Tuple[int, ...],
                 mesh_shape: Dict[str, int]) -> P:
        out = []
        for dim, name in zip(shape, axes.axes):
            mesh_ax = self.rules.get(name) if name else None
            if mesh_ax is None:
                out.append(None)
                continue
            size = 1
            for ax in ((mesh_ax,) if isinstance(mesh_ax, str) else mesh_ax):
                size *= mesh_shape.get(ax, 1)
            # divisibility fallback: replicate rather than force padding
            out.append(mesh_ax if dim % size == 0 else None)
        return P(*out)

    def specs(self, axes_tree, shapes_tree, mesh_shape) -> Any:
        return jax.tree.map(
            lambda a, s: self.spec_for(a, s.shape, mesh_shape),
            axes_tree, shapes_tree,
            is_leaf=lambda x: isinstance(x, Logical))


#: default rules for the production mesh (pod, data, model):
#:   fsdp  — parameter & optimizer-state sharding over the data axis (ZeRO-3)
#:   tp    — tensor-parallel over the model axis
DEFAULT_RULES = AxisRules({
    "embed": "data",        # d_model dim of weights: FSDP
    "heads": "model",       # attention heads / q projection out-dim
    "kv": "model",          # kv projection out-dim (flattened kv_dim)
    "mlp": "model",         # d_ff
    "vocab": "model",       # embedding table / logits
    "layers": None,         # stacked-scan layer dim stays unsharded
    "expert": None,         # experts replicated; TP inside experts ("mlp")
    "ssm_inner": "model",   # mamba d_inner
    "ssm_state": None,
    "batch": ("pod", "data"),
    "seq": "model",         # sequence parallelism for residual activations
})


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int,
               axes: Sequence[Optional[str]],
               scale: Optional[float] = None) -> Leaf:
    scale = scale if scale is not None else (1.0 / in_dim) ** 0.5
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return Leaf(w, axes)


def stacked_dense_init(key, n: int, in_dim: int, out_dim: int,
                       axes: Sequence[Optional[str]],
                       scale: Optional[float] = None) -> Leaf:
    scale = scale if scale is not None else (1.0 / in_dim) ** 0.5
    w = jax.random.normal(key, (n, in_dim, out_dim), jnp.float32) * scale
    return Leaf(w, ("layers", *axes))


def zeros_init(shape: Tuple[int, ...], axes: Sequence[Optional[str]]) -> Leaf:
    return Leaf(jnp.zeros(shape, jnp.float32), axes)


def ones_init(shape: Tuple[int, ...], axes: Sequence[Optional[str]]) -> Leaf:
    return Leaf(jnp.ones(shape, jnp.float32), axes)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array,
         theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding.  x: (..., L, D even), positions: (L,) or (B, L)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast across head dims: x (..., H, L, D) vs ang (L, half)/(B,L,half)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean CE in fp32; logits may stay vocab-sharded (the log-softmax
    reduction is over the last axis, which GSPMD keeps sharded)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint that resolves axis names against the active
    mesh: missing axes (e.g. 'pod' on a single-pod mesh) and non-divisible
    dims fall back to replicated; outside any mesh context it is a no-op.

    This keeps one set of constraints valid across the 1-device test mesh,
    the 16x16 pod and the 2x16x16 multi-pod mesh."""
    mesh = jax_compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    names = dict(zip(mesh.axis_names, mesh.axis_sizes))
    spec = []
    for dim, a in zip(x.shape, axes):
        cand = (a,) if (a is None or isinstance(a, str)) else tuple(a)
        cand = tuple(c for c in cand if c is not None and c in names)
        size = 1
        for c in cand:
            size *= names[c]
        if not cand or size <= 1 or dim % size != 0:
            spec.append(None)
        elif len(cand) == 1:
            spec.append(cand[0])
        else:
            spec.append(cand)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_pinned(x: jax.Array, *axes) -> jax.Array:
    """``shard`` + optimization barrier: pins the resharding collective to
    THIS value.  Used at SP->TP boundaries so the all-gather runs on the
    bf16 activation instead of being commuted past the f32 upcast that the
    CPU/XLA dot emulation inserts (which would double the wire bytes)."""
    y = shard(x, *axes)
    if y is x:
        return x
    return jax_compat.optimization_barrier(y)
