"""Model zoo: all architecture families, built from shared blocks."""
from . import attention, common, decode, mlp, ssm, transformer
from .common import AxisRules, DEFAULT_RULES, Leaf, cross_entropy, split
from .decode import decode_step, init_cache, prefill
from .transformer import forward, init_params

__all__ = ["attention", "common", "decode", "mlp", "ssm", "transformer",
           "AxisRules", "DEFAULT_RULES", "Leaf", "cross_entropy", "split",
           "decode_step", "init_cache", "prefill", "forward", "init_params"]
