"""Mamba-2 (SSD) block: projections + causal conv + chunked SSD + gate.

Training/prefill use the chunked SSD (kernels/ref.ssd_chunked_ref — the XLA
twin of the Pallas kernel); decode keeps an O(1) recurrent state
(B, H, N, P) plus a rolling conv window, which is what makes the 524k-token
decode cell run (sub-quadratic; see configs.base.sub_quadratic).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.ref import ssd_chunked_ref
from .common import Leaf, shard, stacked_dense_init


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssm(key, cfg: ModelConfig, n_layers: int) -> Dict:
    ks = jax.random.split(key, 5)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    cd = conv_dim(cfg)
    # in_proj emits [z (di) | x (di) | B (g n) | C (g n) | dt (h)]
    out_dim = 2 * di + 2 * g * n + h
    p = {
        "in_proj": stacked_dense_init(ks[0], n_layers, d, out_dim,
                                      ("embed", "ssm_inner")),
        "conv_w": Leaf(0.1 * jax.random.normal(
            ks[1], (n_layers, cfg.conv_kernel, cd), jnp.float32),
            ("layers", None, "ssm_inner")),
        "conv_b": Leaf(jnp.zeros((n_layers, cd), jnp.float32),
                       ("layers", "ssm_inner")),
        "a_log": Leaf(jnp.log(jnp.broadcast_to(
            jnp.linspace(1.0, 16.0, h), (n_layers, h))),
            ("layers", None)),
        "d_skip": Leaf(jnp.ones((n_layers, h), jnp.float32),
                       ("layers", None)),
        "dt_bias": Leaf(jnp.zeros((n_layers, h), jnp.float32),
                        ("layers", None)),
        "norm_g": Leaf(jnp.ones((n_layers, di), jnp.float32),
                       ("layers", "ssm_inner")),
        "out_proj": stacked_dense_init(ks[2], n_layers, di, d,
                                       ("ssm_inner", "embed")),
    }
    return p


def _split_proj(proj: jax.Array, cfg: ModelConfig):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * g * n]
    dt = proj[..., di + di + 2 * g * n:]
    return z, xbc, dt


def _gated_norm(y: jax.Array, z: jax.Array, gamma: jax.Array,
                eps: float) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return yf * jax.lax.rsqrt(var + eps) * gamma


def apply_ssm(p: Dict, x: jax.Array, cfg: ModelConfig, *,
              cache: Optional[Dict[str, jax.Array]] = None,
              collect_cache: bool = False,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, L, D).  With ``cache`` (decode): L == 1 and the recurrence
    advances one step.  ``collect_cache`` (prefill) returns the decode cache
    (rolling conv window + final SSD state).  Returns (out, new_cache)."""
    b, l, d = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    compute = jnp.dtype(cfg.dtype)

    xg = x.astype(compute)
    gathered = None
    if cfg.explicit_collectives:
        from .explicit_tp import gather_seq
        gathered = gather_seq(xg)
    xg = gathered if gathered is not None else shard(
        xg, ("pod", "data"), None, None)                        # SP gather
    proj = (xg @ p["in_proj"].astype(compute)).astype(jnp.float32)
    z, xbc, dt = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt + p["dt_bias"])                    # (B, L, H)
    a = -jnp.exp(p["a_log"])                                   # (H,)

    kconv = cfg.conv_kernel
    new_cache = None
    if cache is None:
        # pad L to a chunk multiple; padded steps get dt = 0 so they neither
        # move the state (decay = exp(0) = 1) nor contribute (dt*B*x = 0)
        chunk = min(cfg.ssm_chunk, l)
        lp = -(-l // chunk) * chunk
        if lp != l:
            xbc_c = jnp.pad(xbc, ((0, 0), (0, lp - l), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, lp - l), (0, 0)))
        else:
            xbc_c = xbc
        # causal depthwise conv over (x|B|C) channels
        pad = jnp.pad(xbc_c, ((0, 0), (kconv - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + lp] * p["conv_w"][i] for i in range(kconv))
        conv = jax.nn.silu(conv + p["conv_b"])
        xs = conv[..., :di].reshape(b, lp, h, ph)
        bs = conv[..., di:di + g * n].reshape(b, lp, g, n)
        cs = conv[..., di + g * n:].reshape(b, lp, g, n)
        # pin SSD head sharding: the quadratic (B, nc, H, Q, Q) intra-chunk
        # tensors must stay H-sharded over the model axis
        xs = shard(xs, ("pod", "data"), None, "model", None)
        dt = shard(dt, ("pod", "data"), None, "model")
        y, h_fin = ssd_chunked_ref(xs, dt, a, bs, cs, chunk=chunk)
        y, xs = y[:, :l], xs[:, :l]
        if collect_cache:
            new_cache = {"conv": xbc[:, l - (kconv - 1):].astype(jnp.float32),
                         "state": h_fin}
    else:
        # decode: rolling conv window (B, k-1, cd) + state (B, H, N, P)
        win = jnp.concatenate([cache["conv"], xbc], axis=1)    # (B, k, cd)
        conv = sum(win[:, i:i + 1] * p["conv_w"][i] for i in range(kconv))
        conv = jax.nn.silu(conv + p["conv_b"])                 # (B, 1, cd)
        xs = conv[..., :di].reshape(b, h, ph)
        bs = conv[..., di:di + g * n].reshape(b, g, n)
        cs = conv[..., di + g * n:].reshape(b, g, n)
        rep = h // g
        bh = jnp.repeat(bs, rep, axis=1)                       # (B, H, N)
        ch = jnp.repeat(cs, rep, axis=1)
        dt1 = dt[:, 0]                                         # (B, H)
        decay = jnp.exp(dt1 * a)                               # (B, H)
        h_new = decay[..., None, None] * cache["state"] + jnp.einsum(
            "bhn,bhp->bhnp", dt1[..., None] * bh, xs)
        y = jnp.einsum("bhn,bhnp->bhp", ch, h_new)[:, None]    # (B, 1, H, P)
        new_cache = {"conv": win[:, 1:], "state": h_new}
        xs = xs[:, None]                                       # for D skip

    y = y + p["d_skip"][:, None] * xs                          # D skip conn
    y = y.reshape(b, l, di)
    y = _gated_norm(y, z, p["norm_g"], cfg.norm_eps).astype(compute)
    y = shard(y, ("pod", "data"), None, "model")
    out = (y @ p["out_proj"].astype(compute)).astype(x.dtype)
    if cfg.sequence_parallel:
        out = shard(out, ("pod", "data"), "model", None)   # TP -> SP
    return out, new_cache


def make_ssm_cache(cfg: ModelConfig, batch: int,
                   dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim(cfg)), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                            cfg.ssm_head_dim), dtype),
    }
