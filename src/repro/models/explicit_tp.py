"""Explicit (STT-scheduled) collectives for the transformer hot paths.

GSPMD's auto-partitioner chooses intermediate shardings by local cost
heuristics; inside the chunked-attention scan it ping-pongs between
Lq-sharded and kv-head-sharded layouts (observed 19.2 TB/step of resharding
all-gathers on qwen2.5-32b prefill — EXPERIMENTS.md §Perf).  TensorLib's
thesis applied to the mesh level says: derive the dataflow once and emit the
collectives *explicitly*.  This module provides shard_map realizations of
the three schedules the classification picks for the LM stack:

  * ``gather_seq``       — SP -> TP boundary: bf16 all-gather of sequence
                           shards (multicast dataflow),
  * ``project_scatter``  — TP -> SP boundary: local partial dot + bf16
                           psum_scatter (reduction-tree dataflow, scattered),
  * ``chunked_attn_manual`` — the full attention inner loop under manual
                           sharding: q/output stationary-sharded over Lq,
                           K/V multicast (replicated), zero resharding.

Each helper falls back to the auto path when the mesh/shape doesn't allow
the manual layout (e.g. decode steps with Lq == 1).  Enabled per-config via
``ModelConfig.explicit_collectives``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import jax_compat



def _mesh_info():
    mesh = jax_compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return None, (), 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    return mesh, batch_axes, sizes.get("model", 1)


def _batch_ok(b: int, batch_axes, mesh) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    n = 1
    for a in batch_axes:
        n *= sizes[a]
    return b % n == 0 if n > 1 else True


def gather_seq(x: jax.Array) -> Optional[jax.Array]:
    """(B, S@model, D) -> (B, S, D) via explicit bf16 all-gather; None if
    the manual layout doesn't apply here."""
    mesh, bd, msize = _mesh_info()
    if mesh is None or msize <= 1 or x.ndim != 3:
        return None
    b, s, d = x.shape
    if s % msize or not _batch_ok(b, bd, mesh):
        return None
    bspec = bd if len(bd) > 1 else (bd[0] if bd else None)

    def body(xl):
        return lax.all_gather(xl, "model", axis=1, tiled=True)

    return jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=P(bspec, "model", None),
        out_specs=P(bspec, None, None),
        check_vma=False)(x)


def project_scatter(h: jax.Array, w: jax.Array) -> Optional[jax.Array]:
    """(B, S, F@model) @ (F@model, D) -> (B, S@model, D): local partial dot
    + bf16 psum_scatter over the model axis (reduction tree, scattered)."""
    mesh, bd, msize = _mesh_info()
    if mesh is None or msize <= 1 or h.ndim != 3:
        return None
    b, s, f = h.shape
    if s % msize or f % msize or not _batch_ok(b, bd, mesh):
        return None
    bspec = bd if len(bd) > 1 else (bd[0] if bd else None)

    def body(hl, wl):
        part = jnp.dot(hl, wl, preferred_element_type=jnp.float32)
        part = part.astype(h.dtype)        # reduce on the wire in bf16
        return lax.psum_scatter(part, "model", scatter_dimension=1,
                                tiled=True)

    return jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, "model"), P("model", None)),
        out_specs=P(bspec, "model", None),
        check_vma=False)(h, w)


def mlp_manual(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
               compute) -> Optional[jax.Array]:
    """The whole SwiGLU MLP as ONE manual dataflow:
    all-gather(x over seq) -> local wg/wu/silu/wd -> psum_scatter(out).

    Keeping the dots *inside* the shard_map makes the backward fully manual
    too (AG(dout) -> local dots -> RS(dx)); with the dots outside, the
    partitioner finishes the dx partial-sums with full all-reduces
    (observed 900 GiB/step on qwen1.5-110b — EXPERIMENTS.md §Perf)."""
    mesh, bd, msize = _mesh_info()
    if mesh is None or msize <= 1 or x.ndim != 3:
        return None
    b, s_loc_or_full, d = x.shape
    f = wg.shape[1]
    if s_loc_or_full % msize or f % msize or not _batch_ok(b, bd, mesh):
        return None
    bspec = bd if len(bd) > 1 else (bd[0] if bd else None)

    def body(xl, wgl, wul, wdl):
        xf = lax.all_gather(xl.astype(compute), "model", axis=1, tiled=True)
        g = xf @ wgl
        u = xf @ wul
        h = jax.nn.silu(g.astype(jnp.float32)).astype(compute) * u
        part = jnp.dot(h, wdl, preferred_element_type=jnp.float32)
        return lax.psum_scatter(part.astype(compute), "model",
                                scatter_dimension=1, tiled=True)

    return jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(None, "model"),
                  P(None, "model"), P("model", None)),
        out_specs=P(bspec, "model", None),
        check_vma=False)(x, wg.astype(compute), wu.astype(compute),
                         wd.astype(compute))


def qkv_manual(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
               compute) -> Optional[Tuple[jax.Array, jax.Array, jax.Array]]:
    """Gather(x over seq) + q/k/v projections in one manual dataflow.
    q comes back sharded on its head dim ('model'); k/v are psum-free local
    dots returned sharded the same way (callers re-gather the small kv)."""
    mesh, bd, msize = _mesh_info()
    if mesh is None or msize <= 1 or x.ndim != 3:
        return None
    b, s, d = x.shape
    if (s % msize or wq.shape[1] % msize or wk.shape[1] % msize
            or not _batch_ok(b, bd, mesh)):
        return None
    bspec = bd if len(bd) > 1 else (bd[0] if bd else None)

    def body(xl, wql, wkl, wvl):
        xf = lax.all_gather(xl.astype(compute), "model", axis=1, tiled=True)
        return xf @ wql, xf @ wkl, xf @ wvl

    spec_out = P(bspec, None, "model")
    return jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(None, "model"),
                  P(None, "model"), P(None, "model")),
        out_specs=(spec_out, spec_out, spec_out),
        check_vma=False)(x, wq.astype(compute), wk.astype(compute),
                         wv.astype(compute))


def moe_manual(x: jax.Array, p: dict, cfg, compute
               ) -> Optional[Tuple[jax.Array, jax.Array]]:
    """The whole MoE layer as ONE manual dataflow.

    gather(x over seq) -> local router/top-k/dispatch -> expert dots with
    d_ff sharded over 'model' -> local combine -> psum_scatter(out), which
    performs the f-partial reduction AND the TP->SP scatter in a single
    collective.  Auto-partitioning of the gather/scatter dispatch tensors
    was worth 8.6 TB/step of resharding on mixtral (EXPERIMENTS.md §Perf).
    """
    mesh, bd, msize = _mesh_info()
    if mesh is None or msize <= 1 or x.ndim != 3:
        return None
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    f = cfg.d_ff
    if s % msize or f % msize or not _batch_ok(b, bd, mesh):
        return None
    bspec = bd if len(bd) > 1 else (bd[0] if bd else None)
    capacity = int(s * k / e * cfg.capacity_factor + 1)
    from .mlp import _dispatch_indices

    def body(xl, router, wg, wu, wd):
        xf = lax.all_gather(xl.astype(compute), "model", axis=1, tiled=True)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, top_idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=(0, 1))
        ce = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(
            1.0 / (top_idx.size))
        aux = e * jnp.sum(me * ce) + 1e-3 * jnp.mean(
            jax.nn.logsumexp(logits, -1) ** 2)
        for ax in bd:
            aux = lax.pmean(aux, ax)

        def per_group(xg, idxg, gateg):
            slots, keep = _dispatch_indices(idxg, e, capacity)
            token_of = slots // k
            valid = slots < s * k
            safe_token = jnp.minimum(token_of, s - 1)
            xin = jnp.where(valid[..., None],
                            jnp.take(xg, safe_token, axis=0),
                            0.0).astype(compute)
            h = jax.nn.silu(jnp.einsum(
                "ecd,edf->ecf", xin, wg).astype(jnp.float32)).astype(compute)
            h = h * jnp.einsum("ecd,edf->ecf", xin, wu)
            out_e = jnp.einsum("ecf,efd->ecd", h, wd)      # f-shard partial
            gate_flat = (gateg * keep).reshape(-1)
            w = jnp.where(valid,
                          jnp.take(gate_flat, jnp.minimum(slots, s * k - 1)),
                          0.0)
            contrib = (out_e.astype(jnp.float32) * w[..., None]
                       ).reshape(e * capacity, d)
            scatter_idx = jnp.where(valid, safe_token, s).reshape(-1)
            return jnp.zeros((s, d), jnp.float32).at[scatter_idx].add(
                contrib, mode="drop")

        out = jax.vmap(per_group)(xf, top_idx, gates)      # (B_loc, S, D)
        # one collective: sum f-shard partials AND scatter back to seq shards
        out = lax.psum_scatter(out.astype(compute), "model",
                               scatter_dimension=1, tiled=True)
        return out, aux

    out, aux = jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(None, None),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=(P(bspec, "model", None), P()),
        check_vma=False)(
        x, p["router"], p["wg"].astype(compute), p["wu"].astype(compute),
        p["wd"].astype(compute))
    return out, aux


def chunked_attn_manual(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: Optional[int],
                        bkv: int = 1024) -> Optional[jax.Array]:
    """Online-softmax attention with q/out Lq-sharded over 'model' and K/V
    replicated (multicast) — the manual realization of the dataflow the
    classification picks, with zero partitioner resharding."""
    import os
    bkv = int(os.environ.get("REPRO_ATTN_BKV", bkv))
    mesh, bd, msize = _mesh_info()
    if mesh is None or msize <= 1:
        return None
    b, hq, lq, dh = q.shape
    lkv = k.shape[2]
    if lq % msize or lq // msize < 1 or not _batch_ok(b, bd, mesh):
        return None
    if lkv % bkv:
        bkv = next((bb for bb in (512, 256, 128, 64, 1) if lkv % bb == 0), 1)
    bspec = bd if len(bd) > 1 else (bd[0] if bd else None)
    from .attention import _chunked_attn

    def body(ql, kl, vl):
        off = lax.axis_index("model") * (lq // msize)
        return _chunked_attn(ql, kl, vl, causal=causal, window=window,
                             q_offset=off, bkv=bkv)

    return jax_compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, "model", None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, None, "model", None),
        check_vma=False)(q, k, v)
