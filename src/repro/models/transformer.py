"""Model assembly for all architecture families + the public forward pass.

Families:
    dense / moe — decoder-only, scan over uniform layers
    ssm         — Mamba-2 stack (attention-free)
    hybrid      — Mamba-2 backbone + ONE shared attn+MLP block applied every
                  ``attn_every`` layers (Zamba2-style parameter sharing);
                  implemented as grouped scans so each shared application
                  gets its own KV cache entry
    encdec      — whisper-style: bidirectional encoder over stub frames +
                  causal decoder with per-layer cross-attention
    vlm         — llama-3.2-vision-style: causal decoder, a gated
                  cross-attention block (to stub image embeddings) inserted
                  every ``cross_attn_every`` layers

Everything scans over stacked layer params (HLO size O(1) in depth, which
keeps 512-device compiles tractable — DESIGN.md §7.2), with optional remat.
``forward(..., collect_cache=True)`` additionally returns the decode caches
(prefill); ``models.decode`` consumes them.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import epilogue as epilogue_mod
from . import attention as attn
from . import mlp as mlp_mod
from . import ssm as ssm_mod
from .common import Leaf, is_leaf, ones_init, rmsnorm, shard


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _unstack(tree):
    """Strip the leading 'layers' axis from a stacked Leaf tree."""
    return jax.tree.map(lambda l: Leaf(l[0][0], l[1].axes[1:]), tree,
                        is_leaf=is_leaf)


def hybrid_groups(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, group_size, tail) for the zamba2 grouped scan."""
    g = cfg.attn_every
    n_apps = cfg.n_layers // g
    return n_apps, g, cfg.n_layers - n_apps * g


def init_params(key, cfg: ModelConfig) -> Any:
    """Returns a Leaf pytree (common.split() -> params, logical axes)."""
    keys = jax.random.split(key, 16)
    d = cfg.d_model
    p: Dict[str, Any] = {
        "embed": Leaf(0.02 * jax.random.normal(
            keys[0], (cfg.vocab, d), jnp.float32), ("vocab", "embed")),
        "final_norm": ones_init((d,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = Leaf((1.0 / d ** 0.5) * jax.random.normal(
            keys[1], (d, cfg.vocab), jnp.float32), ("embed", "vocab"))

    L = cfg.n_layers
    if cfg.family in ("dense", "moe"):
        p["layers"] = {
            "ln1": ones_init((L, d), ("layers", "embed")),
            "ln2": ones_init((L, d), ("layers", "embed")),
            "attn": attn.init_attention(keys[2], cfg, L),
            "ffn": (mlp_mod.init_moe(keys[3], cfg, L) if cfg.family == "moe"
                    else mlp_mod.init_mlp(keys[3], cfg, L)),
        }
    elif cfg.family in ("ssm", "hybrid"):
        p["layers"] = {
            "ln1": ones_init((L, d), ("layers", "embed")),
            "ssm": ssm_mod.init_ssm(keys[2], cfg, L),
        }
        if cfg.family == "hybrid":
            p["shared"] = {
                "ln1": ones_init((d,), ("embed",)),
                "ln2": ones_init((d,), ("embed",)),
                "attn": _unstack(attn.init_attention(keys[3], cfg, 1)),
                "mlp": _unstack(mlp_mod.init_mlp(keys[4], cfg, 1)),
            }
    elif cfg.family == "encdec":
        Le = cfg.n_enc_layers
        p["encoder"] = {
            "ln1": ones_init((Le, d), ("layers", "embed")),
            "ln2": ones_init((Le, d), ("layers", "embed")),
            "attn": attn.init_attention(keys[2], cfg, Le),
            "ffn": mlp_mod.init_mlp(keys[3], cfg, Le),
        }
        p["enc_norm"] = ones_init((d,), ("embed",))
        p["layers"] = {
            "ln1": ones_init((L, d), ("layers", "embed")),
            "ln2": ones_init((L, d), ("layers", "embed")),
            "ln3": ones_init((L, d), ("layers", "embed")),
            "attn": attn.init_attention(keys[4], cfg, L),
            "cross": attn.init_attention(keys[5], cfg, L),
            "ffn": mlp_mod.init_mlp(keys[6], cfg, L),
        }
    elif cfg.family == "vlm":
        p["layers"] = {
            "ln1": ones_init((L, d), ("layers", "embed")),
            "ln2": ones_init((L, d), ("layers", "embed")),
            "attn": attn.init_attention(keys[2], cfg, L),
            "ffn": mlp_mod.init_mlp(keys[3], cfg, L),
        }
        n_cross = L // cfg.cross_attn_every
        p["cross_layers"] = {
            "ln": ones_init((n_cross, d), ("layers", "embed")),
            "attn": attn.init_attention(keys[4], cfg, n_cross),
            "gate": Leaf(jnp.zeros((n_cross,), jnp.float32), ("layers",)),
        }
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# blocks (params already unstacked)
# ---------------------------------------------------------------------------

def _residual_shard(x, cfg):
    if cfg.sequence_parallel and x.ndim == 3:
        return shard(x, ("pod", "data"), "model", None)
    return shard(x, ("pod", "data"), None, None)


def _dense_block(pl_, x, cfg, *, causal=True, collect_kv=False):
    h, kv = attn.apply_attention(
        pl_["attn"], rmsnorm(x, pl_["ln1"], cfg.norm_eps), cfg,
        causal=causal, collect_kv=collect_kv)
    x = _residual_shard(x + h, cfg)
    if "router" in pl_["ffn"]:
        h, aux = mlp_mod.apply_moe(pl_["ffn"],
                                   rmsnorm(x, pl_["ln2"], cfg.norm_eps), cfg)
    else:
        h = mlp_mod.apply_mlp(pl_["ffn"], rmsnorm(x, pl_["ln2"], cfg.norm_eps),
                              cfg)
        aux = jnp.zeros((), jnp.float32)
    return _residual_shard(x + h, cfg), aux, kv


def _ssm_block(pl_, x, cfg, *, collect_cache=False):
    h, c = ssm_mod.apply_ssm(pl_["ssm"], rmsnorm(x, pl_["ln1"], cfg.norm_eps),
                             cfg, collect_cache=collect_cache)
    return _residual_shard(x + h, cfg), c


def _shared_block(ps, x, cfg, *, collect_kv=False):
    h, kv = attn.apply_attention(ps["attn"],
                                 rmsnorm(x, ps["ln1"], cfg.norm_eps), cfg,
                                 collect_kv=collect_kv)
    x = x + h
    h = mlp_mod.apply_mlp(ps["mlp"], rmsnorm(x, ps["ln2"], cfg.norm_eps), cfg)
    return _residual_shard(x + h, cfg), kv


# ---------------------------------------------------------------------------
# layer-scan helper
# ---------------------------------------------------------------------------

def scan_layers(stacked, x, body, cfg):
    """body(layer_params, x) -> (x, aux, ys).  Scans with optional remat."""
    def f(carry, pl_):
        x, aux = carry
        x, aux_l, ys = body(pl_, x)
        return (x, aux + aux_l), ys

    if cfg.remat:
        f = jax.checkpoint(f)
    (x, aux), ys = jax.lax.scan(f, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux, ys


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params: Dict, tokens: jax.Array, cfg: ModelConfig, *,
            frontend: Optional[jax.Array] = None,
            collect_cache: bool = False,
            ) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """tokens: (B, S) int32 -> (logits (B, S, V), aux_loss, caches|None).

    ``frontend`` feeds the stubbed modality input (vlm: (B, 1601, D) image
    patch embeddings; encdec: (B, 1500, D) audio frames)."""
    compute = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute)
    x = _residual_shard(x, cfg)
    cc = collect_cache
    caches: Dict[str, Any] = {}

    if cfg.family in ("dense", "moe"):
        def body(pl_, x):
            return _dense_block(pl_, x, cfg, collect_kv=cc)
        x, aux, kv = scan_layers(params["layers"], x, body, cfg)
        if cc:
            caches["self"] = kv                      # (L, B, S, kvd) tree

    elif cfg.family == "ssm":
        def body(pl_, x):
            x, c = _ssm_block(pl_, x, cfg, collect_cache=cc)
            return x, jnp.zeros((), jnp.float32), c
        x, aux, c = scan_layers(params["layers"], x, body, cfg)
        if cc:
            caches["ssm"] = c

    elif cfg.family == "hybrid":
        n_apps, gsz, tail = hybrid_groups(cfg)
        main = jax.tree.map(
            lambda a: a[:n_apps * gsz].reshape(n_apps, gsz, *a.shape[1:]),
            params["layers"])
        shared_kv = []
        ssm_caches = []

        def body(pl_, x):
            x, c = _ssm_block(pl_, x, cfg, collect_cache=cc)
            return x, jnp.zeros((), jnp.float32), c

        shared_fn = (lambda v: _shared_block(params["shared"], v, cfg,
                                             collect_kv=cc))
        if cfg.remat:
            shared_fn = jax.checkpoint(shared_fn)
        aux = jnp.zeros((), jnp.float32)
        for gi in range(n_apps):
            stacked_g = jax.tree.map(lambda a: a[gi], main)
            x, aux_g, c = scan_layers(stacked_g, x, body, cfg)
            aux = aux + aux_g
            x, kv = shared_fn(x)
            if cc:
                ssm_caches.append(c)
                shared_kv.append(kv)
        if tail:
            tstack = jax.tree.map(lambda a: a[n_apps * gsz:], params["layers"])
            x, aux_t, c = scan_layers(tstack, x, body, cfg)
            aux = aux + aux_t
            if cc:
                ssm_caches.append(c)
        if cc:
            caches["ssm"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *ssm_caches)
            caches["shared"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *shared_kv)

    elif cfg.family == "encdec":
        assert frontend is not None, "encdec needs stub frame embeddings"
        enc = _residual_shard(frontend.astype(compute), cfg)

        def enc_body(pl_, h):
            return _dense_block(pl_, h, cfg, causal=False)
        enc, aux_e, _ = scan_layers(params["encoder"], enc, enc_body, cfg)
        enc = rmsnorm(enc, params["enc_norm"], cfg.norm_eps)

        def body(pl_, x):
            h, kv = attn.apply_attention(
                pl_["attn"], rmsnorm(x, pl_["ln1"], cfg.norm_eps), cfg,
                collect_kv=cc)
            x = x + h
            h, _ = attn.apply_attention(
                pl_["cross"], rmsnorm(x, pl_["ln2"], cfg.norm_eps), cfg,
                kv_x=enc, causal=False)
            x = _residual_shard(x + h, cfg)
            h = mlp_mod.apply_mlp(pl_["ffn"],
                                  rmsnorm(x, pl_["ln3"], cfg.norm_eps), cfg)
            return _residual_shard(x + h, cfg), jnp.zeros((), jnp.float32), kv
        x, aux_d, kv = scan_layers(params["layers"], x, body, cfg)
        aux = aux_e + aux_d
        if cc:
            caches["self"] = kv
            caches["enc_out"] = enc

    elif cfg.family == "vlm":
        assert frontend is not None, "vlm needs stub image embeddings"
        img = frontend.astype(compute)
        period = cfg.cross_attn_every
        n_groups = cfg.n_layers // period
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, period, *a.shape[1:]),
            params["layers"])
        aux = jnp.zeros((), jnp.float32)
        self_kv = []

        def cross_fn(cl, x):
            h, _ = attn.apply_attention(
                cl["attn"], rmsnorm(x, cl["ln"], cfg.norm_eps), cfg,
                kv_x=img, causal=False)
            return _residual_shard(x + jnp.tanh(cl["gate"]) * h, cfg)

        if cfg.remat:
            cross_fn = jax.checkpoint(cross_fn)
        for gi in range(n_groups):
            cl = jax.tree.map(lambda a: a[gi], params["cross_layers"])
            x = cross_fn(cl, x)
            stacked_g = jax.tree.map(lambda a: a[gi], grouped)

            def body(pl_, x):
                return _dense_block(pl_, x, cfg, collect_kv=cc)
            x, aux_g, kv = scan_layers(stacked_g, x, body, cfg)
            aux = aux + aux_g
            if cc:
                self_kv.append(kv)
        if cc:
            caches["self"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *self_kv)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_out = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.dot(x.astype(compute), w_out.astype(compute),
                     preferred_element_type=jnp.float32)
    logits = shard(logits, ("pod", "data"), None, "model")
    return logits, aux, (caches or None)


# ---------------------------------------------------------------------------
# Graph-expressible layer oracle (dense family)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("dtype",))
def dense_layer_forward(x, wq, wk, wv_t, wo, w1, b1, w2,
                        dtype: str = "float32"):
    """One simplified dense-family layer, stage-for-stage identical to the
    graph :func:`repro.graph.from_model.transformer_layer_graph` builds:
    single head, no RoPE/GQA/norms (those are not graph-expressible yet),
    weights in the paper's ``(out, in)`` storage so every projection is
    ``X @ W.T``.  ``wv_t`` holds the value projection *pre-transposed*
    ``(dv, d)`` so its product lands directly in the ``(dv, l)`` layout the
    attend gemm's rhs wants.  Each stage accumulates in fp32, applies its
    epilogue in fp32, then casts to ``dtype`` — the same flush the fused
    megakernel and the sequential dispatcher perform, so parity with the
    compiled graph is bitwise, not approximate.

    Returns the post-MLP residual stream ``(l, d)``.
    """
    dt = jnp.dtype(dtype)
    f32 = jnp.float32

    def proj(a, w, epi=(), bias=None):
        acc = jnp.dot(jnp.asarray(a).astype(dt),
                      jnp.asarray(w).astype(dt).T,
                      preferred_element_type=f32)
        if epi:
            acc = epilogue_mod.apply_epilogue(acc, epi, bias=bias)
        return acc.astype(dt)

    d = x.shape[-1]
    q = proj(x, wq)
    k = proj(x, wk)
    vt = proj(wv_t, x)                     # (dv, l): values, born transposed
    p = proj(q, k, epi=(f"scale:{1.0 / math.sqrt(d)}", "softmax"))
    a = proj(p, vt)                        # vt lands on the rhs: p @ vt.T
    o = proj(a, wo)
    r1 = (o.astype(f32) + jnp.asarray(x).astype(f32)).astype(dt)
    h = proj(r1, w1, epi=("bias", "gelu"),
             bias=jnp.asarray(b1).astype(f32))
    y = proj(h, w2)
    return (y.astype(f32) + r1.astype(f32)).astype(dt)
