"""Attention layers: GQA + RoPE + SWA + cross-attention + KV caches.

Three execution paths, all oracle-checked against each other in tests:

* full-scores XLA path (short sequences),
* chunked online-softmax XLA path (long sequences — same math as the Pallas
  flash kernel, expressed with lax.scan so the 32k prefill does not
  materialize (L, L) score matrices when compiled for the dry-run),
* decode path (single query over a — possibly rolling — KV cache).

The Pallas kernel (kernels/flash_attention.py) is the TPU hot-spot
implementation; models call the XLA paths so CPU dry-runs compile, and the
kernel is validated against the same oracle in interpret mode.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import jax_compat
from ..configs.base import ModelConfig
from . import common
from .common import shard, stacked_dense_init

NEG_INF = float(-1e30)
FULL_SCORES_MAX_LEN = 8_192   # above this, use the chunked path


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, n_layers: int) -> Dict:
    """Stacked (scan-ready) attention params for ``n_layers`` layers."""
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": stacked_dense_init(ks[0], n_layers, d, qd, ("embed", "heads")),
        "wk": stacked_dense_init(ks[1], n_layers, d, kvd, ("embed", "kv")),
        "wv": stacked_dense_init(ks[2], n_layers, d, kvd, ("embed", "kv")),
        "wo": stacked_dense_init(ks[3], n_layers, qd, d, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = common.zeros_init((n_layers, qd), ("layers", "heads"))
        p["bk"] = common.zeros_init((n_layers, kvd), ("layers", "kv"))
        p["bv"] = common.zeros_init((n_layers, kvd), ("layers", "kv"))
    return p


# ---------------------------------------------------------------------------
# Score paths
# ---------------------------------------------------------------------------

def _full_scores_attn(q, k, v, *, causal, window, q_offset=0):
    """(B, H, Lq, dh) x (B, Hkv, Lkv, dh); materializes (Lq, Lkv) scores."""
    from ..kernels import ref
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)


def _chunked_attn(q, k, v, *, causal, window, q_offset=0, bkv: int = 1024):
    """Online-softmax over kv chunks via lax.scan — O(Lq * bkv) memory."""
    b, hq, lq, dh = q.shape
    _, hkv, lkv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)
    bkv = min(bkv, lkv)
    assert lkv % bkv == 0, (lkv, bkv)
    nkv = lkv // bkv

    kc = k.reshape(b, hkv, nkv, bkv, dh)
    vc = v.reshape(b, hkv, nkv, bkv, dh)
    qf = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(lq)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp                       # (B,Hkv,bkv,dh) x2, scalar
        kb = jnp.repeat(kb.astype(jnp.float32), group, axis=1)
        vb = jnp.repeat(vb.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)
        kpos = ci * bkv + jnp.arange(bkv)
        mask = jnp.ones((lq, bkv), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
        l_new = alpha * l + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, lq), jnp.float32)
    a0 = jnp.zeros((b, hq, lq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(nkv)))
    safe = jnp.where(l == 0.0, 1.0, l)
    return (acc / safe[..., None]).astype(q.dtype)


def _decode_attn(q, k_cache, v_cache, *, pos, window, cache_len):
    """q: (B, Hq, 1, dh); caches (B, Hkv, S, dh); attend to entries < pos+1.

    With a rolling (SWA) cache the entries are position-tagged modulo the
    cache length, so validity is derived from absolute positions.  ``pos``
    may be a scalar (one shared position, the classic batched decode) or a
    ``(B,)`` vector (per-slot positions, continuous batching): the masks
    vectorize over the batch and each row computes exactly what it would
    with that row's scalar position.
    """
    b, hq, _, dh = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)
    kf = jnp.repeat(k_cache.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v_cache.astype(jnp.float32), group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kf)
    slots = jnp.arange(s)
    pos_a = jnp.asarray(pos)
    if pos_a.ndim:
        pos_b, slots = pos_a[:, None], slots[None, :]      # (B, 1) x (1, S)
    else:
        pos_b = pos_a
    if window is None:
        valid = slots <= pos_b                     # linear cache
    elif cache_len > window:
        valid = (slots <= pos_b) & (slots > pos_b - window)  # linear + SWA
    else:
        # rolling cache: slot holds absolute position p iff p = pos - ((pos -
        # slot) mod S); valid iff within window and <= pos (always true once
        # warm). Entries beyond pos when cold (pos < S) are invalid.
        abs_pos = pos_b - ((pos_b - slots) % s)
        valid = (abs_pos >= 0) & (abs_pos > pos_b - window)
    valid = (valid[:, None, None, :] if pos_a.ndim
        else valid[None, None, None, :])
    scores = jnp.where(valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    k: jax.Array          # (B, S_cache, kv_dim)
    v: jax.Array
    # absolute write position is carried by the caller (shared across layers)


def make_kv_cache(cfg: ModelConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Rolling cache for SWA archs (window slots), linear otherwise."""
    s = seq_len if cfg.swa_window is None else min(seq_len, cfg.swa_window)
    shape = (batch, s, cfg.kv_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def apply_attention(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                    kv_x: Optional[jax.Array] = None,
                    causal: bool = True,
                    positions: Optional[jax.Array] = None,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    pos=None,
                    collect_kv: bool = False,
                    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One attention block on per-layer (already unstacked) params.

    x: (B, Lq, D).  Self-attention when ``kv_x`` is None.  With ``cache``
    (decode): Lq == 1, new K/V are written at ``pos`` and attention runs
    over the cache.  Returns (out, updated_cache_or_None).
    """
    b, lq, d = x.shape
    is_self = kv_x is None
    kv_src = x if is_self else kv_x
    compute = jnp.dtype(cfg.dtype)
    static_cross = (cache is not None) and not is_self

    def heads(t, n):
        return t.reshape(b, -1, n, cfg.head_dim).transpose(0, 2, 1, 3)

    q = k = v = None
    if cfg.explicit_collectives and is_self and not static_cross:
        # fully-manual SP->TP dataflow: gather + q/k/v dots in one shard_map
        from .explicit_tp import qkv_manual
        res = qkv_manual(x, p["wq"].astype(compute), p["wk"].astype(compute),
                         p["wv"].astype(compute), compute)
        if res is not None:
            q, k, v = res

    if q is None:
        # SP -> TP boundary: gather the (bf16) sequence shards explicitly
        xq = x.astype(compute)
        gathered = None
        if cfg.explicit_collectives:
            from .explicit_tp import gather_seq
            gathered = gather_seq(xq)
        xq = gathered if gathered is not None else common.shard_pinned(
            xq, ("pod", "data"), None, None)
        kv_src = xq if is_self else kv_src
        q = xq @ p["wq"].astype(compute)
        if not static_cross:
            xkv = kv_src.astype(compute)
            k = xkv @ p["wk"].astype(compute)
            v = xkv @ p["wv"].astype(compute)

    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute)
    q = shard(q, ("pod", "data"), None, "model")
    qh = heads(q, cfg.n_heads)                    # (B, Hq, Lq, dh)

    if not static_cross:
        if cfg.qkv_bias:
            k = k + p["bk"].astype(compute)
            v = v + p["bv"].astype(compute)
        k = shard(k, ("pod", "data"), None, None)
        v = shard(v, ("pod", "data"), None, None)
        kh = heads(k, cfg.n_kv_heads)
        vh = heads(v, cfg.n_kv_heads)

    if is_self:
        if positions is None:
            if pos is None:
                positions = jnp.arange(lq)
            elif jnp.asarray(pos).ndim:
                # per-slot positions (continuous batching): (B, lq) rope
                positions = jnp.broadcast_to(
                    jnp.asarray(pos, jnp.int32)[:, None], (b, lq))
            else:
                positions = jnp.full((lq,), pos, jnp.int32)
        qh = common.rope(qh, positions, cfg.rope_theta)
        if not static_cross:
            kh = common.rope(kh, positions, cfg.rope_theta)

    def from_cache(c):
        s_cache = c.shape[1]
        return c.reshape(b, s_cache, cfg.n_kv_heads, cfg.head_dim
                         ).transpose(0, 2, 1, 3).astype(compute)

    new_cache = None
    if static_cross:
        # read-only precomputed cross K/V (e.g. whisper encoder output):
        # non-causal attention over the full cache, no update
        s_cache = cache["k"].shape[1]
        out = _decode_attn(qh, from_cache(cache["k"]), from_cache(cache["v"]),
                           pos=s_cache - 1, window=None, cache_len=s_cache)
    elif cache is not None:
        s_cache = cache["k"].shape[1]
        k_flat = kh.transpose(0, 2, 1, 3).reshape(b, lq, cfg.kv_dim)
        v_flat = vh.transpose(0, 2, 1, 3).reshape(b, lq, cfg.kv_dim)
        if jnp.asarray(pos).ndim:
            # per-slot write positions: one scatter row per batch lane
            slot = jnp.asarray(pos, jnp.int32) % s_cache
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, slot].set(
                k_flat[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, slot].set(
                v_flat[:, 0].astype(cache["v"].dtype))
        else:
            slot = pos % s_cache
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k_flat.astype(cache["k"].dtype), (0, slot, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v_flat.astype(cache["v"].dtype), (0, slot, 0))
        new_cache = {"k": ck, "v": cv}
        # rope for cached keys is applied at write time (above); a rolling
        # cache stores *rotated* keys, which is fine because rope is
        # absolute-position — each key was rotated at its own position.
        out = _decode_attn(qh, from_cache(ck), from_cache(cv), pos=pos,
                           window=cfg.swa_window, cache_len=s_cache)
    else:
        lkv = kh.shape[2]
        window = cfg.swa_window if is_self else None
        use_causal = causal and is_self
        # score tensors shard over heads when the head count divides the
        # model axis; otherwise over query rows (attention rows are
        # independent) — whisper's 12 heads don't divide a 16-way axis and
        # would otherwise replicate (B, H, Lq, Lkv) per device
        mesh = jax_compat.get_abstract_mesh()
        model_size = dict(zip(mesh.axis_names, mesh.axis_sizes)
                          ).get("model", 1) if mesh.axis_names else 1
        heads_ok = cfg.n_heads % max(model_size, 1) == 0
        if heads_ok:
            qh = shard(qh, ("pod", "data"), "model", None, None)
        else:
            qh = shard(qh, ("pod", "data"), None, "model", None)
        if lkv <= FULL_SCORES_MAX_LEN:
            out = _full_scores_attn(qh, kh, vh, causal=use_causal,
                                    window=window)
        else:
            out = None
            if cfg.explicit_collectives:
                from .explicit_tp import chunked_attn_manual
                out = chunked_attn_manual(qh, kh, vh, causal=use_causal,
                                          window=window)
            if out is None:
                bkv = (1024 if lkv % 1024 == 0 else
                    next(b for b in (512, 256, 128, 64, 1)
                         if lkv % b == 0))
                out = _chunked_attn(qh, kh, vh, causal=use_causal,
                                    window=window, bkv=bkv)
        if collect_kv:
            # prefill: hand rotated K / V back for the decode cache
            new_cache = {
                "k": kh.transpose(0, 2, 1, 3).reshape(b, lkv, cfg.kv_dim),
                "v": vh.transpose(0, 2, 1, 3).reshape(b, lkv, cfg.kv_dim),
            }

    out = out.transpose(0, 2, 1, 3).reshape(b, lq, cfg.q_dim)
    out = shard(out, ("pod", "data"), None, "model")
    wo = p["wo"].astype(compute)
    if cfg.explicit_collectives and cfg.sequence_parallel:
        from .explicit_tp import project_scatter
        res = project_scatter(out, wo)
        if res is not None:
            return res.astype(x.dtype), new_cache
    out = jnp.dot(out, wo, preferred_element_type=jnp.float32)
    if cfg.sequence_parallel:
        # TP -> SP boundary: constrain the raw dot output so the partitioner
        # emits a reduce-scatter, not all-reduce + slice
        out = shard(out, ("pod", "data"), "model", None)
    return out.astype(x.dtype), new_cache


def precompute_cross_cache(p: Dict, enc_out: jax.Array, cfg: ModelConfig,
                           dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Project encoder output to K/V once; decode steps read it statically."""
    compute = jnp.dtype(cfg.dtype)
    xkv = enc_out.astype(compute)
    k = xkv @ p["wk"].astype(compute)
    v = xkv @ p["wv"].astype(compute)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(compute)
        v = v + p["bv"].astype(compute)
    return {"k": k.astype(dtype), "v": v.astype(dtype)}
