"""Prefill and single-token decode for every architecture family.

The decode cache layout (one pytree, sharded like activations):

    {"pos":   () or (B,) int32 — absolute position of the NEXT token
              (a (B,) vector gives every sequence its own position, which
              is what lets the serving slot engine mix sequences of
              different lengths in one jitted decode batch),
     "self":  {"k","v"} (L, B, S_c, kv_dim)      attention families
     "ssm":   {"conv","state"} (L, B, ...)       ssm / hybrid
     "shared":{"k","v"} (n_apps, B, S_c, kv_dim) hybrid shared-attn
     "cross": {"k","v"} (L|n_cross, B, F, kv_dim) encdec / vlm (static)}

SWA archs use rolling caches of ``window`` slots; prefill fills them with
the last ``window`` positions (valid because window divides the assigned
sequence lengths).  decode_step lowers the ``serve_step`` of the dry-run's
decode cells: one new token against a seq_len-deep cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import mlp as mlp_mod
from . import ssm as ssm_mod
from .common import rmsnorm, shard
from .transformer import forward, hybrid_groups, scan_layers


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _fit_cache(kv: Dict[str, jax.Array], window: Optional[int],
               max_len: int, s0: int) -> Dict[str, jax.Array]:
    """Resize collected (.., S0, kv_dim) K/V to the decode cache layout.

    Rolling caches (SWA) keep ``min(max_len, window)`` slots with slot
    ``i == abs_pos % s_cache`` (a roll re-aligns when s_cache does not
    divide S0); linear caches pad to ``max_len`` slots."""
    s_cache = max_len if window is None else min(max_len, window)

    def fit(a):
        if s0 >= s_cache:
            a = a[:, :, s0 - s_cache:]
            shift = s0 % s_cache
            if shift:
                a = jnp.roll(a, shift, axis=2)
            return a
        pad = [(0, 0)] * a.ndim
        pad[2] = (0, s_cache - s0)
        return jnp.pad(a, pad)

    return jax.tree.map(fit, kv)


def prefill(params: Dict, tokens: jax.Array, cfg: ModelConfig, *,
            frontend: Optional[jax.Array] = None,
            max_len: Optional[int] = None,
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the full prompt, return (last-position logits, decode cache).

    ``max_len`` is the total context budget (prompt + generated); the cache
    allocates min(max_len, swa_window) slots."""
    b, s = tokens.shape
    max_len = max_len or s
    logits, _, caches = forward(params, tokens, cfg, frontend=frontend,
                                collect_cache=True)
    cache: Dict[str, Any] = {"pos": jnp.array(s, jnp.int32)}
    caches = caches or {}
    if "self" in caches:
        cache["self"] = _fit_cache(caches["self"], cfg.swa_window, max_len, s)
    if "ssm" in caches:
        cache["ssm"] = caches["ssm"]
    if "shared" in caches:
        cache["shared"] = _fit_cache(caches["shared"], cfg.swa_window,
                                     max_len, s)
    if cfg.family == "encdec":
        enc = caches["enc_out"]

        def cross_kv(pl_):
            return attn.precompute_cross_cache(pl_["cross"], enc, cfg)
        cache["cross"] = jax.vmap(cross_kv)(
            jax.tree.map(lambda a: a, params["layers"]))
    if cfg.family == "vlm":
        img = frontend.astype(jnp.dtype(cfg.dtype))

        def cross_kv(pl_):
            return attn.precompute_cross_cache(pl_, img, cfg)
        cache["cross"] = jax.vmap(cross_kv)(params["cross_layers"]["attn"])
    return logits[:, -1], cache


def init_cache(params: Dict, cfg: ModelConfig, batch: int, seq_len: int, *,
               frontend: Optional[jax.Array] = None,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Empty decode cache for a maximum context of ``seq_len`` (the decode
    dry-run cells build this from ShapeDtypeStructs via eval_shape)."""
    cache: Dict[str, Any] = {"pos": jnp.array(0, jnp.int32)}
    L = cfg.n_layers

    def kv(n, s):
        return {"k": jnp.zeros((n, batch, s, cfg.kv_dim), dtype),
                "v": jnp.zeros((n, batch, s, cfg.kv_dim), dtype)}

    s_c = seq_len if cfg.swa_window is None else min(seq_len, cfg.swa_window)
    if cfg.family in ("dense", "moe", "encdec", "vlm"):
        cache["self"] = kv(L, s_c)
    if cfg.family in ("ssm", "hybrid"):
        cache["ssm"] = {
            "conv": jnp.zeros((L, batch, cfg.conv_kernel - 1,
                               ssm_mod.conv_dim(cfg)), jnp.float32),
            "state": jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_state,
                                cfg.ssm_head_dim), jnp.float32),
        }
    if cfg.family == "hybrid":
        n_apps, _, _ = hybrid_groups(cfg)
        cache["shared"] = kv(n_apps, s_c)
    if cfg.family == "encdec":
        enc_fwd, _, caches = forward(params, jnp.zeros((batch, 1), jnp.int32),
                                     cfg, frontend=frontend,
                                     collect_cache=True)
        del enc_fwd
        cache["cross"] = jax.vmap(
            lambda pl_: attn.precompute_cross_cache(pl_["cross"],
                                                    caches["enc_out"], cfg)
        )(params["layers"])
    if cfg.family == "vlm":
        img = frontend.astype(jnp.dtype(cfg.dtype))
        cache["cross"] = jax.vmap(
            lambda pl_: attn.precompute_cross_cache(pl_, img, cfg)
        )(params["cross_layers"]["attn"])
    return cache


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_step(params: Dict, tokens: jax.Array, cache: Dict[str, Any],
                cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens: (B, 1) int32 — one new token per sequence.

    ``cache["pos"]`` may be a scalar (all sequences at the same position,
    the classic batched decode) or a ``(B,)`` vector (per-sequence
    positions, continuous batching); rope, validity masks and cache writes
    vectorize accordingly and each row computes exactly what it would with
    that row's scalar position.

    Returns (logits (B, vocab), updated cache)."""
    compute = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute)
    x = shard(x, ("pod", "data"), None, None)
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    if cfg.family in ("dense", "moe"):
        def body(pl_and_kv, x):
            pl_, ck, cv = pl_and_kv
            h, kv_new = attn.apply_attention(
                pl_["attn"], rmsnorm(x, pl_["ln1"], cfg.norm_eps), cfg,
                cache={"k": ck, "v": cv}, pos=pos)
            x = x + h
            if "router" in pl_["ffn"]:
                h, _ = mlp_mod.apply_moe(
                    pl_["ffn"], rmsnorm(x, pl_["ln2"], cfg.norm_eps), cfg)
            else:
                h = mlp_mod.apply_mlp(
                    pl_["ffn"], rmsnorm(x, pl_["ln2"], cfg.norm_eps), cfg)
            return x + h, jnp.zeros((), jnp.float32), kv_new
        x, _, kv = scan_layers(
            (params["layers"], cache["self"]["k"], cache["self"]["v"]),
            x, lambda inp, x: body(inp, x), cfg)
        new_cache["self"] = kv

    elif cfg.family == "ssm":
        def body(pl_and_c, x):
            pl_, conv, state = pl_and_c
            h, c_new = ssm_mod.apply_ssm(
                pl_["ssm"], rmsnorm(x, pl_["ln1"], cfg.norm_eps), cfg,
                cache={"conv": conv, "state": state})
            return x + h, jnp.zeros((), jnp.float32), c_new
        x, _, c = scan_layers(
            (params["layers"], cache["ssm"]["conv"], cache["ssm"]["state"]),
            x, lambda inp, x: body(inp, x), cfg)
        new_cache["ssm"] = c

    elif cfg.family == "hybrid":
        n_apps, gsz, tail = hybrid_groups(cfg)
        lay = params["layers"]
        main = jax.tree.map(
            lambda a: a[:n_apps * gsz].reshape(n_apps, gsz, *a.shape[1:]),
            lay)
        cmain = jax.tree.map(
            lambda a: a[:n_apps * gsz].reshape(n_apps, gsz, *a.shape[1:]),
            cache["ssm"])

        def body(pl_and_c, x):
            pl_, conv, state = pl_and_c
            h, c_new = ssm_mod.apply_ssm(
                pl_["ssm"], rmsnorm(x, pl_["ln1"], cfg.norm_eps), cfg,
                cache={"conv": conv, "state": state})
            return x + h, jnp.zeros((), jnp.float32), c_new

        ssm_new, shared_new = [], []
        for gi in range(n_apps):
            x, _, c = scan_layers(
                (jax.tree.map(lambda a: a[gi], main),
                 cmain["conv"][gi], cmain["state"][gi]),
                x, lambda inp, x: body(inp, x), cfg)
            ssm_new.append(c)
            ps = params["shared"]
            h, kv_new = attn.apply_attention(
                ps["attn"], rmsnorm(x, ps["ln1"], cfg.norm_eps), cfg,
                cache=jax.tree.map(lambda a: a[gi], cache["shared"]),
                pos=pos)
            x = x + h
            h = mlp_mod.apply_mlp(ps["mlp"],
                                  rmsnorm(x, ps["ln2"], cfg.norm_eps), cfg)
            x = x + h
            shared_new.append(kv_new)
        if tail:
            x, _, c = scan_layers(
                (jax.tree.map(lambda a: a[n_apps * gsz:], lay),
                 cache["ssm"]["conv"][n_apps * gsz:],
                 cache["ssm"]["state"][n_apps * gsz:]),
                x, lambda inp, x: body(inp, x), cfg)
            ssm_new.append(c)
        new_cache["ssm"] = _concat_ssm(ssm_new, n_apps, gsz, tail)
        new_cache["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                           *shared_new)

    elif cfg.family == "encdec":
        def body(inp, x):
            pl_, ck, cv, xk, xv = inp
            h, kv_new = attn.apply_attention(
                pl_["attn"], rmsnorm(x, pl_["ln1"], cfg.norm_eps), cfg,
                cache={"k": ck, "v": cv}, pos=pos)
            x = x + h
            h, _ = attn.apply_attention(
                pl_["cross"], rmsnorm(x, pl_["ln2"], cfg.norm_eps), cfg,
                kv_x=x,  # marker: non-self; K/V come from the static cache
                cache={"k": xk, "v": xv}, pos=pos)
            x = x + h
            h = mlp_mod.apply_mlp(pl_["ffn"],
                                  rmsnorm(x, pl_["ln3"], cfg.norm_eps), cfg)
            return x + h, jnp.zeros((), jnp.float32), kv_new
        x, _, kv = scan_layers(
            (params["layers"], cache["self"]["k"], cache["self"]["v"],
             cache["cross"]["k"], cache["cross"]["v"]),
            x, lambda inp, x: body(inp, x), cfg)
        new_cache["self"] = kv
        new_cache["cross"] = cache["cross"]

    elif cfg.family == "vlm":
        period = cfg.cross_attn_every
        n_groups = cfg.n_layers // period
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, period, *a.shape[1:]),
            params["layers"])
        cgrouped = jax.tree.map(
            lambda a: a.reshape(n_groups, period, *a.shape[1:]),
            cache["self"])

        def body(inp, x):
            pl_, ck, cv = inp
            h, kv_new = attn.apply_attention(
                pl_["attn"], rmsnorm(x, pl_["ln1"], cfg.norm_eps), cfg,
                cache={"k": ck, "v": cv}, pos=pos)
            x = x + h
            h = mlp_mod.apply_mlp(pl_["ffn"],
                                  rmsnorm(x, pl_["ln2"], cfg.norm_eps), cfg)
            return x + h, jnp.zeros((), jnp.float32), kv_new

        kv_groups = []
        for gi in range(n_groups):
            cl = jax.tree.map(lambda a: a[gi], params["cross_layers"])
            h, _ = attn.apply_attention(
                cl["attn"], rmsnorm(x, cl["ln"], cfg.norm_eps), cfg,
                kv_x=x,  # marker: K/V from static image cache
                cache=jax.tree.map(lambda a: a[gi], cache["cross"]), pos=pos)
            x = x + jnp.tanh(cl["gate"]) * h
            x, _, kv = scan_layers(
                (jax.tree.map(lambda a: a[gi], grouped),
                 cgrouped["k"][gi], cgrouped["v"][gi]),
                x, lambda inp, x: body(inp, x), cfg)
            kv_groups.append(kv)
        new_cache["self"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *kv_groups)
        new_cache["cross"] = cache["cross"]
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_out = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.dot(x.astype(compute), w_out.astype(compute),
                     preferred_element_type=jnp.float32)
    logits = shard(logits, ("pod", "data"), None, "model")
    return logits[:, 0], new_cache


def _concat_ssm(ssm_new, n_apps, gsz, tail):
    """Stitch per-group (gsz, B, ...) ssm caches back to (L, B, ...)."""
    parts = ssm_new[:n_apps]
    out = (jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        if len(parts) > 1 else parts[0])
    if tail:
        out = jax.tree.map(lambda a, t: jnp.concatenate([a, t], axis=0),
                           out, ssm_new[-1])
    return out
