"""Reference chains: AlgebraGraph builders + the explicit-schedule oracle.

The graph subsystem's acceptance story (ISSUE/ROADMAP): a 2-layer
attention+MLP chain compiles through ``repro.generate(graph)`` with the
softmax/bias/gelu epilogues folded into the producing kernels, and the
result is **bit-identical** to the explicit-TP model's math.  The
schedules ``models/explicit_tp.py`` emits on a mesh degenerate, at
model-parallel size 1, to exactly the plain fp32 dots written out here
(``qkv_manual``/``chunked_attn_manual``/``mlp_manual`` each fall back to
one local dot per projection); this module is that degenerate case as a
runnable single-chip oracle, sharing the *same* epilogue functions
(``kernels/epilogue.py``) the fused kernels flush through — so parity is
exact, not approximate:

* every gemm is one fp32 ``jnp.dot`` — the planner's tile agreement
  gives fused nodes whole-tensor blocks, so the kernel, too, issues
  exactly one dot per node,
* scale/softmax/bias/gelu go through ``epilogue.apply_epilogue`` in
  both worlds.

Layout conventions follow the paper's gemm (``C[m,n] += A[m,k]*B[n,k]``,
i.e. the B operand is stored (n, k) and used transposed): attention
takes ``K`` as (Lkv, d) and ``Vt`` as (dv, Lkv); MLP weights are stored
(out_features, in_features).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..core.algebra import get_algebra
from ..graph.ir import AlgebraGraph, GraphNode
from ..kernels import epilogue as epilogue_mod


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(jnp.asarray(a).astype(jnp.float32),
                   jnp.asarray(b).astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def _scale_op(d: int) -> str:
    return f"scale:{1.0 / math.sqrt(d)}"


# ---------------------------------------------------------------------------
# Graph builders
# ---------------------------------------------------------------------------

def attention_graph(lq: int = 64, lkv: int = 64, d: int = 64,
                    dv: int = 64, prefix: str = "",
                    q_edge: str = "Q") -> AlgebraGraph:
    """Single-head attention as a graph:
    ``softmax(Q @ K.T / sqrt(d)) @ V`` with ``K`` (lkv, d) and ``Vt``
    (dv, lkv) in the paper's (n, k) operand layout."""
    p = prefix
    nodes = (
        GraphNode(name=f"{p}scores", inputs=(q_edge, f"{p}K"),
                  output=f"{p}s_raw", algebra=get_algebra(
                      "gemm", m=lq, n=lkv, k=d)),
        GraphNode(name=f"{p}scale", inputs=(f"{p}s_raw",),
                  output=f"{p}s_scaled", op=_scale_op(d)),
        GraphNode(name=f"{p}softmax", inputs=(f"{p}s_scaled",),
                  output=f"{p}probs", op="softmax"),
        GraphNode(name=f"{p}attend", inputs=(f"{p}probs", f"{p}Vt"),
                  output=f"{p}attn", algebra=get_algebra(
                      "gemm", m=lq, n=dv, k=lkv)),
    )
    return AlgebraGraph(nodes=nodes,
                        inputs=(q_edge, f"{p}K", f"{p}Vt"),
                        output=f"{p}attn")


def mlp_graph(l: int = 64, d: int = 64, f: int = 128,
              d_out: Optional[int] = None, prefix: str = "",
              x_edge: str = "x") -> AlgebraGraph:
    """gemm·bias·gelu·gemm: ``gelu(x @ W1.T + b1) @ W2.T`` with weights
    stored (out_features, in_features)."""
    p = prefix
    d_out = d if d_out is None else d_out
    nodes = (
        GraphNode(name=f"{p}up", inputs=(x_edge, f"{p}W1"),
                  output=f"{p}h_raw", algebra=get_algebra(
                      "gemm", m=l, n=f, k=d)),
        GraphNode(name=f"{p}bias1", inputs=(f"{p}h_raw", f"{p}b1"),
                  output=f"{p}h_biased", op="bias"),
        GraphNode(name=f"{p}act", inputs=(f"{p}h_biased",),
                  output=f"{p}h", op="gelu"),
        GraphNode(name=f"{p}down", inputs=(f"{p}h", f"{p}W2"),
                  output=f"{p}y", algebra=get_algebra(
                      "gemm", m=l, n=d_out, k=f)),
    )
    return AlgebraGraph(nodes=nodes,
                        inputs=(x_edge, f"{p}W1", f"{p}b1", f"{p}W2"),
                        output=f"{p}y")


def attention_mlp_graph(lq: int = 64, lkv: int = 64, d: int = 64,
                        dv: int = 64, f: int = 128,
                        d_out: Optional[int] = None) -> AlgebraGraph:
    """The 2-layer acceptance chain: attention feeding an MLP, six
    algebra nodes + four epilogue nodes in one DAG.  The attention
    output edge fuses straight into the MLP's up-projection lhs."""
    attn = attention_graph(lq, lkv, d, dv)
    mlp = mlp_graph(lq, dv, f, d_out, prefix="mlp_", x_edge="attn")
    return AlgebraGraph(nodes=attn.nodes + mlp.nodes,
                        inputs=attn.inputs + tuple(
                            e for e in mlp.inputs if e != "attn"),
                        output=mlp.output)


# ---------------------------------------------------------------------------
# Explicit-schedule oracle (explicit-TP math at model-parallel size 1)
#
# The oracles are jitted: eager (op-at-a-time) execution skips the FMA
# contractions XLA applies when it compiles the same epilogue expression
# inside a kernel, which costs the last ulp of the gelu/softmax math.
# Compiled-vs-compiled, parity with the fused kernels is exact.
# ---------------------------------------------------------------------------

@jax.jit
def attention_oracle(q: jax.Array, k: jax.Array, vt: jax.Array
                     ) -> jax.Array:
    d = q.shape[-1]
    s = _dot(q, jnp.asarray(k).T)
    probs = epilogue_mod.apply_epilogue(s, (_scale_op(d), "softmax"))
    return _dot(probs, jnp.asarray(vt).T)


@jax.jit
def mlp_oracle(x: jax.Array, w1: jax.Array, b1: jax.Array,
               w2: jax.Array) -> jax.Array:
    h = epilogue_mod.apply_epilogue(
        _dot(x, jnp.asarray(w1).T), ("bias", "gelu"),
        bias=jnp.asarray(b1, jnp.float32))
    return _dot(h, jnp.asarray(w2).T)


@jax.jit
def attention_mlp_oracle(operands: Dict[str, jax.Array]) -> jax.Array:
    """Oracle over the operand dict of :func:`attention_mlp_graph`."""
    attn = attention_oracle(operands["Q"], operands["K"], operands["Vt"])
    return mlp_oracle(attn, operands["mlp_W1"], operands["mlp_b1"],
                      operands["mlp_W2"])
