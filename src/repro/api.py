"""The front door: ``repro.generate`` — one call from algebra to accelerator.

TensorLib's headline claim is that one transformation matrix yields a
*complete* accelerator, module selection **and connection**.  This module
is that claim as an API: ``generate`` runs the whole pipeline —
classification (``core/stt.py``), plan (``core/plan.py``), intra-chip
lowering (``compile.lower``), and, given a device mesh, the inter-chip
CommPlan interpreter (``dist/comm_engine.py``) — and returns a single
:class:`Accelerator` handle:

    import repro
    from repro.dist.engine import square_submesh

    acc = repro.generate("gemm", "output_stationary")   # single chip
    c = acc({"A": a, "B": b})

    acc = repro.generate(alg, search=5)                 # DSE-ranked pick
    multi = acc.sharded(square_submesh(2))              # same plan, mesh'd
    c = multi({"A": a, "B": b})                         # CommPlan-driven

The dataflow classification drives *both* levels from the same plan:
the Pallas template on each chip and the shard_map collectives between
chips.  SUMMA / Cannon / ring-reduce are not modes a user selects — they
fall out of ``gemm`` x the MMT / SST / K-spatial dataflows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .compile import lower as _lower
from .compile.pipeline import CompiledKernel
from .core import dse as _dse
from .core import stt as _stt
from .core.algebra import PAPER_ALGEBRAS, Sparsity, TensorAlgebra, get_algebra
from .core.costmodel import CostReport
from .core.plan import ExecutionPlan
from .core.stt import Dataflow
from .core.tiling import ArrayConfig

DataflowLike = Union[Dataflow, str, None]


def _resolve_algebra(alg: Union[TensorAlgebra, str],
                     bounds: Optional[Dict[str, int]]) -> TensorAlgebra:
    if isinstance(alg, str):
        if alg not in PAPER_ALGEBRAS:
            raise ValueError(f"unknown algebra {alg!r}; "
                             f"registry: {sorted(PAPER_ALGEBRAS)}")
        return get_algebra(alg, **(bounds or {}))
    if bounds:
        return alg.with_bounds(**bounds)
    return alg


def _resolve_dataflow(alg: TensorAlgebra, dataflow: DataflowLike) -> Dataflow:
    if dataflow is None:
        dataflow = "output_stationary"
    if isinstance(dataflow, str):
        return _stt.apply_stt(alg, alg.loops[:3],
                              _stt.stt_from_name(dataflow))
    return dataflow


@dataclasses.dataclass
class Accelerator:
    """A generated accelerator: one handle over both pipeline levels.

    ``__call__`` executes on a single chip (the lowered Pallas kernel) or,
    when bound to a mesh via :meth:`sharded` / ``generate(mesh=...)``,
    across chips with every transfer prescribed by the generated CommPlan.
    """

    kernel: CompiledKernel
    mesh: Optional["jax.sharding.Mesh"] = None
    #: DSE candidates considered when built via ``generate(search=...)``,
    #: best first; ``candidates[0]`` is the one this accelerator runs.
    candidates: Optional[Tuple[Tuple[CostReport, Dataflow], ...]] = None
    #: mesh-execution options forwarded to the CommPlan interpreter:
    #: sparse shipping mode ("auto" | "bsr" | "dense") and batch sharding
    #: (False = replicating baseline, for footprint A/B comparisons)
    sparse_mode_mesh: str = "auto"
    shard_batch: bool = True
    #: the measured autotuner's result when built via ``generate(tune=...)``
    #: (:class:`repro.tune.TuneResult`): winning variant, measured medians,
    #: whether the on-disk tuning cache answered
    tune_result: Optional[object] = None
    _mesh_prog: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- introspection ----------------------------------------------------
    @property
    def algebra(self) -> TensorAlgebra:
        return self.kernel.algebra

    @property
    def dataflow(self) -> Dataflow:
        return self.kernel.dataflow

    @property
    def plan(self) -> ExecutionPlan:
        """The full generated plan: PE modules, KernelPlan, CommPlan."""
        return self.kernel.plan

    @property
    def template(self) -> str:
        return self.kernel.template

    def cost_report(self) -> CostReport:
        """Paper cost model's view of this exact (algebra, dataflow,
        config) — same tile chooser the executed blocks come from."""
        return self.kernel.cost_report()

    @property
    def partition(self):
        """The solved per-tensor mesh partition
        (:class:`~repro.core.plan.PartitionSolution`); requires a bound
        mesh."""
        if self.mesh is None:
            raise ValueError("partition requires a mesh-bound accelerator; "
                             "call .sharded(mesh) first")
        return self._program().solution

    def describe(self) -> str:
        df = self.dataflow
        rep = self.cost_report()
        form = self.kernel.form
        lines = [f"Accelerator({self.algebra.name} x {df.name})",
                 f"  kernel: template={self.template} "
                 f"blocks={self.kernel.blocks} "
                 + (f"batch={form.batch} " if form.batch else "")
                 + f"resident={self.plan.kernel.resident_tensor}",
                 f"  macs:   executed={rep.executed_macs} "
                 f"ratio={rep.executed_mac_ratio:.2f} (executed/priced)"]
        if self.kernel.source == "tuned" or self.tune_result is not None:
            tr = self.tune_result
            bits = [f"source={self.kernel.source}",
                    f"grid_order={self.kernel.grid_order}",
                    f"accum={self.kernel.accum}"]
            if self.kernel.measured_s is not None:
                bits.append(f"measured={self.kernel.measured_s * 1e3:.3f}ms")
            if tr is not None:
                if tr.speedup is not None:
                    bits.append(f"speedup={tr.speedup:.2f}x")
                bits.append("cache-hit" if tr.cache_hit
                            else f"trials={len(tr.trials)}")
            lines.append("  tuned:  " + " ".join(bits))
        if rep.measured_cycles is not None or rep.calibrated:
            cyc = (f"  cycles: model={rep.cycles:.0f}"
                   + (" (calibrated)" if rep.calibrated else ""))
            if rep.measured_cycles is not None:
                cyc += f" measured={rep.measured_cycles:.0f}"
            lines.append(cyc)
        if self.algebra.is_sparse:
            dens = " ".join(f"{name}:{self.algebra.density_of(name):.3f}"
                            for name, _ in self.algebra.sparsity)
            skip = ""
            if form.batch_keep is not None:
                skip = (f" batch_slices={len(form.batch_keep)}"
                        f"/{form.batch_full[0]}")
            lines.append(f"  sparse: mode={self.kernel.sparse_mode} "
                         f"{dens}{skip}")
        kinds = " ".join(
            f"{t.tensor}:{t.kind}"
            + (f"[{','.join(t.mesh_axes)}]" if t.mesh_axes else "")
            for t in self.plan.comm.tensors)
        lines.append(f"  comm:   {kinds}")
        if self.mesh is not None:
            sol = self.partition
            lines.append(
                f"  mesh:   {dict(zip(self.mesh.axis_names, self.mesh.devices.shape))}"
                f" strategy={sol.strategy}"
                + (f" batch_axis={sol.batch_axis}" if sol.batch_axis
                   else ""))
            eb = self.kernel.dtype.itemsize
            stored = sol.per_device_bytes(form, eb)
            moved = sol.comm_bytes(form, eb)
            for tp in sol.sides:
                names = "+".join(tp.tensors)
                lines.append(
                    f"    {tp.side} ({names}): {tp.describe()} "
                    f"stored={stored[tp.side]:.0f}B/dev "
                    f"comm={moved[tp.side]:.0f}B/dev")
        return "\n".join(lines)

    # -- execution --------------------------------------------------------
    def _program(self):
        if self._mesh_prog is None:
            from .dist import comm_engine
            self._mesh_prog = comm_engine.compile_comm_plan(
                self.plan.comm, self.kernel.form, self.mesh,
                dtype=self.kernel.dtype, shard_batch=self.shard_batch,
                sparse=self.sparse_mode_mesh)
        return self._mesh_prog

    def __call__(self, operands: Dict[str, jax.Array]) -> jax.Array:
        if self.mesh is None:
            return self.kernel(operands)
        k = self.kernel
        # same dtype cast + sparsity-pattern enforcement as the single-chip
        # path, so both levels compute the same function of the operands
        cast = k.cast_operands(operands)
        lhs, rhs = k.form.prepare(cast)
        out2d = self._program()(lhs, rhs)
        return k.form.finish(out2d)

    def sharded(self, mesh: "jax.sharding.Mesh", *,
                sparse: str = "auto",
                shard_batch: bool = True) -> "Accelerator":
        """Bind this accelerator to a 2-D device mesh: execution becomes
        the CommPlan interpreter's shard_map program (chip-level wires),
        with the same :class:`~repro.core.plan.PartitionSolution` driving
        both levels.

        Structured block-sparse operands ship **compressed** by default
        (``sparse='auto'``/``'bsr'``): each device holds only its shard's
        nonzero blocks plus their block-COO coordinates, and the CommPlan
        collectives move that payload — no device materializes the dense
        operand.  ``sparse='dense'`` requests the masked-dense shipping
        baseline (exact, but every transfer moves zero blocks too), kept
        for footprint comparisons.  ``shard_batch=False`` likewise keeps
        the replicating-batch baseline.
        """
        if sparse not in ("auto", "bsr", "dense"):
            raise ValueError(f"sparse must be 'auto', 'bsr' or 'dense', "
                             f"got {sparse!r}")
        form = self.kernel.form
        if sparse == "bsr" and (form.sparse is None or form.batch):
            # an explicit compressed request must not silently densify:
            # masked-mode and batched sparse forms have no structured 2-D
            # operand the collectives could ship as BSR payload
            raise ValueError(
                "sparse='bsr' requested but this form has no structured "
                "2-D sparse operand (masked-dense / batched patterns); "
                "use sparse='auto' (compresses whenever possible) or "
                "'dense'")
        return dataclasses.replace(self, mesh=mesh, sparse_mode_mesh=sparse,
                                   shard_batch=shard_batch, _mesh_prog=None)

    def validate(self, seed: int = 0, atol: float = 1e-3) -> float:
        """Run on random operands and compare against ``alg.reference``.

        Validates the *bound* execution path: the single-chip kernel when
        no mesh is attached, the CommPlan-driven shard_map program when
        one is.  Returns the max abs error; raises on mismatch."""
        if self.mesh is None:
            return self.kernel.validate(seed=seed, atol=atol)
        operands = self.algebra.random_operands(seed)
        got = np.asarray(self(operands), dtype=np.float64)
        want = self.algebra.reference(operands).astype(np.float64)
        err = float(np.abs(got - want).max()) if got.size else 0.0
        if got.shape != want.shape or err > atol:
            raise AssertionError(
                f"sharded {self.algebra.name} x {self.dataflow.name} "
                f"diverged from reference: shape {got.shape} vs "
                f"{want.shape}, max err {err:.3e}")
        return err


def generate(alg: Union[TensorAlgebra, str],
             dataflow: DataflowLike = None, *,
             search: Union[int, Sequence[Tuple[CostReport, Dataflow]],
                           None] = None,
             tune: Union[bool, int, None] = None,
             mesh: Optional["jax.sharding.Mesh"] = None,
             bounds: Optional[Dict[str, int]] = None,
             sparsity: Optional[Dict[str, Sparsity]] = None,
             cfg: ArrayConfig = ArrayConfig(),
             dtype=jnp.float32,
             interpret: Optional[bool] = None,
             backend: str = "pallas",
             validate: Optional[bool] = None) -> Accelerator:
    """Generate a complete accelerator from a tensor algebra.

    Args:
      alg: a :class:`TensorAlgebra` or a registry name (``"gemm"``, ...).
      dataflow: a :class:`Dataflow`, a named STT (``"identity"``,
        ``"output_stationary"``, ``"weight_stationary"``,
        ``"input_stationary"``), or None for the output-stationary
        default.  Mutually exclusive with ``search``.
      search: ``top_k`` (int) to run ``dse.search`` here, or a ranked
        ``[(report, dataflow), ...]`` from a previous search.  Candidates
        are lowered best-first; the first that validates wins.
      tune: measured autotuning (``repro.tune``): True runs the timing-
        driven tuner over the analytical top candidates (an int sets the
        candidate width), picks the dataflow + kernel variant with the
        best *measured* median, and persists the winner in the on-disk
        tuning cache — so a second ``generate(tune=...)`` call on the
        same shape is a pure cache hit with no re-measurement.  The
        result is exposed as ``Accelerator.tune_result`` and in
        ``describe()``.  Mutually exclusive with ``dataflow``/``search``.
      mesh: bind the result to a 2-D device mesh — ``__call__`` then runs
        the generated CommPlan through ``dist/comm_engine.py``.
      bounds: loop-bound overrides forwarded to the algebra.
      sparsity: per-tensor block-sparse patterns (tensor name ->
        :class:`~repro.core.algebra.Sparsity`), applied via
        ``TensorAlgebra.with_sparsity``.  Sparse operands route through
        the BSR kernel when the lowering has a structured 2-D image for
        the pattern, masked-dense otherwise; ``.sharded(mesh)`` falls
        back to dense replication (see :meth:`Accelerator.sharded`).
      interpret: run Pallas in interpret mode; default: auto (True off-TPU
        so the same script runs on CPU and real hardware unchanged).

    Returns an :class:`Accelerator` — or, when ``alg`` is an
    :class:`~repro.graph.ir.AlgebraGraph`, a
    :class:`~repro.graph.executor.GraphAccelerator`: the whole DAG is
    planned (``repro.graph.planner``: epilogue folding, per-node
    dataflow selection, inter-node tile agreement, merged-group
    derivation), every node lowers through this same pipeline, and
    ``__call__`` runs the chain with at most one HBM materialization
    per non-fusable edge — merged-eligible fused chains execute as a
    single Pallas megakernel with intermediates in VMEM scratch.  For
    graphs, ``search`` is the per-node DSE width (int), ``tune=k``
    measures each merged group against sequential dispatch (m-block
    ladder x stage interleave, at most ``k`` trials per group) and
    keeps the winner, and ``dataflow`` / ``bounds`` / ``sparsity`` /
    ``mesh`` do not apply.
    """
    from .graph.ir import AlgebraGraph as _AlgebraGraph
    if isinstance(alg, _AlgebraGraph):
        if dataflow is not None or bounds or sparsity:
            raise ValueError(
                "graph generation plans per-node dataflows itself: "
                "dataflow=/bounds=/sparsity= do not apply; use search= "
                "for the per-node DSE width and tune= for merged-group "
                "measurement")
        if search is not None and not isinstance(search, int):
            raise ValueError("for a graph, search= must be an int "
                             "(per-node DSE width)")
        from .graph import executor as _graph_exec
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        group_trials = None
        if tune:
            group_trials = (tune if isinstance(tune, int)
                and not isinstance(tune, bool) else 8)
        return _graph_exec.build(
            alg, search=search, cfg=cfg, dtype=dtype,
            interpret=interpret, backend=backend, validate=validate,
            mesh=mesh, tune=group_trials)
    algebra = _resolve_algebra(alg, bounds)
    if sparsity:
        algebra = algebra.with_sparsity(**sparsity)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    candidates: Optional[Tuple[Tuple[CostReport, Dataflow], ...]] = None
    if tune:
        if dataflow is not None or search is not None:
            raise ValueError("tune= is mutually exclusive with dataflow= "
                             "and search=")
        from . import tune as _tune_mod
        width = (tune if isinstance(tune, int)
            and not isinstance(tune, bool) else 4)
        result = _tune_mod.tune(algebra, search=width, cfg=cfg, dtype=dtype,
                                interpret=interpret, backend=backend,
                                validate=validate)
        acc = Accelerator(result.kernel, tune_result=result)
        return acc.sharded(mesh) if mesh is not None else acc
    if search is not None:
        if dataflow is not None:
            raise ValueError("pass either dataflow= or search=, not both")
        ranked = (_dse.search(algebra, top_k=search, cfg=cfg)
                  if isinstance(search, int) else list(search))
        if not ranked:
            raise ValueError("search produced no candidates")
        errors = []
        kernel = None
        taken = 0
        for rep, df in ranked:
            taken += 1
            try:
                kernel = _lower(algebra, df, cfg=cfg, dtype=dtype,
                                interpret=interpret, backend=backend,
                                validate=validate)
                break
            except Exception as e:          # try the next-ranked candidate
                errors.append(f"{df.name}: {e}")
        if kernel is None:
            raise RuntimeError(
                "no search candidate lowered successfully:\n  "
                + "\n  ".join(errors))
        # winner first, then the remaining candidates in rank order
        candidates = (ranked[taken - 1],) + tuple(
            r for i, r in enumerate(ranked) if i != taken - 1)
    else:
        df = _resolve_dataflow(algebra, dataflow)
        kernel = _lower(algebra, df, cfg=cfg, dtype=dtype,
                        interpret=interpret, backend=backend,
                        validate=validate)

    acc = Accelerator(kernel, candidates=candidates)
    return acc.sharded(mesh) if mesh is not None else acc
