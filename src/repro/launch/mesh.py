"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices *before* any
jax initialization; tests and benches must keep seeing 1 device).
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e pod slice); 2 pods over DCI when multi_pod.

    Axes: ('data', 'model') single-pod; ('pod', 'data', 'model') multi-pod.
    DP runs over (pod, data); FSDP over data; TP/SP/EP over model.
    """
    from .. import jax_compat

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax_compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake or real) devices exist — used by
    tests and the CPU examples."""
    from .. import jax_compat

    return jax_compat.make_mesh((data, model), ("data", "model"))
