"""Production serving entry point.

    python -m repro.launch.serve --arch mixtral-8x22b [--smoke]

``--smoke`` serves the reduced config with random weights on this container;
on hardware, point --ckpt at a training checkpoint and the engine restores
bf16 weights sharded over the production mesh.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..checkpoint import store
    from ..configs import get_config
    from ..models import init_params, split
    from ..serve.engine import DecodeEngine, ServeConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    if args.ckpt:
        params, step, _ = store.restore(args.ckpt, params)
        print(f"restored checkpoint step {step}")

    engine = DecodeEngine(params, cfg,
                          ServeConfig(max_new_tokens=args.new_tokens))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)
                           ).astype(np.int32)
    frontend = None
    if cfg.family in ("encdec", "vlm"):
        frontend = 0.05 * rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
    gen, stats = engine.generate(prompts, frontend=frontend)
    print(f"generated {stats['generated']} tokens x {args.batch} sequences")
    print(gen[:2])


if __name__ == "__main__":
    main()
