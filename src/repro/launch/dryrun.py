import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 placeholder CPU devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.  Nothing here allocates
real tensors — inputs are ShapeDtypeStructs (specs.input_specs).

Per cell this records:
  * compiled.memory_analysis()  (per-device bytes — proves HBM fit),
  * compiled.cost_analysis()    (XLA's own numbers, loop bodies unscaled),
  * hlo_analysis.analyze()      (trip-scaled flops / HBM bytes / collective
                                 wire bytes — the roofline inputs),
  * the three roofline terms + bottleneck (core.tpu.RooflineTerms).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh both
  ... --out results/dryrun  (JSON per cell; reused unless --force)
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    import jax

    from .. import jax_compat
    from ..core import tpu
    from . import hlo_analysis, specs
    from .mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cell = specs.input_specs(arch, shape_name, mesh)

    t0 = time.perf_counter()
    # jax 0.8: set_mesh (not the bare `with mesh:` resource env) is what
    # makes bare-PartitionSpec sharding constraints inside the model resolve
    with jax_compat.set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    stats = hlo_analysis.analyze(compiled.as_text())

    terms = tpu.RooflineTerms(
        cell=f"{arch}/{shape_name}/{'multi' if multi_pod else 'single'}",
        chips=chips,
        hlo_flops=stats.flops * chips,          # per-device -> global
        hlo_bytes=stats.hbm_bytes * chips,
        collective_bytes=stats.wire_bytes * chips,
        model_flops=cell.model_flops,
    )
    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips, "kind": cell.shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": per_dev_bytes,
            "fits_hbm": bool(per_dev_bytes < tpu.V5E.hbm_bytes),
        },
        "xla_cost_analysis": {
            "flops_unscaled": cost.get("flops"),
            "bytes_accessed_unscaled": cost.get("bytes accessed"),
        },
        "hlo_stats": stats.as_dict(),
        "trip_counts": stats.trip_counts,
        "roofline": terms.as_dict(),
    }


def main() -> None:
    from ..configs import ARCH_IDS
    from . import specs

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    results, failures = [], []
    for arch, shape_name in specs.all_cells():
        if arch not in archs:
            continue
        if args.shape != "all" and shape_name != args.shape:
            continue
        for multi in meshes:
            tag = f"{arch}_{shape_name}_{'multi' if multi else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {tag}")
                continue
            print(f"[run] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape_name, multi)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"  ok: compile={rec['compile_s']}s "
                      f"bottleneck={r['bottleneck']} "
                      f"mfu={r['roofline_fraction']:.3f} "
                      f"fits={rec['memory']['fits_hbm']}", flush=True)
                results.append(tag)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"  FAIL {tag}: {e}")
                traceback.print_exc()

    # note the assignment-mandated skips
    skips = [{"arch": a, "shape": s, "reason": r}
             for a, s, r in specs.skipped_cells()]
    with open(os.path.join(args.out, "_skips.json"), "w") as f:
        json.dump(skips, f, indent=1)
    print(f"\ndone: {len(results)} cells ok, {len(failures)} failed, "
          f"{len(skips)} skipped-by-assignment")
    if failures:
        for tag, err in failures:
            print(f"  FAILED {tag}: {err}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
