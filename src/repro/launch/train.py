"""Production training entry point.

    python -m repro.launch.train --arch qwen2.5-32b --steps 1000 \
        [--multi-pod] [--smoke]

On real TPU hardware this builds the production mesh and runs the sharded
fault-tolerant driver; ``--smoke`` scales the config down and runs on
whatever devices exist (CI / this CPU container).
"""
from __future__ import annotations

import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="ckpts")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, no mesh (CPU CI)")
    args = ap.parse_args()

    import jax

    from ..configs import get_config
    from ..data.pipeline import DataConfig
    from ..launch.mesh import make_production_mesh
    from ..launch.specs import opt_config_for
    from ..runtime.driver import RunConfig, TrainDriver

    cfg = get_config(args.arch)
    mesh = None
    if args.smoke:
        cfg = cfg.reduced()
        batch, seq = 8, 64
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        batch, seq = args.global_batch, args.seq_len

    opt_cfg = dataclasses.replace(opt_config_for(cfg), lr=args.lr,
                                  total_steps=args.steps)
    driver = TrainDriver(
        cfg, opt_cfg,
        DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                   n_shards=max(1, jax.process_count()),
                   shard=jax.process_index()),
        RunConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                  ckpt_dir=args.ckpt_dir),
        mesh=mesh,
    )
    out = driver.run()
    for m in out["metrics"][-5:]:
        print(m)
    print(f"finished at step {out['final_step']}")


if __name__ == "__main__":
    main()
