"""Cell specifications: (arch x input-shape x mesh) -> lowerable closure.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input (no device allocation), plus the in/out shardings the
cell lowers with:

  * train cells lower ``train_step`` (loss + grads + AdamW update, donated),
  * prefill cells lower ``prefill``  (forward + KV-cache build),
  * decode cells lower ``decode_step`` (one token against a seq_len cache).

Serving cells use bf16 parameters (no optimizer); training uses fp32
masters + AdamW state (8-bit for grok-1-314b so it fits v5e HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, InputShape, ModelConfig, cells_for, get_config
from ..models import common, decode as dec, transformer
from ..models.ssm import conv_dim
from ..models.transformer import hybrid_groups
from ..optim import adamw
from ..train import trainer


def opt_config_for(cfg: ModelConfig) -> adamw.AdamWConfig:
    """8-bit optimizer state where fp32 moments would not fit HBM."""
    bits = 8 if cfg.param_count() > 200e9 else 32
    return adamw.AdamWConfig(total_steps=10_000, state_bits=bits)


# ---------------------------------------------------------------------------
# shape/sharding helpers
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(spec_axes, shape, mesh: Mesh) -> P:
    """PartitionSpec with divisibility fallback (axis -> None)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            out.append(None)
            continue
        size = 1
        for a in ((ax,) if isinstance(ax, str) else ax):
            size *= sizes[a]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def params_struct(cfg: ModelConfig, dtype=None) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct params tree, logical-axes tree) — no allocation."""
    out = jax.eval_shape(
        lambda k: common.split(transformer.init_params(k, cfg)),
        jax.random.PRNGKey(0))
    params, axes = out
    if dtype is not None:
        params = jax.tree.map(lambda s: _sds(s.shape, dtype), params)
    return params, axes


def param_shardings(params, axes, cfg: ModelConfig, mesh: Mesh,
                    rules: common.AxisRules = common.DEFAULT_RULES):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    specs = rules.specs(axes, params, sizes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    """Decode-cache ShapeDtypeStructs (mirrors models.decode.init_cache)."""
    L = cfg.n_layers
    s_c = seq_len if cfg.swa_window is None else min(seq_len, cfg.swa_window)
    kvd = cfg.kv_dim

    def kv(n, s):
        return {"k": _sds((n, batch, s, kvd), jnp.bfloat16),
                "v": _sds((n, batch, s, kvd), jnp.bfloat16)}

    cache: Dict[str, Any] = {"pos": _sds((), jnp.int32)}
    if cfg.family in ("dense", "moe", "encdec", "vlm"):
        cache["self"] = kv(L, s_c)
    if cfg.family in ("ssm", "hybrid"):
        cache["ssm"] = {
            "conv": _sds((L, batch, cfg.conv_kernel - 1, conv_dim(cfg)),
                         jnp.float32),
            "state": _sds((L, batch, cfg.ssm_heads, cfg.ssm_state,
                           cfg.ssm_head_dim), jnp.float32),
        }
    if cfg.family == "hybrid":
        n_apps, _, _ = hybrid_groups(cfg)
        cache["shared"] = kv(n_apps, s_c)
    if cfg.family == "encdec":
        cache["cross"] = kv(L, cfg.frontend_tokens)
    if cfg.family == "vlm":
        cache["cross"] = kv(cfg.n_layers // cfg.cross_attn_every,
                            cfg.frontend_tokens)
    return cache


def cache_shardings(cache, cfg: ModelConfig, mesh: Mesh):
    """Path-keyed shardings: batch over (pod, data), feature over model."""
    b_ax = _batch_axes(mesh)

    def spec(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        if "pos" in keys:
            return P()
        if "state" in keys:                    # (L, B, H, N, P)
            return _fit((None, b_ax, "model", None, None), leaf.shape, mesh)
        if "conv" in keys:                     # (L, B, k-1, cd)
            return _fit((None, b_ax, None, "model"), leaf.shape, mesh)
        # kv caches (N, B, S, kvd)
        return _fit((None, b_ax, None, "model"), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec(p, l)), cache)


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: InputShape
    kind: str
    fn: Callable                   # to be jit'd
    args: Tuple                    # ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...]
    model_flops: float             # 6ND / 2ND per the assignment formulas
    tokens: float


def input_specs(arch: str, shape_name: str, mesh: Mesh,
                overrides: Optional[Dict] = None) -> Cell:
    """Build the lowerable cell for (arch x shape x mesh).

    ``overrides``: ModelConfig field overrides — the perf-iteration loop
    (EXPERIMENTS.md §Perf) sweeps remat / sequence_parallel / attention
    block knobs through here."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if shape_name not in cells_for(cfg):
        raise ValueError(f"{arch} skips {shape_name} (full attention; see "
                         "DESIGN.md §Arch-applicability)")
    b_ax = _batch_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    needs_frontend = cfg.family in ("encdec", "vlm")
    n_active = cfg.active_param_count()

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        state, axes = jax.eval_shape(
            lambda k: trainer.init_state(k, cfg, opt_cfg),
            jax.random.PRNGKey(0))
        st_sh = trainer.state_shardings(state, axes, mesh)
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "targets": _sds((B, S), jnp.int32)}
        b_sh = {k: NamedSharding(mesh, _fit((b_ax, None), (B, S), mesh))
                for k in batch}
        if needs_frontend:
            fshape = (B, cfg.frontend_tokens, cfg.d_model)
            batch["frontend"] = _sds(fshape, jnp.float32)
            b_sh["frontend"] = NamedSharding(
                mesh, _fit((b_ax, None, None), fshape, mesh))
        step = trainer.make_train_step(cfg, opt_cfg)
        tokens = float(B) * S
        return Cell(arch, shape, "train", step, (state, batch),
                    (st_sh, b_sh), (st_sh, None), (0,),
                    model_flops=6.0 * n_active * tokens, tokens=tokens)

    # serving cells: bf16 params
    params, axes = params_struct(cfg, dtype=jnp.bfloat16)
    p_sh = param_shardings(params, axes, cfg, mesh)

    if shape.kind == "prefill":
        toks = _sds((B, S), jnp.int32)
        t_sh = NamedSharding(mesh, _fit((b_ax, None), (B, S), mesh))
        args = [params, toks]
        in_sh = [p_sh, t_sh]

        if needs_frontend:
            fshape = (B, cfg.frontend_tokens, cfg.d_model)
            args.append(_sds(fshape, jnp.float32))
            in_sh.append(NamedSharding(
                mesh, _fit((b_ax, None, None), fshape, mesh)))

            def fn(p, t, f):
                return dec.prefill(p, t, cfg, frontend=f, max_len=S)
        else:
            def fn(p, t):
                return dec.prefill(p, t, cfg, max_len=S)

        # output: (last logits, cache)
        out_cache = jax.eval_shape(fn, *args)[1]
        logits_sh = NamedSharding(
            mesh, _fit((b_ax, "model"), (B, cfg.vocab), mesh))
        c_sh = cache_shardings(out_cache, cfg, mesh)
        tokens = float(B) * S
        return Cell(arch, shape, "prefill", fn, tuple(args), tuple(in_sh),
                    (logits_sh, c_sh), (), 2.0 * n_active * tokens, tokens)

    # decode
    cache = cache_struct(cfg, B, S)
    c_sh = cache_shardings(cache, cfg, mesh)
    toks = _sds((B, 1), jnp.int32)
    t_sh = NamedSharding(mesh, _fit((b_ax, None), (B, 1), mesh))

    def fn(p, t, c):
        return dec.decode_step(p, t, c, cfg)

    logits_sh = NamedSharding(
        mesh, _fit((b_ax, "model"), (B, cfg.vocab), mesh))
    tokens = float(B)
    return Cell(arch, shape, "decode", fn, (params, toks, cache),
                (p_sh, t_sh, c_sh), (logits_sh, c_sh), (2,),
                2.0 * n_active * tokens, tokens)


def all_cells(mesh_name: str = "single"):
    """Iterate every runnable (arch x shape) pair; yields (arch, shape_name)."""
    from ..configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in cells_for(cfg):
            yield arch, shape_name


def skipped_cells():
    from ..configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            if shape_name not in cells_for(cfg):
                yield arch, shape_name, "full attention; long_500k skipped"
