"""Post-optimization HLO analyzer: per-device FLOPs, HBM traffic, and
collective wire bytes — with while-loop trip scaling.

This is the dry-run "profiler" (no real TPU): ``compiled.cost_analysis()``
counts while bodies ONCE, which under-reports scan-over-layers models by a
factor of n_layers, so we parse ``compiled.as_text()`` ourselves:

  * every computation gets a multiplier = product of enclosing while trip
    counts (trip parsed from the loop-condition constants) and fusion
    call edges,
  * FLOPs: 2 * |lhs| * |rhs_free| per dot (operand shapes from the symbol
    table; elementwise flops are ignored — dots dominate at these scales),
  * HBM bytes: sum of operand+result bytes over *top-level* ops of
    non-fusion computations (fusion internals are on-chip), with
    dynamic-(update-)slice charged only their slice bytes,
  * wire bytes per chip, by collective kind with replica-group size g:
      all-gather         result * (g-1)/g
      all-reduce     2 * result * (g-1)/g      (ring = RS + AG)
      reduce-scatter     result * (g-1)        (operand ~= result * g)
      all-to-all         result * (g-1)/g
      collective-permute result

Shapes in SPMD-partitioned HLO are per-device, so all outputs here are
per-device numbers.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_GROUPS_V1 = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota", "partition-id", "replica-id",
               "while", "conditional", "call", "custom-call", "rng",
               "get-dimension-size", "domain", "opt-barrier",
               "all-gather-start", "all-reduce-start", "copy-start",
               "copy-done", "all-gather-done", "all-reduce-done"}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str          # args + attributes


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    wire_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops_by_name: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "wire_bytes": self.wire_bytes,
                "collective_counts": dict(self.collective_counts),
                "wire_by_kind": dict(self.wire_by_kind)}


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_RE.match(line)
            if m:
                current = Computation(m.group(2), bool(m.group(1)), [])
            continue
        if line.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            current.ops.append(Op(m.group(1), m.group(2), m.group(3),
                                  m.group(4)))
    return comps


def _operand_names(rest: str) -> List[str]:
    """Names inside the top-level call parens of the op line."""
    depth, out, cur = 0, [], ""
    for ch in rest:
        if ch == ")" and depth == 0:
            out.append(cur)
            break
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        cur += ch
    args = out[0] if out else rest
    return re.findall(r"%([\w\.\-]+)", args)


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_V2.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1.search(rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(comp: Computation) -> int:
    """Max integer constant in a loop-condition computation (heuristic —
    scan conditions compare the induction variable against the trip count)."""
    best = 1
    for op in comp.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.shape + " " + op.kind +
                          "(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Computation name -> product of enclosing trip counts."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = defaultdict(float)
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "while":
                mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if mb and mc and mc.group(1) in comps:
                    trip = _trip_count(comps[mc.group(1)])
                    edges[comp.name].append((mb.group(1), float(trip)))
                    edges[comp.name].append((mc.group(1), float(trip)))
            else:
                for attr in ("calls", "to_apply"):
                    m = re.search(attr + r"=%?([\w\.\-]+)", op.rest)
                    if m and m.group(1) in comps:
                        edges[comp.name].append((m.group(1), 1.0))
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    stack = [entry]
    while stack:
        cur = stack.pop()
        for child, factor in edges.get(cur, ()):
            new = mult[cur] * factor
            if new > mult[child]:
                mult[child] = new
                stack.append(child)
    return dict(mult)


def _dot_flops(op: Op, table: Dict[str, str]) -> float:
    names = _operand_names(op.rest)
    if len(names) < 2:
        return 0.0
    lhs, rhs = table.get(names[0]), table.get(names[1])
    if lhs is None or rhs is None:
        return 0.0
    ld = shape_dims(lhs)
    rd = shape_dims(rhs)
    if ld is None or rd is None:
        return 0.0
    rdims = rd[1]
    rc = re.search(r"rhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    rb = re.search(r"rhs_batch_dims=\{([0-9,]*)\}", op.rest)
    used = set()
    for m in (rc, rb):
        if m and m.group(1):
            used.update(int(i) for i in m.group(1).split(","))
    rhs_free = 1
    for i, d in enumerate(rdims):
        if i not in used:
            rhs_free *= d
    lhs_total = math.prod(ld[1]) if ld[1] else 1
    return 2.0 * lhs_total * rhs_free


_WIRE_FACTOR = {
    "all-gather": lambda b, g: b * (g - 1) / g,
    "all-reduce": lambda b, g: 2.0 * b * (g - 1) / g,
    "reduce-scatter": lambda b, g: b * (g - 1),
    "all-to-all": lambda b, g: b * (g - 1) / g,
    "collective-permute": lambda b, g: float(b),
}


def analyze(text: str, default_group: int = 1) -> HloStats:
    comps = parse_computations(text)
    mult = _multipliers(comps)
    fusion_comps = set()
    for comp in comps.values():
        for op in comp.ops:
            m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
            if m:
                fusion_comps.add(m.group(1))
            m = re.search(r"to_apply=%?([\w\.\-]+)", op.rest)
            if m:
                fusion_comps.add(m.group(1))

    stats = HloStats()
    counts: Dict[str, int] = defaultdict(int)
    wire: Dict[str, float] = defaultdict(float)

    for comp in comps.values():
        k = mult.get(comp.name, 0.0)
        if k == 0.0:
            continue
        table = {op.name: op.shape for op in comp.ops}
        for op in comp.ops:
            if op.kind == "dot":
                f = _dot_flops(op, table) * k
                stats.flops += f
                stats.dot_flops_by_name[f"{comp.name}/{op.name}"] = f
            if op.kind in _WIRE_FACTOR:
                g = _group_size(op.rest, default_group)
                b = shape_bytes(op.shape)
                w = _WIRE_FACTOR[op.kind](b, max(g, 1)) * k
                stats.wire_bytes += w
                counts[op.kind] += int(k) if k >= 1 else 1
                wire[op.kind] += w
            # HBM traffic: only top-level ops of non-fusion computations
            if comp.name in fusion_comps:
                continue
            if op.kind in _NO_TRAFFIC:
                continue
            res = shape_bytes(op.shape)
            if op.kind == "dynamic-slice":
                stats.hbm_bytes += 2 * res * k
            elif op.kind == "dynamic-update-slice":
                names = _operand_names(op.rest)
                upd = (shape_bytes(table.get(names[1], "")) if len(names) > 1
                    else 0)
                stats.hbm_bytes += 2 * upd * k
            else:
                names = _operand_names(op.rest)
                opnd = sum(shape_bytes(table.get(n, "")) for n in names)
                stats.hbm_bytes += (res + opnd) * k

    # record trip counts for debugging / EXPERIMENTS.md
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "while":
                mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                if mc and mc.group(1) in comps:
                    stats.trip_counts[op.name] = _trip_count(comps[mc.group(1)])
    stats.collective_counts = dict(counts)
    stats.wire_by_kind = dict(wire)
    return stats
