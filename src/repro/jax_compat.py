"""Version compatibility shims for the jax mesh/sharding API.

The model substrate targets the jax 0.8 sharding-in-types API
(``jax.sharding.get_abstract_mesh`` / ``set_mesh``); older releases (the
seed image ships 0.4.37) spell those ``jax._src.mesh.get_abstract_mesh``
and the ``with mesh:`` resource env + ``set_abstract_mesh``.  Same
pattern as kernels/pallas_compat.py for ``pltpu.CompilerParams``.
"""
from __future__ import annotations

import contextlib

import jax


def get_abstract_mesh():
    """The ambient abstract mesh; an *empty* AbstractMesh (jax 0.8
    semantics — ``axis_names == ()``) when outside any mesh context."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as mesh_lib
    m = mesh_lib.get_abstract_mesh()
    if getattr(m, "axis_names", None):
        return m
    return mesh_lib.AbstractMesh(())


def shard_map(*args, **kwargs):
    """``jax.shard_map`` (jax 0.8 top-level name) with the
    ``jax.experimental.shard_map`` fallback for older releases, where the
    replication-check kwarg was still called ``check_rep``."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    return fn(*args, **kwargs)


_BARRIER_HAS_AD = None


def optimization_barrier(x):
    """``jax.lax.optimization_barrier``, degrading to identity on jax
    releases whose barrier has no differentiation rule.  The barrier only
    pins XLA scheduling (which collective runs on which value), so
    dropping it is semantically safe — just potentially slower."""
    global _BARRIER_HAS_AD
    if _BARRIER_HAS_AD is None:
        try:
            jax.grad(lambda v: jax.lax.optimization_barrier(v))(1.0)
            _BARRIER_HAS_AD = True
        except NotImplementedError:
            _BARRIER_HAS_AD = False
    return jax.lax.optimization_barrier(x) if _BARRIER_HAS_AD else x


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where the API has
    them (jax 0.8 sharding-in-types); plain make_mesh otherwise — Auto is
    the older default, so behavior matches."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.sharding.set_mesh`` with a fallback for older jax: enter the
    legacy resource env (so bare-PartitionSpec sharding constraints
    resolve) *and* publish the abstract mesh (so ``get_abstract_mesh``
    callers see the axis names)."""
    sm = getattr(jax.sharding, "set_mesh", None)
    if sm is not None:
        with sm(mesh):
            yield mesh
        return
    from jax._src import mesh as mesh_lib
    with mesh, mesh_lib.set_abstract_mesh(mesh.abstract_mesh):
        yield mesh
