"""Explicit (STT-scheduled shard_map) collectives vs GSPMD-auto parity.

Runs in a subprocess with 8 fake devices (pytest's jax already holds 1).
Covers: forward logits, gradients (incl. mlp_manual/qkv_manual transposes),
and the MoE manual path (logits exact; aux is per-shard by design).
"""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "@SRC@")
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import init_params, split, forward
from repro.models import attention
from repro.train import trainer

attention.FULL_SCORES_MAX_LEN = 16   # force the chunked/manual path
from repro import jax_compat
mesh = jax_compat.make_mesh((2, 4), ("data", "model"))

def grads_for(cfg, params, batch):
    with jax_compat.set_mesh(mesh):
        return jax.jit(lambda p, b: jax.grad(
            lambda pp: trainer.loss_fn(pp, b, cfg)[0])(p))(params, batch)

def flat(tree):
    return np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(tree)])

# --- dense (granite): forward + grads, incl. qkv/mlp_manual ---------------
base = dataclasses.replace(get_config("granite-8b").reduced(),
                           sequence_parallel=True, dtype="float32", d_ff=128)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, base.vocab)
batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
outs = {}
for flag in (False, True):
    cfg = dataclasses.replace(base, explicit_collectives=flag)
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    with jax_compat.set_mesh(mesh):
        logits, _, _ = jax.jit(lambda p, t: forward(p, t, cfg))(params, toks)
    outs[flag] = (np.asarray(logits), flat(grads_for(cfg, params, batch)))
lerr = np.abs(outs[True][0] - outs[False][0]).max()
gerr = np.abs(outs[True][1] - outs[False][1]).max() / (
    np.abs(outs[False][1]).max() + 1e-12)
assert lerr < 2e-3, ("dense logits", lerr)
assert gerr < 1e-3, ("dense grads", gerr)
print("dense parity OK", lerr, gerr)

# --- moe (mixtral): logits exact; aux per-shard (documented) ---------------
base = dataclasses.replace(get_config("mixtral-8x22b").reduced(),
                           sequence_parallel=True, dtype="float32",
                           capacity_factor=8.0)
outs = {}
for flag in (False, True):
    cfg = dataclasses.replace(base, explicit_collectives=flag)
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    with jax_compat.set_mesh(mesh):
        logits, aux, _ = jax.jit(lambda p, t: forward(p, t, cfg))(params, toks)
    outs[flag] = np.asarray(logits)
lerr = np.abs(outs[True] - outs[False]).max()
assert lerr < 2e-3, ("moe logits", lerr)
print("moe parity OK", lerr)
print("EXPLICIT_TP_PARITY_OK")
"""


def test_explicit_collectives_parity():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT.replace("@SRC@", src)],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "EXPLICIT_TP_PARITY_OK" in proc.stdout
