"""Per-architecture smoke tests (reduced configs): forward, train step,
prefill/decode consistency — one test per assigned arch as required."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cells_for, get_config
from repro.models import forward, init_params, split
from repro.models.decode import decode_step, prefill
from repro.optim.adamw import AdamWConfig
from repro.train import trainer


def setup_arch(arch, **overrides):
    cfg = get_config(arch).reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    params, axes = split(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params, axes


def make_inputs(cfg, b=2, s=32, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab)
    frontend = None
    if cfg.family in ("encdec", "vlm"):
        frontend = 0.1 * jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (b, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return toks, frontend


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg, params, _ = setup_arch(arch)
    toks, frontend = make_inputs(cfg)
    logits, aux, _ = forward(params, toks, cfg, frontend=frontend)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_runs_and_is_finite(arch):
    cfg, params, axes = setup_arch(arch)
    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=1)
    state, _ = trainer.init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(trainer.make_train_step(cfg, opt_cfg))
    toks, frontend = make_inputs(cfg)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if frontend is not None:
        batch["frontend"] = frontend
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    before = jax.tree.leaves(trainer.init_state(
        jax.random.PRNGKey(0), cfg, opt_cfg)[0].params)[0]
    after = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits — validates
    every cache type (KV / rolling-SWA / SSM state / shared-attn / cross)."""
    overrides = {}
    if get_config(arch).family == "moe":
        overrides["capacity_factor"] = 8.0   # exclude capacity drops
    cfg, params, _ = setup_arch(arch, **overrides)
    b, s, s0 = 2, 24, 16
    toks, frontend = make_inputs(cfg, b=b, s=s)
    want, _, _ = forward(params, toks, cfg, frontend=frontend)
    lg, cache = prefill(params, toks[:, :s0], cfg, frontend=frontend,
                        max_len=s)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(want[:, s0 - 1]),
                               rtol=5e-3, atol=5e-3)
    for t in range(s0, s):
        lg, cache = decode_step(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(want[:, t]),
                                   rtol=5e-3, atol=5e-3)


def test_moe_capacity_drops_tokens():
    """With a tight capacity factor, some tokens must be dropped (and the
    layer still runs) — the documented GShard behaviour."""
    cfg, params, _ = setup_arch("mixtral-8x22b", capacity_factor=0.5)
    toks, _ = make_inputs(cfg)
    logits, aux, _ = forward(params, toks, cfg)
    assert bool(jnp.isfinite(logits).all())


def test_swa_restricts_context():
    """Moving a distant token must not change SWA logits at the end."""
    cfg, params, _ = setup_arch("h2o-danube-1.8b")
    assert cfg.swa_window == 16
    toks, _ = make_inputs(cfg, s=40)
    l1, _, _ = forward(params, toks, cfg)
    toks2 = toks.at[:, 0].set((toks[:, 0] + 1) % cfg.vocab)
    l2, _, _ = forward(params, toks2, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)
    # ... while a full-attention model does change
    cfg_f, params_f, _ = setup_arch("granite-8b")
    l1, _, _ = forward(params_f, toks, cfg_f)
    l2, _, _ = forward(params_f, toks2, cfg_f)
    assert np.abs(np.asarray(l1[:, -1]) - np.asarray(l2[:, -1])).max() > 1e-5


def test_vlm_image_conditioning():
    """Changing the stub image embeddings must change the logits (with the
    cross-attn gate opened — it inits to 0 by design, like Llama 3.2)."""
    cfg, params, _ = setup_arch("llama-3.2-vision-11b")
    params["cross_layers"]["gate"] = jnp.full_like(
        params["cross_layers"]["gate"], 0.5)
    toks, frontend = make_inputs(cfg)
    l1, _, _ = forward(params, toks, cfg, frontend=frontend)
    l2, _, _ = forward(params, toks, cfg, frontend=frontend + 0.5)
    assert np.abs(np.asarray(l1) - np.asarray(l2)).max() > 1e-6


def test_vlm_gate_starts_closed():
    """At init the cross-attn gate is 0: image must NOT affect logits."""
    cfg, params, _ = setup_arch("llama-3.2-vision-11b")
    toks, frontend = make_inputs(cfg)
    l1, _, _ = forward(params, toks, cfg, frontend=frontend)
    l2, _, _ = forward(params, toks, cfg, frontend=frontend + 0.5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


def test_encdec_frame_conditioning():
    cfg, params, _ = setup_arch("whisper-small")
    toks, frontend = make_inputs(cfg)
    l1, _, _ = forward(params, toks, cfg, frontend=frontend)
    l2, _, _ = forward(params, toks, cfg, frontend=frontend * 2.0)
    assert np.abs(np.asarray(l1) - np.asarray(l2)).max() > 1e-6


def test_cells_assignment():
    """long_500k runs exactly for the sub-quadratic archs."""
    runs_long = {a for a in ARCH_IDS
                 if "long_500k" in cells_for(get_config(a))}
    assert runs_long == {"h2o-danube-1.8b", "mamba2-370m", "zamba2-1.2b",
                         "mixtral-8x22b"}
    total_cells = sum(len(cells_for(get_config(a))) for a in ARCH_IDS)
    assert total_cells == 34   # 10*3 + 4 runnable long_500k (6 noted skips)


def test_param_counts_match_published():
    expect = {"qwen1.5-110b": (100e9, 120e9),
              "qwen2.5-32b": (30e9, 35e9),
              "granite-8b": (7e9, 9e9),
              "h2o-danube-1.8b": (1.5e9, 2.0e9),
              "mamba2-370m": (0.3e9, 0.45e9),
              "zamba2-1.2b": (0.9e9, 1.4e9),
              "mixtral-8x22b": (130e9, 150e9),
              "grok-1-314b": (290e9, 330e9),
              "llama-3.2-vision-11b": (9e9, 11e9),
              "whisper-small": (0.2e9, 0.35e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
