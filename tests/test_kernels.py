"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.

All kernels run in interpret=True mode (CPU container; TPU is the target).
"""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # seed image lacks hypothesis
    from _hypothesis_compat import given, settings, st

from repro.core import algebra, stt, plan
from repro.kernels import ops, ref, stt_gemm


RNG = np.random.default_rng(42)


def randn(*shape, dtype=np.float32):
    return RNG.standard_normal(shape).astype(dtype)


TOL = {np.float32: 2e-5, jnp.bfloat16: 6e-2}


# ---------------------------------------------------------------------------
# GEMM templates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("template", ["output_stationary",
                                      "operand_stationary",
                                      "reduction_tree"])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (64, 64, 64, 16, 16, 16),
    (128, 32, 96, 32, 16, 32),
    (16, 16, 16, 16, 16, 16),      # single block
    (100, 52, 70, 32, 32, 32),     # ragged -> padded by ops
])
def test_gemm_templates_shape_sweep(template, m, n, k, bm, bn, bk):
    a, b = randn(m, k), randn(k, n)
    got = ops.stt_matmul(jnp.array(a), jnp.array(b), template=template,
                         bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_gemm_dtype_sweep(dtype):
    a = jnp.array(randn(64, 64)).astype(dtype)
    b = jnp.array(randn(64, 64)).astype(dtype)
    got = ops.stt_matmul(a, b, template="output_stationary",
                         bm=32, bn=32, bk=32, interpret=True)
    want = ref.matmul_ref(a, b)
    assert got.dtype == a.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-1)


@pytest.mark.parametrize("stationary", ["A", "B"])
def test_operand_stationary_both_operands(stationary):
    a, b = randn(64, 96), randn(96, 48)
    got = stt_gemm.matmul_operand_stationary(
        jnp.array(a), jnp.array(b), stationary=stationary,
        bm=16, bn=16, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-3)


def test_template_dispatch_from_stt_plan():
    """The full paper pipeline: STT matrix -> plan -> kernel -> numbers."""
    g = algebra.gemm()
    for kind in ["output_stationary", "weight_stationary", "input_stationary"]:
        df = stt.apply_stt(g, ("m", "n", "k"), stt.stt_from_name(kind))
        kp = plan.kernel_plan_for(df)
        a, b = randn(64, 64), randn(64, 64)
        got = ops.matmul_from_plan(kp, jnp.array(a), jnp.array(b),
                                   bm=32, bn=32, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4,
                                   atol=1e-3)


@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_gemm_property_random_ragged(mi, ni, ki):
    """Property: padding logic is correct for arbitrary ragged shapes."""
    m, n, k = 13 * mi, 9 * ni, 11 * ki
    a, b = randn(m, k), randn(k, n)
    got = ops.stt_matmul(jnp.array(a), jnp.array(b),
                         template="output_stationary",
                         bm=32, bn=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_attention_masks_and_gqa(causal, window, hq, hkv):
    q = jnp.array(randn(2, hq, 64, 32))
    k = jnp.array(randn(2, hkv, 64, 32))
    v = jnp.array(randn(2, hkv, 64, 32))
    got = ops.attention(q, k, v, causal=causal, window=window,
                        bq=16, bkv=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_attention_bf16():
    q = jnp.array(randn(1, 2, 64, 32)).astype(jnp.bfloat16)
    k = jnp.array(randn(1, 2, 64, 32)).astype(jnp.bfloat16)
    v = jnp.array(randn(1, 2, 64, 32)).astype(jnp.bfloat16)
    got = ops.attention(q, k, v, causal=True, bq=32, bkv=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=6e-2, atol=6e-2)


def test_attention_ragged_q():
    q = jnp.array(randn(1, 2, 50, 16))
    k = jnp.array(randn(1, 2, 64, 16))
    v = jnp.array(randn(1, 2, 64, 16))
    got = ops.attention(q, k, v, causal=True, bq=16, bkv=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    assert got.shape == (1, 2, 50, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_attention_fully_masked_rows_are_zero():
    """SWA window smaller than block: early rows of later q blocks mask out
    whole kv blocks; online softmax must not produce NaNs."""
    q = jnp.array(randn(1, 1, 64, 16))
    k = jnp.array(randn(1, 1, 64, 16))
    v = jnp.array(randn(1, 1, 64, 16))
    got = ops.attention(q, k, v, causal=True, window=4, bq=16, bkv=16,
                        interpret=True)
    assert bool(jnp.isfinite(got).all())
    want = ref.attention_ref(q, k, v, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def ssd_inputs(B=2, L=128, H=4, P=16, G=2, N=8):
    x = randn(B, L, H, P)
    dt = (0.1 + 0.9 * RNG.random((B, L, H))).astype(np.float32)
    a = (-0.5 - RNG.random(H)).astype(np.float32)
    b = randn(B, L, G, N)
    c = randn(B, L, G, N)
    return map(jnp.array, (x, dt, a, b, c))


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_chunk_sweep(chunk):
    x, dt, a, b, c = ssd_inputs()
    want, _ = ref.ssd_ref(x, dt, a, b, c)
    got = ops.ssd(x, dt, a, b, c, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("G", [1, 2, 4])
def test_ssd_group_broadcast(G):
    x, dt, a, b, c = ssd_inputs(G=G, H=4)
    want, _ = ref.ssd_ref(x, dt, a, b, c)
    got = ops.ssd(x, dt, a, b, c, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_ref_equals_sequential_ref():
    """The chunked XLA path (used by models) == sequential oracle."""
    x, dt, a, b, c = ssd_inputs(L=256)
    y1, h1 = ref.ssd_ref(x, dt, a, b, c)
    y2, h2 = ref.ssd_chunked_ref(x, dt, a, b, c, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 4))
@settings(max_examples=6, deadline=None)
def test_ssd_state_continuity_property(nc):
    """Splitting a sequence across chunk boundaries must not change y —
    the stationary-state invariant of the dataflow."""
    L = 32 * nc
    x, dt, a, b, c = ssd_inputs(B=1, L=L, H=2, P=8, G=1, N=4)
    got = ops.ssd(x, dt, a, b, c, chunk=32, interpret=True)
    want, _ = ref.ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
