"""The runnable examples must stay runnable (subprocess smoke tests)."""
import os
import subprocess
import sys


ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_example(name, *args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", name), *args],
        capture_output=True, text=True, timeout=timeout, cwd=ROOT, env=env)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout[-2000:]}\nSTDERR:\n{proc.stderr[-2000:]}")
    return proc.stdout


def test_quickstart():
    out = run_example("quickstart.py")
    assert "quickstart OK" in out


def test_serve_lm():
    out = run_example("serve_lm.py", "--capacity", "3")
    assert "serve OK" in out
    assert "decode compiles 1" in out


def test_train_lm_short(tmp_path):
    # fresh checkpoint dir each run: a leftover completed checkpoint would
    # make the driver resume at the final step and train nothing
    out = run_example("train_lm.py", "--steps", "40", "--d-model", "64",
                      "--layers", "2", "--seq", "32", "--batch", "4",
                      "--ckpt-dir", str(tmp_path / "ckpt"))
    assert "DECREASED" in out


def test_dse_explore():
    out = run_example("dse_explore.py")
    assert "pareto frontier" in out
