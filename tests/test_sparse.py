"""Block-sparse operand form, end to end (ISSUE 3 tentpole).

Layers under test:
  * ``Sparsity`` descriptor (block-COO) on ``TensorAlgebra``,
  * the BSR Pallas kernel (grid iterates only nonzero blocks) vs the
    masked dense oracle at >= 3 densities, bit-exact at density 1.0,
  * the lowering's pattern -> 2-D GEMM operand mapping (including the
    block-sparse im2col form for conv weights) and the masked-dense
    fallback for unmappable placements,
  * compressed-format cost-model terms: traffic/runtime strictly
    decreasing as density decreases for a fixed dataflow,
  * the front door: ``repro.generate(..., sparsity=...)`` and the
    sharded dense-replication fallback contract.
"""
import numpy as np
import pytest
import jax.numpy as jnp

import repro
from repro import compile as rcompile
from repro.core import dse, stt
from repro.core.algebra import Sparsity, gemm, get_algebra
from repro.core.costmodel import PaperCycleModel
from repro.kernels import bsr_gemm, ops

DENSITIES = (0.25, 0.5, 1.0)


def sparse_gemm(density, seed=2, size=16, block=4):
    sp = Sparsity.random((size, size), (block, block), density, seed=seed)
    return gemm(size, size, size).with_sparsity(A=sp), sp


# ---------------------------------------------------------------------------
# Sparsity descriptor
# ---------------------------------------------------------------------------

def test_sparsity_canonicalizes_coords():
    sp = Sparsity((4, 4), ((1, 1), (0, 2), (1, 1)))
    assert sp.coords == ((0, 2), (1, 1))
    assert sp.nnz_blocks == 2


def test_sparsity_random_is_deterministic():
    a = Sparsity.random((16, 16), (4, 4), 0.5, seed=9)
    b = Sparsity.random((16, 16), (4, 4), 0.5, seed=9)
    assert a == b
    assert a.nnz_blocks == 8
    assert Sparsity.random((16, 16), (4, 4), 1.0).nnz_blocks == 16
    assert Sparsity.random((16, 16), (4, 4), 0.0).nnz_blocks == 0
    # density > 0 keeps at least one block
    assert Sparsity.random((16, 16), (4, 4), 0.001).nnz_blocks == 1


def test_sparsity_validation():
    with pytest.raises(ValueError, match="tile"):
        Sparsity((3, 3), ()).grid((16, 16))
    with pytest.raises(ValueError, match="outside"):
        Sparsity((4, 4), ((4, 0),)).grid((16, 16))
    with pytest.raises(ValueError, match="density"):
        Sparsity.random((16, 16), (4, 4), 1.5)


def test_element_mask_matches_block_mask():
    sp = Sparsity.random((8, 8), (4, 4), 0.5, seed=1)
    em = sp.element_mask((8, 8))
    bm = sp.block_mask((8, 8))
    assert em.shape == (8, 8)
    assert (em[::4, ::4] == bm).all()


def test_with_sparsity_validates():
    g = gemm(16, 16, 16)
    sp = Sparsity.random((16, 16), (4, 4), 0.5)
    with pytest.raises(ValueError, match="no tensor"):
        g.with_sparsity(Z=sp)
    with pytest.raises(ValueError, match="output"):
        g.with_sparsity(C=sp)
    gs = g.with_sparsity(A=sp)
    assert gs.is_sparse and gs.sparsity_of("A") == sp
    assert gs.with_sparsity(A=None) == g
    # the sparse algebra is a distinct (hashable) compile-cache identity
    assert hash(gs) != hash(g) and gs != g


def test_random_sparse_inputs_are_masked():
    gs, sp = sparse_gemm(0.25)
    ops_ = gs.random_sparse_inputs(seed=4)
    mask = sp.element_mask((16, 16))
    assert (ops_["A"][~mask] == 0).all()
    assert (ops_["A"][mask] != 0).any()
    assert (ops_["B"] != 0).any()          # dense operand untouched


# ---------------------------------------------------------------------------
# BSR kernel vs the masked dense oracle (acceptance: >= 3 densities)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("density", DENSITIES)
def test_bsr_pipeline_matches_masked_oracle(density):
    alg, _ = sparse_gemm(density)
    kern = rcompile.lower(alg, interpret=True)
    assert kern.sparse_mode == "bsr"
    assert kern.validated                   # auto-validated at lower time
    operands = alg.random_sparse_inputs(seed=7)
    got = np.asarray(kern(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, alg.reference(operands))


def test_density_one_reproduces_dense_path_bit_exactly():
    alg, _ = sparse_gemm(1.0)
    dense = gemm(16, 16, 16)
    sparse_kern = rcompile.lower(alg, interpret=True)
    dense_kern = rcompile.lower(dense, interpret=True)
    assert sparse_kern.sparse_mode == "bsr"
    operands = {k: np.asarray(v, np.float32)
                for k, v in dense.random_operands(seed=5).items()}
    got_sparse = np.asarray(sparse_kern(operands))
    got_dense = np.asarray(dense_kern(operands))
    # same fp32 accumulation order (k-blocks ascending per output block):
    # bitwise equality, not just closeness
    assert (got_sparse == got_dense).all()


def test_bsr_grid_iterates_only_nonzero_blocks():
    alg, sp = sparse_gemm(0.25)
    kern = rcompile.lower(alg, interpret=True)
    osp = kern.sparse
    assert osp is not None and osp.side == "lhs"
    assert osp.nnz_blocks == sp.nnz_blocks == 4     # 0.25 * 16 blocks
    assert osp.coords == sp.coords                   # gemm A maps directly


def test_bsr_rhs_operand():
    sp = Sparsity.random((16, 16), (4, 4), 0.5, seed=5)
    alg = gemm(16, 16, 16).with_sparsity(B=sp)
    kern = rcompile.lower(alg, interpret=True)
    assert kern.sparse_mode == "bsr" and kern.sparse.side == "rhs"
    operands = alg.random_sparse_inputs(seed=3)
    got = np.asarray(kern(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, alg.reference(operands))


def test_bsr_empty_pattern_yields_zeros():
    sp = Sparsity((4, 4), ())
    alg = gemm(16, 16, 16).with_sparsity(A=sp)
    kern = rcompile.lower(alg, interpret=True)
    out = np.asarray(kern(alg.random_sparse_inputs()))
    assert out.shape == (16, 16) and (out == 0).all()


def test_bsr_kernel_zeroes_empty_block_rows():
    # pattern leaving block-row 2 fully empty: its output rows must be 0,
    # not uninitialized memory
    sp = Sparsity((4, 4), ((0, 0), (1, 2), (3, 1)))
    alg = gemm(16, 16, 16).with_sparsity(A=sp)
    kern = rcompile.lower(alg, interpret=True)
    operands = alg.random_sparse_inputs(seed=1)
    got = np.asarray(kern(operands))
    assert (got[8:12] == 0).all()
    np.testing.assert_array_equal(got.round().astype(np.int64),
                                  alg.reference(operands))


def test_gather_scatter_roundtrip():
    sp = Sparsity.random((16, 16), (4, 4), 0.5, seed=8)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((16, 16)).astype(np.float32)
    a *= sp.element_mask((16, 16))
    data = bsr_gemm.gather_blocks(jnp.asarray(a), sp.coords, 4, 4)
    back = np.asarray(bsr_gemm.scatter_blocks(data, sp.coords, 16, 16))
    np.testing.assert_array_equal(back, a)


def test_ops_bsr_matmul_xla_backend():
    sp = Sparsity.random((16, 16), (4, 4), 0.5, seed=8)
    a = np.asarray(gemm(16, 16, 16).with_sparsity(A=sp)
                   .random_sparse_inputs()["A"], np.float32)
    b = np.asarray(np.arange(16 * 16).reshape(16, 16), np.float32)
    got = ops.bsr_matmul(jnp.asarray(a), jnp.asarray(b), coords=sp.coords,
                         block=(4, 4), backend="xla")
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-5)


# ---------------------------------------------------------------------------
# Lowering: pattern -> 2-D operand mapping + masked fallback
# ---------------------------------------------------------------------------

def test_conv2d_block_sparse_im2col_weights():
    c = get_algebra("conv2d", k=8, c=4, y=6, x=6, p=3, q=3)
    sp = Sparsity.random((8, 4, 3, 3), (4, 2, 3, 3), 0.5, seed=1)
    kern = rcompile.lower(c.with_sparsity(B=sp), interpret=True)
    assert kern.sparse_mode == "bsr"
    assert kern.sparse.tensor == "B" and kern.sparse.side == "lhs"
    assert kern.sparse.block == (4, 2 * 3 * 3)   # (p, q) folded into cols
    assert kern.validated


def test_conv2d_partial_window_block_falls_back_to_masked():
    c = get_algebra("conv2d", k=8, c=4, y=6, x=6, p=3, q=3)
    # block does not cover the full (p, q) window -> no structured image
    sp = Sparsity.random((8, 4, 3, 3), (4, 2, 1, 1), 0.5, seed=1)
    kern = rcompile.lower(c.with_sparsity(B=sp), interpret=True)
    assert kern.sparse_mode == "masked"
    assert kern.gemm.masked_sparse == ("B",)
    assert kern.validated                       # fallback stays exact


def test_mttkrp_sparse_factor_tensor():
    mt = get_algebra("mttkrp", i=8, j=8, k=4, l=4)
    sp = Sparsity.random((8, 4, 4), (4, 2, 4), 0.5, seed=1)
    kern = rcompile.lower(mt.with_sparsity(A=sp), interpret=True)
    assert kern.sparse_mode == "bsr" and kern.validated


def test_unmapped_algebra_falls_back_to_masked():
    bg = get_algebra("batched_gemv", m=4, k=8, n=8)
    sp = Sparsity.random((4, 8), (2, 4), 0.5, seed=1)
    kern = rcompile.lower(bg.with_sparsity(B=sp), interpret=True)
    assert kern.sparse_mode == "masked" and kern.validated


def test_two_sparse_operands_pick_sparser_for_bsr():
    spA = Sparsity.random((16, 16), (4, 4), 0.25, seed=1)
    spB = Sparsity.random((16, 16), (4, 4), 0.75, seed=2)
    alg = gemm(16, 16, 16).with_sparsity(A=spA, B=spB)
    kern = rcompile.lower(alg, interpret=True)
    # one structured operand max: the sparser one wins, the other is masked
    assert kern.sparse.tensor == "A"
    assert kern.gemm.masked_sparse == ("B",)
    operands = alg.random_sparse_inputs(seed=6)
    got = np.asarray(kern(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, alg.reference(operands))


@pytest.mark.parametrize("case", ["bsr", "masked"])
def test_pattern_enforced_on_unmasked_operands(case):
    """The sparsity pattern is part of the kernel's semantics: operands
    with nonzero (even non-finite) data outside the pattern are masked at
    call time, so the BSR path and the masked-dense fallback compute the
    same function instead of silently disagreeing."""
    sp = Sparsity.random((16, 16), (4, 4), 0.5, seed=3)
    if case == "bsr":
        alg = gemm(16, 16, 16).with_sparsity(A=sp)
    else:
        alg = (get_algebra("batched_gemv", m=4, k=8, n=8)
               .with_sparsity(B=Sparsity.random((4, 8), (2, 4), 0.5,
                                                seed=3)))
    kern = rcompile.lower(alg, interpret=True)
    assert kern.sparse_mode == case
    sparse_name = alg.sparsity[0][0]
    spx = alg.sparsity_of(sparse_name)
    t = next(t for t in alg.tensors if t.name == sparse_name)
    shape = alg.tensor_shape(t)
    # fully dense operands, with inf planted outside the pattern
    dense_alg = dataclasses_replace_dense(alg)
    operands = {k: np.asarray(v, np.float64)
                for k, v in dense_alg.random_operands(seed=9).items()}
    mask = spx.element_mask(shape)
    operands[sparse_name][~mask] = np.inf
    got = np.asarray(kern(operands))
    masked = dict(operands)
    masked[sparse_name] = np.where(mask, operands[sparse_name], 0.0)
    want = alg.reference(masked)
    np.testing.assert_array_equal(got.round().astype(np.int64), want)


def dataclasses_replace_dense(alg):
    """The same algebra without patterns (dense random operands)."""
    import dataclasses
    return dataclasses.replace(alg, sparsity=())


def test_sparse_and_dense_algebras_cache_separately():
    rcompile.cache_clear()
    alg, _ = sparse_gemm(0.5)
    k1 = rcompile.lower(gemm(16, 16, 16), interpret=True)
    k2 = rcompile.lower(alg, interpret=True)
    assert k1 is not k2
    assert rcompile.cache_info()["misses"] == 2
    rcompile.cache_clear()


# ---------------------------------------------------------------------------
# Cost model: compressed-format terms (acceptance: monotone in density)
# ---------------------------------------------------------------------------

def test_costmodel_monotone_in_density():
    g = gemm(256, 256, 256)
    df = stt.apply_stt(g, g.loops, stt.stt_from_name("output_stationary"))
    model = PaperCycleModel()
    prev = None
    for density in (1.0, 0.5, 0.25, 0.125):
        sp = Sparsity.random((256, 256), (16, 16), density, seed=0)
        rep = model.evaluate(g.with_sparsity(A=sp), df)
        total = (sum(rep.traffic_bytes.values())
                 + sum(rep.metadata_bytes.values()))
        assert rep.work_density == density
        assert rep.metadata_bytes["A"] > 0
        if prev is not None:
            assert rep.cycles < prev[0]          # runtime strictly drops
            assert total < prev[1]               # traffic strictly drops
            assert rep.traffic_bytes["A"] < prev[2]
        prev = (rep.cycles, total, rep.traffic_bytes["A"])


def test_costmodel_density_one_matches_dense_cycles():
    g = gemm(256, 256, 256)
    df = stt.apply_stt(g, g.loops, stt.stt_from_name("output_stationary"))
    model = PaperCycleModel()
    dense = model.evaluate(g, df)
    full = model.evaluate(
        g.with_sparsity(A=Sparsity.random((256, 256), (16, 16), 1.0)), df)
    assert full.cycles == dense.cycles
    assert full.traffic_bytes == dense.traffic_bytes
    assert dense.metadata_bytes == {} and full.metadata_bytes["A"] > 0


def test_uniform_density_override_scales_search():
    g = gemm(256, 256, 256)
    sel = [("m", "n", "k")]
    dense_top = dse.search(g, top_k=1, selections=sel)[0][0]
    sparse_top = dse.search(g, top_k=1, selections=sel, density=0.25)[0][0]
    assert sparse_top.cycles < dense_top.cycles
    with pytest.raises(ValueError, match="density"):
        PaperCycleModel(density=0.0)


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("density", DENSITIES)
def test_generate_sparse_front_door(density):
    sp = Sparsity.random((16, 16), (4, 4), density, seed=2)
    acc = repro.generate("gemm", bounds=dict(m=16, n=16, k=16),
                         sparsity={"A": sp}, interpret=True)
    assert acc.kernel.sparse_mode == "bsr"
    assert acc.validate() <= 1e-3
    rep = acc.cost_report()
    assert rep.work_density == pytest.approx(density)
    assert "sparse: mode=bsr" in acc.describe()
    # dist-facing plan metadata carries the density
    assert acc.plan.comm.by_tensor()["A"].density == pytest.approx(density)


def test_generate_sparse_search_ranks_and_validates():
    sp = Sparsity.random((16, 16), (4, 4), 0.5, seed=2)
    alg = gemm(16, 16, 16).with_sparsity(A=sp)
    acc = repro.generate(alg, search=2, interpret=True)
    assert acc.kernel.validated and acc.candidates


def test_sharded_sparse_modes():
    """Compressed shipping is the default for structured operands now:
    ``sparse='bsr'`` is accepted (no more NotImplementedError), and the
    masked-dense baseline stays requestable; unknown modes still raise."""
    alg, _ = sparse_gemm(0.5)
    acc = repro.generate(alg, interpret=True)
    assert acc.sharded(None, sparse="bsr").sparse_mode_mesh == "bsr"
    assert acc.sharded(None).sparse_mode_mesh == "auto"
    assert acc.sharded(None, sparse="dense").sparse_mode_mesh == "dense"
    with pytest.raises(ValueError, match="sparse"):
        acc.sharded(None, sparse="bogus")
    # an explicit bsr request on a form with no structured operand must
    # fail loudly, not silently ship masked-dense
    from repro.core.algebra import depthwise_conv
    dws = depthwise_conv(k=8, y=5, x=5, p=2, q=2).with_sparsity(
        B=Sparsity.random((8, 2, 2), (4, 2, 2), 0.5, seed=0))
    masked = repro.generate(dws, interpret=True)
    assert masked.kernel.sparse_mode == "masked"
    with pytest.raises(ValueError, match="structured"):
        masked.sharded(None, sparse="bsr")
    assert masked.sharded(None).sparse_mode_mesh == "auto"
