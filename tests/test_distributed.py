"""Distributed STT-GEMM engine tests (8 fake devices in a subprocess).

The pytest process has already initialized jax with a single CPU device, so
all device-count-dependent assertions live in repro.dist.selftest and run in
a fresh interpreter.
"""
import os
import subprocess
import sys


def run_selftest(module: str, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", module], env=env, capture_output=True,
        text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_distributed_engine_selftest():
    out = run_selftest("repro.dist.selftest")
    assert "ALL DIST SELFTESTS PASSED" in out


def test_sparse_accelerator_mesh_parity():
    """A block-sparse GEMM accelerator sharded on 8 fake devices matches
    the masked dense oracle and the single-chip BSR kernel at several
    densities — the documented dense-replication fallback is exact
    (ISSUE 3)."""
    out = run_selftest("repro.dist.sparse_selftest")
    assert "ALL SPARSE MESH SELFTESTS PASSED" in out
    for density in ("0.25", "0.50", "1.00"):
        assert f"sparse-mesh-parity density={density}" in out


def test_comm_engine_selftest():
    """The generic CommPlan interpreter: every registry algebra sharded
    on an 8-fake-device mesh matches the single-chip kernel and the
    loop-nest oracle, and SUMMA / Cannon / ring-reduce fall out as
    special cases matching the hand-written engines (ISSUE 2)."""
    out = run_selftest("repro.dist.comm_selftest")
    assert "ALL COMM-ENGINE SELFTESTS PASSED" in out
    for name in ("gemm", "conv2d", "mttkrp", "ttmc", "batched_gemv",
                 "depthwise_conv"):
        # the exact per-algebra parity row, not just the name anywhere
        assert f"{name:15s} comm=" in out, f"missing parity row for {name}"
    # the no-silent-replication assert ran: batched algebras report the
    # mesh axis their batch dim folds onto
    assert "batched_gemv" in out and "batch_axis=x" in out
    assert "summa-as-oracle" in out
    assert "cannon-as-oracle" in out
    assert "ring-reduce-as-oracle" in out


def test_partition_selftest():
    """The unified partition solver (ISSUE 5): degenerate + skewed
    meshes through every CommPlan kind, batch-sharded and bsr-sharded
    parity, dt-staggered schedules, and the ~1/P footprint shrink — all
    asserted on 8 fake devices from the solver's reported partition."""
    out = run_selftest("repro.dist.partition_selftest", timeout=1200)
    assert "ALL PARTITION SELFTESTS PASSED" in out
    for name in ("gemm", "conv2d", "mttkrp", "ttmc", "batched_gemv",
                 "depthwise_conv"):
        assert f"degenerate-mesh {name:15s}" in out, name
    assert "batch-shard batched_gemv" in out
    assert "batch-shard depthwise_conv" in out
    assert "compressed (2, 2) density=0.25" in out
    assert "stagger (2, 4)" in out
    assert "batched-sparse batched_gemv" in out
