"""Distributed STT-GEMM engine tests (8 fake devices in a subprocess).

The pytest process has already initialized jax with a single CPU device, so
all device-count-dependent assertions live in repro.dist.selftest and run in
a fresh interpreter.
"""
import os
import subprocess
import sys

import pytest


def run_selftest(module: str, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", module], env=env, capture_output=True,
        text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_distributed_engine_selftest():
    out = run_selftest("repro.dist.selftest")
    assert "ALL DIST SELFTESTS PASSED" in out
