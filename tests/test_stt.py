"""STT dataflow generation: paper examples, invariants, property tests."""
import itertools

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # seed image lacks hypothesis
    from _hypothesis_compat import given, settings, st

from repro.core import algebra, linalg, stt
from repro.core.stt import DataflowClass as DC


MNK = ("m", "n", "k")


def classes(df):
    return tuple(t.cls for t in df.tensors)


class TestPaperExamples:
    """Every concrete example stated in the paper text."""

    def test_fig1b_space_time_point(self):
        # i=1, j=2, k=3 with T=[[1,0,0],[0,1,0],[1,1,1]] -> PE(1,2), cycle 6
        T = stt.stt_from_name("output_stationary")
        assert linalg.as_int_tuple(linalg.matvec(T, [1, 2, 3])) == (1, 2, 6)

    def test_section4_example_A_systolic_vertical(self):
        # paper §IV: A[i,k]'s reuse vector under the Fig.1b T is (0,1,1):
        # systolic, vertical direction
        g = algebra.gemm()
        df = stt.apply_stt(g, MNK, stt.stt_from_name("output_stationary"))
        a = df.by_tensor()["A"]
        assert a.cls is DC.SYSTOLIC and a.dp == (0, 1) and a.dt == 1

    def test_output_stationary_is_SST(self):
        g = algebra.gemm()
        df = stt.apply_stt(g, MNK, stt.stt_from_name("output_stationary"))
        assert df.name == "MNK-SST"
        assert classes(df) == (DC.SYSTOLIC, DC.SYSTOLIC, DC.STATIONARY)

    def test_weight_stationary_is_STS(self):
        g = algebra.gemm()
        df = stt.apply_stt(g, MNK, stt.stt_from_name("weight_stationary"))
        assert classes(df) == (DC.SYSTOLIC, DC.STATIONARY, DC.SYSTOLIC)

    def test_identity_is_MMT(self):
        g = algebra.gemm()
        df = stt.apply_stt(g, MNK, stt.stt_from_name("identity"))
        assert classes(df) == (DC.MULTICAST, DC.MULTICAST, DC.STATIONARY)
        # output stationary letter name
        assert df.name == "MNK-MMT"

    def test_mttkrp_ikl_ubbb(self):
        # paper §VI names IKL-UBBB for MTTKRP: A unicast, rest 2-D reuse
        mt = algebra.mttkrp()
        df = stt.apply_stt(mt, ("i", "k", "l"), stt.stt_from_name("identity"))
        assert df.name == "IKL-UBBB"
        assert df.by_tensor()["A"].cls is DC.UNICAST
        for t in ("B", "C", "D"):
            assert df.by_tensor()[t].cls.is_2d

    def test_batched_gemv_A_always_unicast(self):
        # paper: "Batched-GEMV can only use unicast dataflow because tensor A
        # is only accessed once" — true for EVERY loop selection and T.
        bg = algebra.batched_gemv()
        for sel in itertools.permutations(bg.loops, 3):
            df = stt.apply_stt(bg, sel, stt.stt_from_name("output_stationary"))
            assert df.by_tensor()["A"].cls is DC.UNICAST


class TestValidity:
    def test_singular_T_rejected(self):
        g = algebra.gemm()
        T = linalg.mat([[1, 0, 0], [1, 0, 0], [0, 0, 1]])
        with pytest.raises(stt.InvalidSTT):
            stt.apply_stt(g, MNK, T)

    def test_wrong_size_T_rejected(self):
        g = algebra.gemm()
        with pytest.raises(stt.InvalidSTT):
            stt.apply_stt(g, MNK, linalg.identity(2))

    def test_simulator_detects_collision_for_rank_deficient(self):
        g = algebra.gemm(4, 4, 4)
        T = linalg.mat([[1, 0, 0], [0, 1, 0], [1, 1, 0]])  # singular
        with pytest.raises(stt.InvalidSTT):
            stt.simulate(g, MNK, T)


class TestSimulator:
    """The space-time simulator proves schedules compute the algebra."""

    @pytest.mark.parametrize("kind", ["identity", "output_stationary",
                                      "weight_stationary", "input_stationary"])
    def test_gemm_all_classic_dataflows(self, kind):
        g = algebra.gemm(5, 4, 3)
        out, cycles, ext = stt.simulate(g, MNK, stt.stt_from_name(kind))
        assert cycles >= 3  # at least the reduction depth

    def test_conv2d_kcx(self):
        cv = algebra.conv2d(4, 3, 4, 4, 2, 2)
        stt.simulate(cv, ("k", "c", "x"), stt.stt_from_name("identity"))

    def test_mttkrp(self):
        mt = algebra.mttkrp(3, 3, 3, 3)
        stt.simulate(mt, ("i", "j", "k"), stt.stt_from_name("output_stationary"))

    def test_ttmc(self):
        tt = algebra.ttmc(3, 3, 3, 2, 2)
        stt.simulate(tt, ("i", "j", "k"), stt.stt_from_name("identity"))

    def test_depthwise(self):
        dw = algebra.depthwise_conv(4, 4, 4, 2, 2)
        stt.simulate(dw, ("k", "y", "x"), stt.stt_from_name("identity"))

    def test_batched_gemv(self):
        bg = algebra.batched_gemv(3, 4, 4)
        stt.simulate(bg, ("m", "n", "k"), stt.stt_from_name("identity"))


full_rank_T = st.lists(
    st.lists(st.integers(min_value=-1, max_value=1), min_size=3, max_size=3),
    min_size=3, max_size=3,
).map(linalg.mat).filter(lambda T: linalg.det(T) != 0)


class TestProperties:
    @given(full_rank_T)
    @settings(max_examples=150, deadline=None)
    def test_reuse_rank_matches_nullity(self, T):
        """rank(reuse subspace) == 3 - rank(A_sel): T is a bijection."""
        g = algebra.gemm()
        df = stt.apply_stt(g, MNK, T)
        cols = [g.loop_index(s) for s in MNK]
        for t, tdf in zip(g.tensors, df.tensors):
            a_sel = linalg.submatrix_cols(t.access, cols)
            assert tdf.reuse_rank == 3 - linalg.rank(a_sel)

    @given(full_rank_T)
    @settings(max_examples=150, deadline=None)
    def test_gemm_classification_consistency(self, T):
        """For GEMM every tensor has reuse rank exactly 1, so the class must
        be one of the three rank-1 classes, and dp/dt predicates must agree
        with the class."""
        g = algebra.gemm()
        df = stt.apply_stt(g, MNK, T)
        for t in df.tensors:
            assert t.reuse_rank == 1
            if t.cls is DC.STATIONARY:
                assert all(d == 0 for d in t.dp) and t.dt != 0
            elif t.cls is DC.SYSTOLIC:
                assert any(d != 0 for d in t.dp) and t.dt != 0
            else:
                assert t.cls in (DC.MULTICAST, DC.REDUCTION)
                assert any(d != 0 for d in t.dp) and t.dt == 0

    @given(full_rank_T)
    @settings(max_examples=25, deadline=None)
    def test_simulation_correct_for_any_full_rank_T(self, T):
        """One-to-one mapping + correct result for arbitrary full-rank T —
        the paper's central claim about STT validity."""
        g = algebra.gemm(3, 3, 3)
        stt.simulate(g, MNK, T)

    @given(full_rank_T, full_rank_T)
    @settings(max_examples=50, deadline=None)
    def test_signature_deterministic(self, T1, T2):
        """Equal T -> equal signature; signatures only depend on T."""
        g = algebra.gemm()
        df1 = stt.apply_stt(g, MNK, T1)
        df1b = stt.apply_stt(g, MNK, T1)
        assert df1.signature == df1b.signature

    @given(full_rank_T)
    @settings(max_examples=50, deadline=None)
    def test_output_never_multicast_input_class(self, T):
        """Rank-1 dt=0 output must classify as REDUCTION, never MULTICAST."""
        g = algebra.gemm()
        df = stt.apply_stt(g, MNK, T)
        assert df.tensors[-1].cls is not DC.MULTICAST
