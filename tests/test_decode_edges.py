"""models/decode.py edge cases (ISSUE 7 satellite).

``_fit_cache`` window fitting (roll alignment + padding), prefill with
the prompt already at ``max_len`` exactly, and EOS fired by the very
first decoded token.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import forward, init_params, split
from repro.models.decode import _fit_cache, decode_step, prefill
from repro.serve import DecodeEngine, ServeConfig


def setup_arch(arch):
    cfg = get_config(arch).reduced()
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


# ---------------------------------------------------------------------------
# _fit_cache
# ---------------------------------------------------------------------------

def _seq_cache(s0):
    """(L, B, S0, kv) leaf whose value encodes its absolute position."""
    return {"k": jnp.broadcast_to(
        jnp.arange(s0, dtype=jnp.float32)[None, None, :, None],
        (2, 1, s0, 4))}


def test_fit_cache_linear_pads_to_max_len():
    out = _fit_cache(_seq_cache(5), window=None, max_len=12, s0=5)["k"]
    assert out.shape == (2, 1, 12, 4)
    np.testing.assert_array_equal(out[0, 0, :5, 0], np.arange(5))
    np.testing.assert_array_equal(out[0, 0, 5:, 0], np.zeros(7))


def test_fit_cache_rolling_keeps_last_window_aligned():
    """SWA: slot i must hold absolute position with ``pos % s_cache == i``
    — that alignment is what decode's rolling write depends on."""
    s0, window = 10, 4
    out = _fit_cache(_seq_cache(s0), window=window, max_len=16, s0=s0)["k"]
    assert out.shape == (2, 1, window, 4)
    kept = sorted(int(v) for v in np.asarray(out[0, 0, :, 0]))
    assert kept == [6, 7, 8, 9]            # the last `window` positions
    for slot in range(window):
        assert int(out[0, 0, slot, 0]) % window == slot


def test_fit_cache_rolling_window_divides_s0_no_roll():
    s0, window = 8, 4
    out = _fit_cache(_seq_cache(s0), window=window, max_len=16, s0=s0)["k"]
    np.testing.assert_array_equal(np.asarray(out[0, 0, :, 0]),
                                  [4, 5, 6, 7])  # already aligned


def test_fit_cache_prompt_shorter_than_window_pads():
    out = _fit_cache(_seq_cache(3), window=8, max_len=16, s0=3)["k"]
    assert out.shape == (2, 1, 8, 4)
    np.testing.assert_array_equal(out[0, 0, :3, 0], [0, 1, 2])
    assert np.asarray(out[0, 0, 3:, 0]).sum() == 0


# ---------------------------------------------------------------------------
# prefill exactly at max_len
# ---------------------------------------------------------------------------

def test_prefill_at_max_len_exactly_matches_forward():
    """A prompt that fills the whole context budget: prefill's last-token
    logits must equal forward's, and one more decode step still works
    (SWA rolls; linear caches simply have no free slot left to read)."""
    cfg, params = setup_arch("h2o-danube-1.8b")   # SWA: rolling cache
    s = 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    logits_f, _, _ = forward(params, toks, cfg)
    logits_p, cache = prefill(params, toks, cfg, max_len=s)
    np.testing.assert_array_equal(np.asarray(logits_p),
                                  np.asarray(logits_f[:, -1]))
    assert int(cache["pos"]) == s
    nxt = jnp.argmax(logits_p, axis=-1)[:, None].astype(jnp.int32)
    logits_d, cache2 = decode_step(params, nxt, cache, cfg)
    assert np.isfinite(np.asarray(logits_d)).all()
    assert int(cache2["pos"]) == s + 1


# ---------------------------------------------------------------------------
# EOS on the first decoded token
# ---------------------------------------------------------------------------

def test_eos_on_first_decoded_token_stops_generation():
    cfg, params = setup_arch("granite-8b")
    prompts = (np.arange(12, dtype=np.int32) % cfg.vocab)[None]
    probe = DecodeEngine(params, cfg)
    first = int(probe.generate(prompts, max_new_tokens=1)[0][0, 0])

    engine = DecodeEngine(params, cfg, ServeConfig(eos_id=first))
    gen, stats = engine.generate(prompts, max_new_tokens=8)
    assert gen.shape == (1, 1)             # stopped immediately
    assert int(gen[0, 0]) == first
    assert stats["generated"] == 1
