"""The front door: ``repro.generate`` / ``Accelerator`` (ISSUE 2).

Single-device API behaviour lives here (mesh-parity tests run on 8 fake
devices in a subprocess — see test_distributed.py).  Also home to the
satellite regression tests: diagonal CommPlan axes and the bounded,
thread-safe compile cache.
"""
import concurrent.futures as cf

import numpy as np
import pytest

import repro
from repro import compile as rcompile
from repro.core import algebra, dse, linalg, plan, stt
from repro.core.plan import ExecutionPlan


def small_gemm():
    return algebra.gemm(8, 8, 8)


# ---------------------------------------------------------------------------
# generate(): the one front door
# ---------------------------------------------------------------------------

def test_generate_by_name_matches_reference():
    acc = repro.generate("gemm", bounds=dict(m=8, n=8, k=8), interpret=True)
    assert isinstance(acc, repro.Accelerator)
    operands = acc.algebra.random_operands(seed=1)
    got = np.asarray(acc(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, acc.algebra.reference(operands))


def test_generate_named_dataflow_and_plan_surface():
    acc = repro.generate(small_gemm(), "weight_stationary", interpret=True)
    assert isinstance(acc.plan, ExecutionPlan)
    assert acc.template == "operand_stationary"
    assert acc.plan.kernel.resident_tensor == "B"
    # cost_report comes from the same (algebra, dataflow) pair
    assert acc.cost_report().dataflow_name == acc.dataflow.name
    assert acc.validate() <= 1e-3
    assert "Accelerator(gemm" in acc.describe()


def test_generate_default_is_output_stationary():
    acc = repro.generate(small_gemm(), interpret=True)
    assert acc.dataflow.name == "MNK-SST"
    assert acc.template == "output_stationary"


def test_generate_rejects_unknown_name():
    with pytest.raises(ValueError, match="registry"):
        repro.generate("winograd")


def test_generate_rejects_dataflow_and_search_together():
    with pytest.raises(ValueError, match="not both"):
        repro.generate(small_gemm(), "identity", search=2)


def test_generate_from_search_consumes_ranked_candidates():
    g = small_gemm()
    ranked = dse.search(g, top_k=3, selections=[("m", "n", "k")])
    assert len(ranked) == 3
    acc = repro.generate(g, search=ranked, interpret=True)
    assert acc.candidates is not None and len(acc.candidates) == 3
    # the winner is the dataflow the accelerator actually runs
    assert acc.candidates[0][1].signature == acc.dataflow.signature
    operands = g.random_operands(seed=2)
    got = np.asarray(acc(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, g.reference(operands))


def test_generate_search_int_runs_dse():
    acc = repro.generate(small_gemm(), search=2, interpret=True)
    assert acc.candidates and acc.kernel.validated


# ---------------------------------------------------------------------------
# Satellite: diagonal reuse directions keep both mesh axes
# ---------------------------------------------------------------------------

def test_diagonal_reduction_reports_both_axes():
    # T maps e_k -> (1, 1, 0): C's reuse moves diagonally in space with
    # dt = 0 -> a reduction over *both* mesh axes, previously truncated
    # to the major axis by _axis_for
    g = small_gemm()
    T = linalg.mat([[1, 0, 1], [0, 1, 1], [1, 1, 0]])
    df = stt.apply_stt(g, ("m", "n", "k"), T)
    by = {t.tensor: t for t in df.tensors}
    assert by["C"].cls.value == "reduction" and by["C"].dp == (1, 1)
    comm = plan.comm_plan_for(df)
    c = comm.by_tensor()["C"]
    assert c.kind == "psum"
    assert c.mesh_axes == ("x", "y")        # both axes, major first
    assert c.mesh_axis == "x"               # back-compat accessor
    assert c.is_diagonal


def test_single_axis_moves_unchanged():
    g = small_gemm()
    df = stt.apply_stt(g, ("m", "n", "k"),
                       stt.stt_from_name("output_stationary"))
    comm = plan.comm_plan_for(df)
    a = comm.by_tensor()["A"]
    assert a.kind == "ppermute_ring" and a.mesh_axes == ("y",)
    assert not a.is_diagonal


# ---------------------------------------------------------------------------
# Satellite: bounded + locked compile cache
# ---------------------------------------------------------------------------

def test_cache_capacity_evicts_lru():
    rcompile.cache_clear()
    old_cap = rcompile.cache_info()["capacity"]
    try:
        rcompile.cache_resize(2)
        g = small_gemm()
        for m in (8, 16, 24):
            alg = g.with_bounds(m=m)
            df = stt.apply_stt(alg, alg.loops,
                               stt.stt_from_name("identity"))
            rcompile.lower(alg, df, interpret=True, validate=False)
        info = rcompile.cache_info()
        assert info["size"] == 2
        assert info["evictions"] >= 1
        # the first-lowered (LRU) entry was evicted: re-lowering misses
        alg = g.with_bounds(m=8)
        df = stt.apply_stt(alg, alg.loops, stt.stt_from_name("identity"))
        before = rcompile.cache_info()["misses"]
        rcompile.lower(alg, df, interpret=True, validate=False)
        assert rcompile.cache_info()["misses"] == before + 1
    finally:
        rcompile.cache_resize(old_cap)
        rcompile.cache_clear()


def test_cache_resize_rejects_nonpositive():
    with pytest.raises(ValueError):
        rcompile.cache_resize(0)


def test_accelerator_serve_engine_rides_front_door():
    from repro.serve import AcceleratorEngine
    eng = AcceleratorEngine(interpret=True)
    g = small_gemm()
    operands = g.random_operands(seed=4)
    out = eng.submit("gemm", operands, bounds=dict(m=8, n=8, k=8))
    np.testing.assert_array_equal(
        np.asarray(out).round().astype(np.int64), g.reference(operands))
    st = eng.stats()
    assert st["requests"] == 1 and st["algebras"] == ["gemm"]
    assert st["compile_cache"]["size"] >= 1


def test_cache_concurrent_lowers_share_one_kernel():
    rcompile.cache_clear()
    alg = small_gemm()
    df = stt.apply_stt(alg, alg.loops, stt.stt_from_name("identity"))

    def one(_):
        return rcompile.lower(alg, df, interpret=True, validate=False)

    with cf.ThreadPoolExecutor(max_workers=8) as ex:
        kernels = list(ex.map(one, range(16)))
    assert len({id(k) for k in kernels}) == 1
    info = rcompile.cache_info()
    assert info["size"] == 1
    assert info["hits"] + info["misses"] == 16
    rcompile.cache_clear()
