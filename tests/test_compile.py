"""End-to-end compile pipeline: every registry algebra x named STTs must
lower to an executable kernel matching the loop-nest oracle (ISSUE 1)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import compile as rcompile
from repro.core import algebra, plan, stt, tiling
from repro.kernels import ops, stt_gemm


#: small bounds so alg.reference (python loop oracle) stays fast and the
#: fp32 path is exact on integer operands
SMALL_BOUNDS = {
    "gemm": dict(m=8, n=8, k=8),
    "batched_gemv": dict(m=4, k=8, n=8),
    "conv2d": dict(k=8, c=4, y=6, x=6, p=3, q=3),
    "depthwise_conv": dict(k=8, y=6, x=6, p=3, q=3),
    "mttkrp": dict(i=8, j=8, k=4, l=4),
    "ttmc": dict(i=4, j=4, k=4, l=4, m=4),
}

NAMED_STTS = ("identity", "output_stationary", "weight_stationary",
              "input_stationary")


def small(name):
    return algebra.get_algebra(name, **SMALL_BOUNDS[name])


# ---------------------------------------------------------------------------
# The acceptance matrix: registry x named STTs, interpret mode vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", NAMED_STTS)
@pytest.mark.parametrize("name", sorted(algebra.PAPER_ALGEBRAS))
def test_every_algebra_executes_through_pipeline(name, kind):
    alg = small(name)
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(kind))
    kern = rcompile.lower(alg, df, interpret=True)
    assert kern.validated          # small problem -> auto-validated
    operands = alg.random_operands(seed=7)
    got = np.asarray(kern(operands)).round().astype(np.int64)
    want = alg.reference(operands)
    np.testing.assert_array_equal(got, want)
    # the template really is the plan's selection for this dataflow
    assert kern.template == plan.kernel_plan_for(df).template


def test_lowering_covers_whole_registry():
    for name in algebra.PAPER_ALGEBRAS:
        form = rcompile.gemmize(small(name))
        alg = small(name)
        assert form.m * form.n * form.k > 0
        # every loop iterator is folded into exactly the dims it claims
        folded = [l for dim in ("m", "n", "k") for l in form.dim_loops[dim]]
        assert set(folded) <= set(alg.loops)


def test_gemmize_unknown_algebra_raises():
    bogus = algebra.gemm(4, 4, 4)
    bogus = bogus.__class__(name="winograd", loops=bogus.loops,
                            bounds=bogus.bounds, tensors=bogus.tensors)
    with pytest.raises(NotImplementedError):
        rcompile.gemmize(bogus)


# ---------------------------------------------------------------------------
# Compile cache
# ---------------------------------------------------------------------------

def test_cache_hits_on_repeat_lowering():
    rcompile.cache_clear()
    alg = small("gemm")
    df = stt.apply_stt(alg, alg.loops, stt.stt_from_name("identity"))
    k1 = rcompile.lower(alg, df, interpret=True)
    before = rcompile.cache_info()
    k2 = rcompile.lower(alg, df, interpret=True)
    after = rcompile.cache_info()
    assert k1 is k2
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]


def test_cache_hit_honours_late_validate_request():
    rcompile.cache_clear()
    alg = small("gemm")
    df = stt.apply_stt(alg, alg.loops, stt.stt_from_name("identity"))
    k1 = rcompile.lower(alg, df, interpret=True, validate=False)
    assert not k1.validated
    k2 = rcompile.lower(alg, df, interpret=True, validate=True)
    assert k2 is k1 and k2.validated


def test_cache_distinguishes_shapes_dtype_interpret():
    rcompile.cache_clear()
    a1 = small("gemm")
    a2 = a1.with_bounds(m=16)
    df1 = stt.apply_stt(a1, a1.loops, stt.stt_from_name("identity"))
    df2 = stt.apply_stt(a2, a2.loops, stt.stt_from_name("identity"))
    k1 = rcompile.lower(a1, df1, interpret=True)
    k2 = rcompile.lower(a2, df2, interpret=True)            # shapes differ
    k3 = rcompile.lower(a1, df1, interpret=True, dtype=jnp.bfloat16,
                        validate=False)                     # dtype differs
    k4 = rcompile.lower(a1, df1, interpret=True, backend="xla")
    assert len({id(k) for k in (k1, k2, k3, k4)}) == 4
    assert rcompile.cache_info()["misses"] == 4


def _lower_gemm_m(m, **kw):
    alg = small("gemm").with_bounds(m=m)
    df = stt.apply_stt(alg, alg.loops, stt.stt_from_name("identity"))
    return rcompile.lower(alg, df, interpret=True, validate=False, **kw)


def test_cache_eviction_follows_recency_not_insertion():
    """A cache hit must refresh recency: with capacity 2, touching the
    older entry before inserting a third evicts the *other* one."""
    rcompile.cache_clear()
    old_cap = rcompile.cache_info()["capacity"]
    try:
        rcompile.cache_resize(2)
        _lower_gemm_m(8)
        _lower_gemm_m(16)
        _lower_gemm_m(8)            # hit: m=8 becomes most-recently-used
        _lower_gemm_m(24)           # evicts m=16, not m=8
        before = rcompile.cache_info()
        _lower_gemm_m(8)
        after = rcompile.cache_info()
        assert after["hits"] == before["hits"] + 1       # m=8 survived
        _lower_gemm_m(16)
        assert rcompile.cache_info()["misses"] == after["misses"] + 1
    finally:
        rcompile.cache_resize(old_cap)
        rcompile.cache_clear()


def test_cache_resize_below_occupancy_evicts_lru_first():
    rcompile.cache_clear()
    old_cap = rcompile.cache_info()["capacity"]
    try:
        kernels = {m: _lower_gemm_m(m) for m in (8, 16, 24)}
        assert rcompile.cache_info()["size"] == 3
        rcompile.cache_resize(1)
        info = rcompile.cache_info()
        assert info["size"] == 1 and info["capacity"] == 1
        assert info["evictions"] == 2
        # the survivor is the most recently used entry (m=24)
        assert _lower_gemm_m(24) is kernels[24]
        assert rcompile.cache_info()["hits"] == info["hits"] + 1
    finally:
        rcompile.cache_resize(old_cap)
        rcompile.cache_clear()


def test_cache_hit_auto_validates_small_problems():
    """An entry cached via lower(validate=False) must be validated on a
    later hit when the default auto-validate policy applies (small MACs),
    not only on an explicit validate=True request."""
    rcompile.cache_clear()
    alg = small("gemm")
    df = stt.apply_stt(alg, alg.loops, stt.stt_from_name("identity"))
    k1 = rcompile.lower(alg, df, interpret=True, validate=False)
    assert not k1.validated
    assert alg.total_macs() <= rcompile.pipeline.VALIDATE_MACS_LIMIT
    k2 = rcompile.lower(alg, df, interpret=True)         # validate=None
    assert k2 is k1 and k2.validated
    rcompile.cache_clear()


def test_lower_rejects_foreign_dataflow():
    g = small("gemm")
    mt = small("mttkrp")
    df = stt.apply_stt(mt, mt.loops[:3], stt.stt_from_name("identity"))
    with pytest.raises(ValueError):
        rcompile.lower(g, df, interpret=True)


# ---------------------------------------------------------------------------
# Tile chooser is shared between cost model and compiler
# ---------------------------------------------------------------------------

def test_blocks_come_from_shared_tile_chooser():
    alg = algebra.gemm(256, 256, 256)
    df = stt.apply_stt(alg, alg.loops, stt.stt_from_name("output_stationary"))
    kern = rcompile.lower(alg, df, interpret=True, validate=False)
    tile, _, _ = tiling.choose_tile(alg, df, kern.cfg.pe_dims)
    per_loop = dict(zip(df.selected, tile))
    assert kern.blocks == (per_loop["m"], per_loop["n"], per_loop["k"])
    # and not the historic hard-coded 128 default
    assert kern.blocks != (stt_gemm.DEFAULT_BLOCK,) * 3
    # the cost model prices the same tile the compiler runs with
    assert kern.cost_report().dataflow_name == df.name


# ---------------------------------------------------------------------------
# VMEM bound on the operand-stationary strip (satellite 2)
# ---------------------------------------------------------------------------

def test_operand_stationary_vmem_check_raises():
    a = jnp.zeros((256, 32), jnp.float32)
    b = jnp.zeros((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        stt_gemm.matmul_operand_stationary(
            a, b, bm=32, bn=32, bk=32, interpret=True,
            vmem_budget=256 * 32 * 4 - 1)


def test_stt_matmul_falls_back_to_output_stationary():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    # budget below the (64, 32) fp32 strip -> silently uses the
    # output-stationary template; result must still be correct
    got = ops.stt_matmul(a, b, template="operand_stationary",
                         bm=32, bn=32, bk=32, interpret=True,
                         vmem_budget=64 * 32 * 4 - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-3)


def test_stt_matmul_within_budget_unchanged():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    got = ops.stt_matmul(a, b, template="operand_stationary",
                         bm=32, bn=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-3)
