"""Minimal fallback for ``hypothesis`` when it is not installed.

The property tests in this repo use a small, fixed subset of the hypothesis
API: ``@given`` over ``st.integers`` / ``st.lists`` (with ``.map`` and
``.filter``) plus ``@settings(max_examples=..., deadline=...)``.  This shim
re-implements exactly that subset with a deterministic seeded RNG so the
suite still exercises the properties (with less sophisticated shrinking and
no database) on images without hypothesis.

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""
import random

DEFAULT_MAX_EXAMPLES = 20
_FILTER_ATTEMPTS = 10_000


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(_FILTER_ATTEMPTS):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate rejected too many examples")
        return _Strategy(draw)


class st:
    """Drop-in namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10
        return _Strategy(
            lambda rng: [elements._draw(rng)
                         for _ in range(rng.randint(min_size, hi))])


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        n = getattr(fn, "_compat_max_examples", DEFAULT_MAX_EXAMPLES)

        # *args-only signature so pytest does not mistake the drawn
        # parameters for fixtures.
        def wrapper(*args, **kwargs):
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = [s._draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
