"""Launch-layer tests: HLO analyzer units + a miniature dry-run cell
(subprocess with 8 fake devices — the full 512-device sweep is
`python -m repro.launch.dryrun`, recorded in EXPERIMENTS.md)."""
import os
import subprocess
import sys
import textwrap


from repro.launch import hlo_analysis as H


class TestHloAnalysis:
    def test_shape_bytes(self):
        assert H.shape_bytes("f32[2,3]{1,0}") == 24
        assert H.shape_bytes("bf16[128]") == 256
        assert H.shape_bytes("(f32[2], s32[4])") == 24
        assert H.shape_bytes("pred[]") == 1

    def test_group_size_formats(self):
        assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 1) == 4
        assert H._group_size("replica_groups=[16,32]<=[512]", 1) == 32
        assert H._group_size("no groups here", 7) == 7

    def test_wire_factors(self):
        assert H._WIRE_FACTOR["all-gather"](160, 16) == 150
        assert H._WIRE_FACTOR["all-reduce"](160, 16) == 300
        assert H._WIRE_FACTOR["collective-permute"](160, 16) == 160

    def test_analyze_synthetic_module(self):
        hlo = textwrap.dedent("""\
        HloModule test

        %cond (p: (s32[], f32[8,8])) -> pred[] {
          %p = (s32[], f32[8,8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %n = s32[] constant(5)
          ROOT %lt = pred[] compare(%i, %n), direction=LT
        }

        %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
          %p = (s32[], f32[8,8]) parameter(0)
          %x = f32[8,8] get-tuple-element(%p), index=1
          %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %ag = f32[8,8] all-gather(%d), replica_groups=[4,4]<=[16], dimensions={0}
          %i = s32[] get-tuple-element(%p), index=0
          %one = s32[] constant(1)
          %i2 = s32[] add(%i, %one)
          ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ag)
        }

        ENTRY %main (a: f32[8,8]) -> f32[8,8] {
          %a = f32[8,8] parameter(0)
          %zero = s32[] constant(0)
          %t0 = (s32[], f32[8,8]) tuple(%zero, %a)
          %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
          ROOT %out = f32[8,8] get-tuple-element(%w), index=1
        }
        """)
        st = H.analyze(hlo)
        # dot: 2*64*8 = 1024 flops, x5 trips
        assert st.flops == 1024 * 5
        # all-gather result 256B * 3/4 * 5 trips
        assert st.wire_bytes == 256 * 0.75 * 5
        assert st.trip_counts == {"w": 5}

    def test_nested_while_multiplies(self):
        hlo = textwrap.dedent("""\
        HloModule nested

        %icond (p: (s32[], f32[4,4])) -> pred[] {
          %p = (s32[], f32[4,4]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %n = s32[] constant(3)
          ROOT %lt = pred[] compare(%i, %n), direction=LT
        }

        %ibody (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
          %p = (s32[], f32[4,4]) parameter(0)
          %x = f32[4,4] get-tuple-element(%p), index=1
          %d = f32[4,4] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %i = s32[] get-tuple-element(%p), index=0
          %one = s32[] constant(1)
          %i2 = s32[] add(%i, %one)
          ROOT %t = (s32[], f32[4,4]) tuple(%i2, %d)
        }

        %ocond (p: (s32[], f32[4,4])) -> pred[] {
          %p = (s32[], f32[4,4]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %n = s32[] constant(4)
          ROOT %lt = pred[] compare(%i, %n), direction=LT
        }

        %obody (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
          %p = (s32[], f32[4,4]) parameter(0)
          %x = f32[4,4] get-tuple-element(%p), index=1
          %zero = s32[] constant(0)
          %t0 = (s32[], f32[4,4]) tuple(%zero, %x)
          %w = (s32[], f32[4,4]) while(%t0), condition=%icond, body=%ibody
          %y = f32[4,4] get-tuple-element(%w), index=1
          %i = s32[] get-tuple-element(%p), index=0
          %one = s32[] constant(1)
          %i2 = s32[] add(%i, %one)
          ROOT %t = (s32[], f32[4,4]) tuple(%i2, %y)
        }

        ENTRY %main (a: f32[4,4]) -> f32[4,4] {
          %a = f32[4,4] parameter(0)
          %zero = s32[] constant(0)
          %t0 = (s32[], f32[4,4]) tuple(%zero, %a)
          %w = (s32[], f32[4,4]) while(%t0), condition=%ocond, body=%obody
          ROOT %out = f32[4,4] get-tuple-element(%w), index=1
        }
        """)
        st = H.analyze(hlo)
        # inner dot 2*16*4=128 flops x3 inner x4 outer
        assert st.flops == 128 * 3 * 4


SMOKE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import jax
from repro.launch import specs, hlo_analysis
from repro.configs import get_config

# miniature production mesh (2x4) standing in for (16x16)
from repro import jax_compat
mesh = jax_compat.make_mesh((2, 4), ("data", "model"))
cell = specs.input_specs("granite-8b", "train_4k", mesh)
with jax_compat.set_mesh(mesh):
    lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                      out_shardings=cell.out_shardings,
                      donate_argnums=cell.donate).lower(*cell.args)
    compiled = lowered.compile()
mem = compiled.memory_analysis()
assert mem.argument_size_in_bytes > 0
st = hlo_analysis.analyze(compiled.as_text())
assert st.flops > 0 and st.wire_bytes > 0
assert 36 in st.trip_counts.values()   # granite has 36 layers scanned
print("SMOKE_DRYRUN_OK flops=%g wire=%g" % (st.flops, st.wire_bytes))
"""


def test_dryrun_cell_smoke_8_devices():
    """Full lower+compile+analyze path on a small mesh in a subprocess."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = SMOKE_SCRIPT.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SMOKE_DRYRUN_OK" in proc.stdout


def test_input_specs_all_cells_constructible():
    """Every (arch x shape) cell must build its specs (no device state)."""
    from repro.launch import specs
    from repro import jax_compat
    mesh = jax_compat.make_mesh((1, 1), ("data", "model"))
    n = 0
    for arch, shape in specs.all_cells():
        cell = specs.input_specs(arch, shape, mesh)
        assert cell.model_flops > 0
        n += 1
    assert n == 34

    skips = list(specs.skipped_cells())
    assert len(skips) == 6
    assert n + len(skips) == 40   # the full assignment grid
