"""Exact linear algebra: unit + property tests."""
from fractions import Fraction

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # seed image lacks hypothesis
    from _hypothesis_compat import given, settings, st

from repro.core import linalg


def rand_matrix(draw, m, n, lo=-3, hi=3):
    return linalg.mat([[draw for _ in range(n)] for _ in range(m)])


small_int = st.integers(min_value=-3, max_value=3)


def mat_strategy(m, n):
    return st.lists(
        st.lists(small_int, min_size=n, max_size=n), min_size=m, max_size=m
    ).map(linalg.mat)


class TestBasics:
    def test_identity_matmul(self):
        a = linalg.mat([[1, 2], [3, 4]])
        assert linalg.matmul(a, linalg.identity(2)) == a

    def test_inverse_known(self):
        a = linalg.mat([[1, 0, 0], [0, 1, 0], [1, 1, 1]])
        inv = linalg.inverse(a)
        assert linalg.matmul(a, inv) == linalg.identity(3)

    def test_inverse_singular_raises(self):
        with pytest.raises(ValueError):
            linalg.inverse(linalg.mat([[1, 2], [2, 4]]))

    def test_nullspace_simple(self):
        # A = [[1,0,0],[0,0,1]] -> null = e2
        a = linalg.mat([[1, 0, 0], [0, 0, 1]])
        ns = linalg.nullspace(a)
        assert ns == [(Fraction(0), Fraction(1), Fraction(0))]

    def test_integerize(self):
        v = (Fraction(1, 2), Fraction(-1, 3), Fraction(0))
        assert linalg.integerize(v) == (Fraction(3), Fraction(-2), Fraction(0))
        v = (Fraction(-1, 2), Fraction(1, 3), Fraction(0))
        assert linalg.integerize(v) == (Fraction(3), Fraction(-2), Fraction(0))

    def test_intersect_with_hyperplane(self):
        # plane spanned by e0,e2; intersect with {x2=0} -> e0
        basis = [linalg.integerize((Fraction(1), Fraction(0), Fraction(0))),
                 linalg.integerize((Fraction(0), Fraction(0), Fraction(1)))]
        normal = (Fraction(0), Fraction(0), Fraction(1))
        got = linalg.intersect_with_hyperplane(basis, normal)
        assert got == [(Fraction(1), Fraction(0), Fraction(0))]


class TestProperties:
    @given(mat_strategy(3, 3))
    @settings(max_examples=200, deadline=None)
    def test_rank_nullity(self, a):
        assert linalg.rank(a) + len(linalg.nullspace(a)) == 3

    @given(mat_strategy(3, 3))
    @settings(max_examples=200, deadline=None)
    def test_nullspace_annihilates(self, a):
        for v in linalg.nullspace(a):
            assert all(x == 0 for x in linalg.matvec(a, v))

    @given(mat_strategy(3, 3))
    @settings(max_examples=200, deadline=None)
    def test_inverse_roundtrip(self, a):
        if linalg.det(a) == 0:
            with pytest.raises(ValueError):
                linalg.inverse(a)
        else:
            assert linalg.matmul(a, linalg.inverse(a)) == linalg.identity(3)

    @given(mat_strategy(2, 4))
    @settings(max_examples=100, deadline=None)
    def test_rank_transpose_invariant(self, a):
        assert linalg.rank(a) == linalg.rank(linalg.transpose(a))

    @given(mat_strategy(3, 3), mat_strategy(3, 3))
    @settings(max_examples=100, deadline=None)
    def test_det_multiplicative(self, a, b):
        assert linalg.det(linalg.matmul(a, b)) == linalg.det(a) * linalg.det(b)
