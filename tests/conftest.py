"""Shared test fixtures.

The measured-tuning cache (``repro.tune.cache``) persists to
``$REPRO_TUNE_CACHE`` (default ``~/.cache/repro-tune``) and ``lower()``
consults it before the analytical tile chooser — so a leftover cache
from a developer's tuning run would silently change block sizes under
tests that assert analytical behavior.  Every test therefore gets a
fresh, empty cache directory.
"""
import pytest


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "repro-tune"))
    from repro.tune import cache
    cache.cache_clear(counters_only=True)
    yield
    cache.cache_clear(counters_only=True)
