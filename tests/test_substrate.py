"""Substrate tests: optimizer, data pipeline, checkpointing (atomic/async/
elastic), fault-tolerant runtime, straggler watchdog, serve engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models import init_params, split
from repro.optim import adamw
from repro.runtime.driver import (RunConfig, TrainDriver,
                                  run_with_restarts)
from repro.serve.engine import DecodeEngine, ServeConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class TestAdamW:
    def quad_params(self):
        return {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([0.5])}

    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200)
        params = self.quad_params()
        state = adamw.init(params, cfg)
        loss = lambda p: sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.apply_updates(params, g, state, cfg)
        assert float(loss(params)) < 1e-2

    def test_8bit_state_tracks_fp32(self):
        loss = lambda p: sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))
        outs = {}
        for bits in (32, 8):
            cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                                    warmup_steps=1, total_steps=50,
                                    state_bits=bits)
            p = self.quad_params()
            st = adamw.init(p, cfg)
            for _ in range(50):
                g = jax.grad(loss)(p)
                p, st, _ = adamw.apply_updates(p, g, st, cfg)
            outs[bits] = np.concatenate(
                [np.asarray(x).ravel() for x in jax.tree.leaves(p)])
        np.testing.assert_allclose(outs[8], outs[32], atol=0.05)

    def test_8bit_state_memory(self):
        """int8 moments must be ~4x smaller than fp32."""
        params = {"w": jnp.zeros((1024, 512))}
        st8 = adamw.init(params, adamw.AdamWConfig(state_bits=8))
        st32 = adamw.init(params, adamw.AdamWConfig(state_bits=32))
        bytes8 = sum(np.asarray(x).nbytes
                     for x in jax.tree.leaves(st8.m))
        bytes32 = sum(np.asarray(x).nbytes
                      for x in jax.tree.leaves(st32.m))
        assert bytes8 < bytes32 / 3.5

    def test_grad_clipping(self):
        cfg = adamw.AdamWConfig(clip_norm=1.0)
        params = self.quad_params()
        state = adamw.init(params, cfg)
        huge = jax.tree.map(lambda x: 1e6 * jnp.ones_like(x), params)
        newp, _, m = adamw.apply_updates(params, huge, state, cfg)
        assert float(m["grad_norm"]) > 1e5
        delta = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(newp),
                                    jax.tree.leaves(params)))
        assert delta < 1.0   # clipped update is bounded

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_frac=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.array(s)))
               for s in [0, 9, 10, 50, 99]]
        assert lrs[0] < lrs[1] <= lrs[2]          # warmup rises
        assert lrs[2] > lrs[3] > lrs[4]           # cosine decays
        assert lrs[4] >= 0.1 * 0.999              # floor


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestPipeline:
    def test_deterministic_and_restart_safe(self):
        cfg = DataConfig(vocab=64, seq_len=16, global_batch=4)
        p1 = SyntheticPipeline(cfg)
        batches = [p1.next() for _ in range(5)]
        p2 = SyntheticPipeline(cfg)
        p2.restore({"step": 3})
        np.testing.assert_array_equal(p2.next()["tokens"],
                                      batches[3]["tokens"])

    def test_shards_disjoint(self):
        c0 = DataConfig(vocab=64, seq_len=16, global_batch=8, n_shards=2,
                        shard=0)
        c1 = DataConfig(vocab=64, seq_len=16, global_batch=8, n_shards=2,
                        shard=1)
        b0 = SyntheticPipeline(c0).next()["tokens"]
        b1 = SyntheticPipeline(c1).next()["tokens"]
        assert b0.shape == (4, 16)
        assert not np.array_equal(b0, b1)

    def test_targets_shifted(self):
        cfg = DataConfig(vocab=64, seq_len=16, global_batch=2)
        b = SyntheticPipeline(cfg).next()
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def tree(self):
        return {"a": jnp.arange(12.0).reshape(3, 4),
                "nest": {"b": jnp.ones((5,), jnp.int32)}}

    def test_roundtrip(self, tmp_path):
        t = self.tree()
        store.save(str(tmp_path), 7, t)
        got, step, _ = store.restore(str(tmp_path), t)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))

    def test_atomicity_tmp_never_visible(self, tmp_path):
        t = self.tree()
        store.save(str(tmp_path), 1, t)
        # a stale tmp dir (simulated crash) must not be listed or restored
        os.makedirs(tmp_path / "tmp.2")
        assert store.list_steps(str(tmp_path)) == [1]
        _, step, _ = store.restore(str(tmp_path), t)
        assert step == 1

    def test_async_checkpointer_gc(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
        t = self.tree()
        for s in (1, 2, 3, 4):
            ck.save_async(s, t)
        ck.wait()
        assert store.list_steps(str(tmp_path)) == [3, 4]

    def test_elastic_restore_other_device_count(self, tmp_path):
        """Checkpoints carry logical arrays; restoring under a different
        (here: trivial) sharding works — full elastic path exercised in the
        512-device dry-run harness."""
        t = self.tree()
        store.save(str(tmp_path), 5, t)
        shardings = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
        got, _, _ = store.restore(str(tmp_path), t, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))

    def test_shape_mismatch_rejected(self, tmp_path):
        store.save(str(tmp_path), 1, self.tree())
        bad = {"a": jnp.zeros((2, 2)), "nest": {"b": jnp.ones((5,), jnp.int32)}}
        with pytest.raises(ValueError, match="shape mismatch"):
            store.restore(str(tmp_path), bad)


# ---------------------------------------------------------------------------
# fault-tolerant runtime
# ---------------------------------------------------------------------------

def _driver_factory(tmp, cfg, failure_at=None, slow_at=None, steps=30):
    def make():
        return TrainDriver(
            cfg,
            adamw.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=steps),
            DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8),
            RunConfig(total_steps=steps, ckpt_every=10, log_every=10,
                      ckpt_dir=tmp),
            failure_at=failure_at, slow_step_at=slow_at)
    return make


class TestRuntime:
    def test_loss_decreases(self, tmp_path):
        cfg = get_config("granite-8b").reduced()
        out = _driver_factory(str(tmp_path), cfg, steps=60)().run()
        losses = [m["loss"] for m in out["metrics"]]
        assert losses[-1] < losses[0] * 0.8

    def test_restart_after_failure_resumes(self, tmp_path):
        cfg = get_config("granite-8b").reduced()
        holder = {"n": 0}

        def make():
            holder["n"] += 1
            return _driver_factory(str(tmp_path), cfg,
                                   failure_at=15 if holder["n"] == 1 else None,
                                   steps=30)()

        out = run_with_restarts(make, max_restarts=2)
        assert out["restarts"] == 1
        assert out["final_step"] == 30
        # resumed from the step-10 checkpoint, not from scratch
        assert store.latest_step(str(tmp_path)) == 30

    def test_straggler_watchdog_flags_slow_step(self, tmp_path):
        cfg = get_config("granite-8b").reduced()
        out = _driver_factory(str(tmp_path), cfg, slow_at=20, steps=25)().run()
        assert 20 in out["stragglers"]

    def test_resume_replays_data_stream(self, tmp_path):
        """After restore, pipeline.step must continue where it left off."""
        cfg = get_config("granite-8b").reduced()
        d1 = _driver_factory(str(tmp_path), cfg, steps=20)()
        d1.run()
        d2 = _driver_factory(str(tmp_path), cfg, steps=20)()
        assert d2.start_step == 20
        assert d2.pipeline.step == 20


# ---------------------------------------------------------------------------
# serve engine
# ---------------------------------------------------------------------------

class TestServe:
    def test_greedy_generation_matches_decode(self):
        cfg = get_config("h2o-danube-1.8b").reduced()
        params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
        eng = DecodeEngine(params, cfg, ServeConfig(max_new_tokens=8))
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab, size=(2, 12)).astype(np.int32)
        gen, stats = eng.generate(prompts)
        assert gen.shape == (2, 8)
        assert stats["generated"] == 8
        # deterministic greedy
        gen2, _ = eng.generate(prompts)
        np.testing.assert_array_equal(gen, gen2)

    def test_ssm_generation(self):
        cfg = get_config("mamba2-370m").reduced()
        params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
        eng = DecodeEngine(params, cfg, ServeConfig(max_new_tokens=6))
        prompts = np.zeros((1, 8), np.int32)
        gen, _ = eng.generate(prompts)
        assert gen.shape == (1, 6)
