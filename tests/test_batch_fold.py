"""Grid-folded batch execution (ISSUE 4): the batched templates execute
exactly the algebra's MACs and are bit-exact against the retired
block-diagonal GEMM-ization, kept as a test-only oracle in kernels/ref.py.

Integer-valued operands make every fp path exact (products and fp32
accumulations are integers far below 2^24), so "bit-exact" is meaningful
across dtypes: both paths compute the same integers and round identically
on the final cast.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import compile as rcompile
from repro.core import algebra, stt, tiling
from repro.core.algebra import Sparsity
from repro.core.costmodel import PaperCycleModel
from repro.kernels import ops, ref

NAMED_STTS = ("identity", "output_stationary", "weight_stationary",
              "input_stationary")

#: default (divisible) and deliberately awkward (non-divisible) bounds
GEMV_BOUNDS = dict(m=4, k=8, n=8)
GEMV_RAGGED = dict(m=5, k=7, n=6)
DW_BOUNDS = dict(k=8, y=6, x=6, p=3, q=3)
DW_RAGGED = dict(k=5, y=5, x=5, p=2, q=2)


def _bitwise_equal(got, want):
    got, want = np.asarray(got), np.asarray(want)
    assert got.dtype == want.dtype, (got.dtype, want.dtype)
    assert got.shape == want.shape, (got.shape, want.shape)
    np.testing.assert_array_equal(
        got.astype(np.float64), want.astype(np.float64))


# ---------------------------------------------------------------------------
# Bit-exactness vs the retired block-diagonal oracle, across dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", NAMED_STTS)
def test_batched_gemv_bit_exact_vs_blockdiag(kind, dtype):
    alg = algebra.batched_gemv(**GEMV_BOUNDS)
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(kind))
    kern = rcompile.lower(alg, df, interpret=True, dtype=dtype,
                          validate=False)
    operands = alg.random_operands(seed=11)
    got = kern(operands)
    want = ref.batched_gemv_blockdiag_ref(
        jnp.asarray(operands["A"]).astype(dtype),
        jnp.asarray(operands["B"]).astype(dtype))
    _bitwise_equal(got, want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", NAMED_STTS)
def test_depthwise_bit_exact_vs_blockdiag(kind, dtype):
    alg = algebra.depthwise_conv(**DW_BOUNDS)
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(kind))
    kern = rcompile.lower(alg, df, interpret=True, dtype=dtype,
                          validate=False)
    operands = alg.random_operands(seed=13)
    got = kern(operands)
    want = ref.depthwise_blockdiag_ref(
        jnp.asarray(operands["A"]).astype(dtype),
        jnp.asarray(operands["B"]).astype(dtype),
        y=DW_BOUNDS["y"], x=DW_BOUNDS["x"])
    _bitwise_equal(got, want)


def test_blockdiag_oracle_matches_loop_nest():
    """The oracle itself must reproduce alg.reference — otherwise the
    bit-exactness tests above would prove nothing."""
    bg = algebra.batched_gemv(**GEMV_BOUNDS)
    ops_bg = bg.random_operands(seed=2)
    want = bg.reference(ops_bg)
    got = ref.batched_gemv_blockdiag_ref(
        jnp.asarray(ops_bg["A"], jnp.float32),
        jnp.asarray(ops_bg["B"], jnp.float32))
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)

    dw = algebra.depthwise_conv(**DW_BOUNDS)
    ops_dw = dw.random_operands(seed=2)
    got = ref.depthwise_blockdiag_ref(
        jnp.asarray(ops_dw["A"], jnp.float32),
        jnp.asarray(ops_dw["B"], jnp.float32),
        y=DW_BOUNDS["y"], x=DW_BOUNDS["x"])
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64),
                                  dw.reference(ops_dw))


# ---------------------------------------------------------------------------
# _block_diag_rows is gone from the execution path
# ---------------------------------------------------------------------------

def test_block_diag_retired_from_lowering():
    from repro.compile import lowering
    assert not hasattr(lowering, "_block_diag_rows")
    for name in ("batched_gemv", "depthwise_conv"):
        form = rcompile.lower_form(algebra.get_algebra(name))
        assert form.batch, name           # batch grid dim, not zero padding
        assert form.lhs_batched and form.rhs_batched


# ---------------------------------------------------------------------------
# Executed MACs == algebra MACs across the whole registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", NAMED_STTS)
@pytest.mark.parametrize("name", sorted(algebra.PAPER_ALGEBRAS))
def test_registry_executed_mac_ratio_is_one(name, kind):
    alg = algebra.get_algebra(name)
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(kind))
    rep = PaperCycleModel().evaluate(alg, df)
    assert rep.executed_macs == alg.total_macs()
    assert rep.executed_mac_ratio == 1.0


def test_lowered_form_executed_macs_matches_algebra():
    for name in sorted(algebra.PAPER_ALGEBRAS):
        alg = algebra.get_algebra(name)
        form = rcompile.lower_form(alg)
        assert form.executed_macs == alg.total_macs(), name


def test_masked_dense_sparse_reports_honest_ratio():
    """A sparse pattern with no structured 2-D image runs masked-dense
    *within the kept batch slices*: in-slice zero blocks still execute,
    and the ratio must report that gap, not hide it.  (All-zero slices
    themselves are skipped since the per-slice mapping — ISSUE 5.)"""
    dw = algebra.depthwise_conv(**DW_BOUNDS)
    # every channel keeps only the q=0 column of its window: no slice is
    # all-zero (nothing to skip), but 2/3 of each slice's MACs are masked
    sp = Sparsity((4, 3, 1), ((0, 0, 0), (1, 0, 0)))
    dws = dw.with_sparsity(B=sp)
    form = rcompile.lower_form(dws)
    assert form.sparse is None and form.masked_sparse == ("B",)
    assert form.batch_keep is None
    rep = PaperCycleModel().evaluate(dws, rcompile.default_dataflow(dws))
    assert rep.executed_mac_ratio > 1.0


def test_batched_sparse_slice_skip_closes_ratio():
    """A pattern whose zero blocks cover whole batch slices is captured
    completely by the per-slice mapping: the kernel skips those slices
    and the ratio returns to 1.0 (previously batch/kept x too high)."""
    dw = algebra.depthwise_conv(**DW_BOUNDS)
    sp = Sparsity.random((8, 3, 3), (4, 3, 3), density=0.5, seed=0)
    dws = dw.with_sparsity(B=sp)
    form = rcompile.lower_form(dws)
    assert form.batch_keep is not None and form.batch == (4,)
    rep = PaperCycleModel().evaluate(dws, rcompile.default_dataflow(dws))
    assert rep.executed_mac_ratio == pytest.approx(1.0)
    kern = rcompile.lower(dws, interpret=True)
    assert kern.validated


# ---------------------------------------------------------------------------
# Non-divisible batch/channel and per-slice shapes pad correctly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", NAMED_STTS)
def test_batched_gemv_ragged_bounds(kind):
    alg = algebra.batched_gemv(**GEMV_RAGGED)
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(kind))
    kern = rcompile.lower(alg, df, interpret=True)
    assert kern.validated
    operands = alg.random_operands(seed=3)
    got = np.asarray(kern(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, alg.reference(operands))


@pytest.mark.parametrize("kind", NAMED_STTS)
def test_depthwise_ragged_bounds(kind):
    alg = algebra.depthwise_conv(**DW_RAGGED)
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(kind))
    kern = rcompile.lower(alg, df, interpret=True)
    assert kern.validated
    operands = alg.random_operands(seed=4)
    got = np.asarray(kern(operands)).round().astype(np.int64)
    np.testing.assert_array_equal(got, alg.reference(operands))


@pytest.mark.parametrize("template", ["output_stationary",
                                      "operand_stationary",
                                      "reduction_tree"])
def test_stt_matmul_batched_ragged_blocks(template):
    """Per-slice dims that don't divide the blocks pad through
    ops.stt_matmul; the batch dim itself never needs padding."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((5, 13, 11)).astype(np.float32)
    b = rng.standard_normal((5, 11, 9)).astype(np.float32)
    got = ops.stt_matmul(jnp.asarray(a), jnp.asarray(b), template=template,
                         bm=4, bn=4, bk=4, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.einsum("bmk,bkn->bmn", a, b),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("template", ["output_stationary",
                                      "operand_stationary",
                                      "reduction_tree"])
def test_stt_matmul_broadcasts_unbatched_operand(template):
    """A rank-2 operand broadcasts across the batch grid axis via its
    index map — one template instance serves batched x shared shapes."""
    rng = np.random.default_rng(1)
    a = rng.standard_normal((3, 16, 8)).astype(np.float32)
    b = rng.standard_normal((8, 12)).astype(np.float32)
    got = ops.stt_matmul(jnp.asarray(a), jnp.asarray(b), template=template,
                         bm=8, bn=4, bk=4, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.einsum("bmk,kn->bmn", a, b),
                               rtol=1e-4, atol=1e-3)


def test_batched_operand_stationary_vmem_check_is_per_slice():
    """The strip accumulator budget sees the per-slice m extent, not
    batch x it: a batch of strips each within budget must not trip the
    fallback-triggering check in the template itself."""
    from repro.kernels import stt_gemm
    a = jnp.zeros((8, 32, 16), jnp.float32)
    b = jnp.zeros((8, 16, 16), jnp.float32)
    # budget exactly one (32, 16) fp32 strip: per-slice fits, batch x
    # would not — must succeed
    out = stt_gemm.matmul_operand_stationary(
        a, b, bm=16, bn=16, bk=16, interpret=True,
        vmem_budget=32 * 16 * 4)
    assert out.shape == (8, 32, 16)
    with pytest.raises(ValueError, match="VMEM"):
        stt_gemm.matmul_operand_stationary(
            a, b, bm=16, bn=16, bk=16, interpret=True,
            vmem_budget=32 * 16 * 4 - 1)


# ---------------------------------------------------------------------------
# Batch never inflates the contraction in the shared tile chooser
# ---------------------------------------------------------------------------

def test_form_blocks_exclude_batch_loops():
    alg = algebra.batched_gemv(m=64, k=32, n=32)
    df = rcompile.default_dataflow(alg)
    form = rcompile.lower_form(alg)
    bm, bn, bk = tiling.form_blocks(alg, df, form)
    assert bm == 1                       # per-slice gemv row
    assert bk <= form.k                  # contraction ends at k, not m*k
    assert form.k == 32


# ---------------------------------------------------------------------------
# _attach_sparsity tie-break: lowest density wins, name breaks ties
# ---------------------------------------------------------------------------

def test_attach_sparsity_lowest_density_wins():
    g = algebra.gemm(16, 16, 16)
    dense_ish = Sparsity.random((16, 16), (4, 4), density=0.75, seed=0)
    sparse_st = Sparsity.random((16, 16), (4, 4), density=0.25, seed=1)
    form = rcompile.lower_form(g.with_sparsity(A=dense_ish, B=sparse_st))
    assert form.sparse is not None and form.sparse.tensor == "B"
    assert form.masked_sparse == ("A",)


def test_attach_sparsity_tie_breaks_by_tensor_name():
    g = algebra.gemm(16, 16, 16)
    # two distinct patterns with identical density: 4 of 16 blocks each
    sp_a = Sparsity((4, 4), ((0, 0), (1, 1), (2, 2), (3, 3)))
    sp_b = Sparsity((4, 4), ((0, 1), (1, 2), (2, 3), (3, 0)))
    form = rcompile.lower_form(g.with_sparsity(A=sp_a, B=sp_b))
    assert form.sparse is not None
    assert form.sparse.tensor == "A"     # alphabetical on equal density
    assert form.masked_sparse == ("B",)
    # ...and the choice is symmetric in the attachment order
    form2 = rcompile.lower_form(g.with_sparsity(B=sp_b, A=sp_a))
    assert form2.sparse.tensor == "A"
