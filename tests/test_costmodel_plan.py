"""Cost model (paper Fig. 5/6 claims) and plan generation tests."""
import pytest

from repro.core import algebra, costmodel, dse, plan, stt
from repro.core.stt import DataflowClass as DC

MNK = ("m", "n", "k")
MODEL = costmodel.PaperCycleModel()


def df_of(alg, sel, kind):
    return stt.apply_stt(alg, sel, stt.stt_from_name(kind))


class TestFig5Claims:
    """Assert the paper's qualitative performance findings (§VI-A)."""

    def test_gemm_multicast_beats_systolic(self):
        # "the performance of multicast dataflows (MTM) is better than
        #  systolic dataflow (STS) because of smaller pipeline overhead"
        g = algebra.gemm(256, 256, 256)
        mmt = MODEL.evaluate(g, df_of(g, MNK, "identity"))
        sts = MODEL.evaluate(g, df_of(g, MNK, "weight_stationary"))
        assert mmt.normalized_perf > sts.normalized_perf
        assert sts.fill_overhead_frac > 0 and mmt.fill_overhead_frac == 0

    def test_mttkrp_unicast_is_bandwidth_bound(self):
        # "unicast dataflows (e.g. IKL-UBBB) perform worse ... bandwidth
        #  becomes insufficient"
        mt = algebra.mttkrp(64, 64, 32, 32)
        ubbb = MODEL.evaluate(mt, df_of(mt, ("i", "k", "l"), "identity"))
        mmbt = MODEL.evaluate(mt, df_of(mt, ("i", "j", "k"), "identity"))
        assert ubbb.bw_stall_factor > 2.0
        assert ubbb.normalized_perf < 0.5 * mmbt.normalized_perf

    def test_batched_gemv_bandwidth_limited(self):
        bg = algebra.batched_gemv(64, 256, 256)
        r = MODEL.evaluate(bg, df_of(bg, MNK, "identity"))
        assert r.bw_stall_factor > 1.0      # A is unicast -> starved

    def test_conv_small_loop_bounds_idle_pes(self):
        # "XYP-SMM ... 1/16 idle PEs since the range of p is 3"
        cv = algebra.conv2d(64, 64, 16, 16, 3, 3)
        df = stt.apply_stt(cv, ("p", "x", "y"), stt.stt_from_name("identity"))
        r = MODEL.evaluate(cv, df)
        assert r.utilization == pytest.approx(15 / 16, abs=1e-9)

    def test_conv_resnet_layer5_lower_util(self):
        # x = y = 7 on layer5-like shapes -> worse utilization than layer2
        cv2 = algebra.conv2d(64, 64, 28, 28, 3, 3)
        cv5 = algebra.conv2d(512, 512, 7, 7, 3, 3)
        sel = ("x", "y", "c")
        r2 = MODEL.evaluate(cv2, stt.apply_stt(cv2, sel, stt.stt_from_name("identity")))
        r5 = MODEL.evaluate(cv5, stt.apply_stt(cv5, sel, stt.stt_from_name("identity")))
        assert r5.utilization < r2.utilization

    def test_conv_kcx_beats_xyp(self):
        # "selecting KCX iterations can deliver better performance because it
        #  becomes standard GEMM with large loop bounds"
        cv = algebra.conv2d(64, 64, 14, 14, 3, 3)
        kcx = MODEL.evaluate(cv, stt.apply_stt(
            cv, ("k", "c", "x"), stt.stt_from_name("identity")))
        xyp = MODEL.evaluate(cv, stt.apply_stt(
            cv, ("x", "y", "p"), stt.stt_from_name("identity")))
        assert kcx.normalized_perf > xyp.normalized_perf


class TestFig6Claims:
    def test_multicast_inputs_cost_more_power(self):
        # "dataflow with two multicast input (MMT, MMS) consumes more energy"
        g = algebra.gemm(256, 256, 256)
        mmt = MODEL.evaluate(g, df_of(g, MNK, "identity"))
        sst = MODEL.evaluate(g, df_of(g, MNK, "output_stationary"))
        assert mmt.power_mw > sst.power_mw

    def test_stationary_costs_area(self):
        # "dataflows with stationary tensor consume more area"
        g = algebra.gemm(256, 256, 256)
        sst = MODEL.evaluate(g, df_of(g, MNK, "output_stationary"))
        # a hypothetical all-streaming dataflow: MM + reduction output
        T = stt.stt_from_name("identity")
        # k->space, m->time gives C reduction, no stationary tensor
        df = stt.apply_stt(g, ("k", "n", "m"), T)
        r = MODEL.evaluate(g, df)
        assert any(t.cls is DC.REDUCTION for t in df.tensors)
        assert sst.area_units > r.area_units

    def test_power_range_calibration(self):
        # paper GEMM sweep spans roughly 35–63 mW (1.8x); require our sweep
        # to land in a comparable band
        g = algebra.gemm(256, 256, 256)
        sweep = [MODEL.evaluate(g, df) for df in
                 dse.enumerate_dataflows(g, selections=[MNK]).values()]
        # compare over efficient designs (perf >= 0.5), as inefficient
        # mappings idle the array and legitimately draw less power
        powers = sorted(r.power_mw for r in sweep if r.normalized_perf >= 0.5)
        assert 30 < powers[0] < powers[-1] < 80
        assert powers[-1] / powers[0] > 1.3   # meaningful spread


class TestDSE:
    def test_gemm_design_space_size(self):
        # paper reports 148 distinct GEMM dataflow points; our enumeration
        # universe is stated in dse.py — require a comparably rich space
        g = algebra.gemm(256, 256, 256)
        flows = dse.enumerate_dataflows(g)
        assert len(flows) >= 100
        classes = {t.cls for df in flows.values() for t in df.tensors}
        # the space must exercise every rank<=1 dataflow class
        assert {DC.STATIONARY, DC.SYSTOLIC, DC.MULTICAST,
                DC.REDUCTION}.issubset(classes)

    def test_depthwise_design_space(self):
        dw = algebra.depthwise_conv(64, 14, 14, 3, 3)
        sels = [("k", "x", "y"), ("k", "p", "x"), ("x", "y", "p")]
        flows = dse.enumerate_dataflows(dw, selections=sels)
        assert len(flows) >= 30   # paper: 33 points

    def test_pareto_front(self):
        g = algebra.gemm(256, 256, 256)
        reports = dse.sweep(g, selections=[MNK])
        front = dse.pareto_front(reports)
        assert 0 < len(front) < len(reports)


class TestPlans:
    def test_output_stationary_kernel_plan(self):
        g = algebra.gemm()
        p = plan.plan_for(df_of(g, MNK, "output_stationary"))
        assert p.kernel.template == "output_stationary"
        assert p.kernel.resident_tensor == "C"
        assert p.kernel.reduction_in_kernel

    def test_weight_stationary_kernel_plan(self):
        g = algebra.gemm()
        p = plan.plan_for(df_of(g, MNK, "weight_stationary"))
        assert p.kernel.template == "operand_stationary"
        assert p.kernel.resident_tensor == "B"

    def test_comm_plan_classes(self):
        g = algebra.gemm()
        # SST -> Cannon-like: two ppermute rings + sharded output
        p = plan.plan_for(df_of(g, MNK, "output_stationary"))
        kinds = {t.tensor: t.kind for t in p.comm.tensors}
        assert kinds == {"A": "ppermute_ring", "B": "ppermute_ring",
                         "C": "shard"}
        # MMT -> SUMMA: two all_gathers + sharded output
        p = plan.plan_for(df_of(g, MNK, "identity"))
        kinds = {t.tensor: t.kind for t in p.comm.tensors}
        assert kinds == {"A": "all_gather", "B": "all_gather", "C": "shard"}

    def test_paper_module_selection(self):
        # paper §V-A: "output stationary contains two modules (a) and one (d);
        #  weight stationary contains one (a), one (b) and one (c)"
        g = algebra.gemm()
        p = plan.plan_for(df_of(g, MNK, "output_stationary"))
        mods = " ".join(p.pe_modules)
        assert mods.count("a:systolic-in") == 2 and "d:stationary-out" in mods
        p = plan.plan_for(df_of(g, MNK, "weight_stationary"))
        mods = " ".join(p.pe_modules)
        assert ("a:systolic-in" in mods and "b:systolic-out" in mods
                and "c:stationary-in" in mods)

    def test_unicast_plan_streams(self):
        bg = algebra.batched_gemv()
        p = plan.plan_for(df_of(bg, MNK, "identity"))
        assert p.comm.by_tensor()["A"].kind == "stream"


class TestParetoFront:
    """Sort-based pareto_front (ISSUE 1 satellite): known front + oracle."""

    @staticmethod
    def _report(cycles, area, power, name="pt"):
        return costmodel.CostReport(
            dataflow_name=name, cycles=cycles, macs=0, peak_macs=0,
            normalized_perf=0.0, utilization=0.0, bw_stall_factor=1.0,
            fill_overhead_frac=0.0, traffic_bytes={},
            area_units=area, power_mw=power)

    def test_known_front(self):
        r = self._report
        pts = [
            r(1, 5, 5, "a"),   # front: best cycles
            r(1, 5, 5, "h"),   # exact duplicate of a: neither dominates
            r(2, 4, 6, "b"),   # front: beats c on area, loses on power
            r(2, 6, 4, "c"),   # front
            r(2, 4, 6, "d"),   # duplicate of b -> front
            r(3, 4, 6, "e"),   # dominated by b (same area/power, more cycles)
            r(3, 9, 9, "f"),   # dominated by everything
            r(2, 5, 5, "g"),   # dominated by a (equal area/power, cycles<)
        ]
        front = {p.dataflow_name for p in dse.pareto_front(pts)}
        assert front == {"a", "h", "b", "c", "d"}
        assert front == {p.dataflow_name
                         for p in dse.pareto_front_reference(pts)}

    def test_matches_reference_on_sweep(self):
        g = algebra.gemm(128, 128, 128)
        reports = dse.sweep(g, selections=[MNK])
        fast = dse.pareto_front(reports)
        slow = dse.pareto_front_reference(reports)
        assert {id(r) for r in fast} == {id(r) for r in slow}
        assert len(fast) >= 1


class TestEnumerationFastPath:
    """The cached enumeration must be indistinguishable from the original."""

    def test_gemm_matches_reference(self):
        g = algebra.gemm(64, 64, 64)
        fast = dse.enumerate_dataflows(g, selections=[MNK])
        slow = dse.enumerate_dataflows_reference(g, selections=[MNK])
        assert set(fast) == set(slow)
        for key in fast:
            assert fast[key].signature == slow[key].signature
            assert fast[key].T == slow[key].T     # same representative

    def test_rank3_selection_skipped_not_crashing(self):
        # conv2d with selection (c, p, q): the output C[k,y,x] has a rank-3
        # reuse subspace for every T -> the selection is unbuildable and
        # must be skipped silently by both paths
        cv = algebra.conv2d(4, 4, 4, 4, 2, 2)
        sel = [("c", "p", "q")]
        assert dse.enumerate_dataflows(cv, selections=sel) == {}
        assert dse.enumerate_dataflows_reference(cv, selections=sel) == {}
